"""Multi-RHS batched-solve benchmark (the serving hot path).

``solve_many`` shares one Gram/Cholesky factorization across a batch of
right-hand sides; this measures its end-to-end wall time against a loop of
independent single-RHS ``solve`` calls (each paying ``prepare`` again) and
reports the amortization speedup.  Projection-family methods get an extra
``use_kernel=True`` row — the fused multi-RHS Pallas path, where the k
batch rows stream through one VMEM residency of every A/B tile (interpret
mode off-TPU; per-iteration trend lives in periter/BENCH_PR5.json).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.core.partition import BlockSystem
from repro.data import linsys
from repro.solvers.store import FactorStore

K = 8          # RHS batch size
ITERS = 150
METHODS = ["apc", "dhbm", "cimmino"]


def run(verbose: bool = True, n: int = 384, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=40.0, seed=0)
    B = np.random.default_rng(1).standard_normal((K, sys_.N))
    store = FactorStore()       # the batched side's one factorization
    rows = []
    for name in METHODS:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)

        t0 = time.perf_counter()
        rb = s.solve_many(sys_, B, iters=ITERS,
                          plan=solvers.ExecutionPlan(store=store), **prm)
        jax.block_until_ready(rb.x)
        t_batch = time.perf_counter() - t0

        # the loop baseline deliberately stays store-less: it is the
        # un-amortized case (every solve repays prepare) that solve_many
        # is measured against

        t0 = time.perf_counter()
        for i in range(K):
            si = BlockSystem(sys_.A_blocks,
                             jnp.asarray(B[i]).reshape(sys_.m, sys_.p))
            ri = s.solve(si, iters=ITERS, **prm)
            jax.block_until_ready(ri.x)
        t_loop = time.perf_counter() - t0

        rows.append((f"batch_rhs/{name}", t_batch * 1e6,
                     f"k={K};speedup={t_loop / t_batch:.2f}x"))
        if verbose:
            print(f"{name:10s} solve_many {t_batch*1e3:8.1f} ms   "
                  f"loop {t_loop*1e3:8.1f} ms   "
                  f"speedup {t_loop/t_batch:5.2f}x")

        if s.supports_kernel:
            # kernel-vs-unfused must isolate FUSION from store
            # amortization: re-time the unfused path store-WARM (factors
            # now cached) so both sides of the ratio hit the cache
            t0 = time.perf_counter()
            rw = s.solve_many(sys_, B, iters=ITERS,
                              plan=solvers.ExecutionPlan(store=store),
                              **prm)
            jax.block_until_ready(rw.x)
            t_warm = time.perf_counter() - t0
            t0 = time.perf_counter()
            rk = s.solve_many(sys_, B, iters=ITERS,
                              plan=solvers.ExecutionPlan(store=store,
                                                         kernel=True),
                              **prm)
            jax.block_until_ready(rk.x)
            t_kernel = time.perf_counter() - t0
            rows.append((f"batch_rhs/{name}_kernel", t_kernel * 1e6,
                         f"k={K};vs_unfused={t_warm / t_kernel:.2f}x"))
            if verbose:
                print(f"{name:10s} solve_many(kernel) {t_kernel*1e3:8.1f} "
                      f"ms   vs unfused(warm) {t_warm/t_kernel:5.2f}x")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
