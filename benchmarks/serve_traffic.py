"""Serve-traffic benchmark: cold vs warm-cache request latency + steady
state through ``LinsysServer``.

What the factor-store/serving subsystem claims, measured:

  * COLD request latency — the first batch for a system pays the
    one-time b-independent ``prepare`` (a store miss) AND the executor
    compile.  WARM latency — every later same-system batch is a store
    hit on an already-compiled executor, so only the per-RHS iterations
    remain.  The paper's cost split (expensive projection/factorization
    phase, cheap per-RHS iterations) is exactly this amortization; the
    acceptance bar is warm >= 5x below cold.
  * ZERO retraces in steady state — the compile-once executor cache is
    keyed by (solver, shapes, params, backend, use_kernel), so the jit
    cache size must be CONSTANT across the last K batches (asserted when
    the running jax can report it).
  * Steady-state throughput in RHS/s, padding excluded.

``measure()`` is the machine-readable core (also recorded in
BENCH_PR5.json by ``scripts/bench_ci.py``, which re-asserts the
zero-retrace invariant as a trend gate); ``use_kernel=True`` serves every
batch through the fused multi-RHS Pallas kernels.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data import linsys
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore

ITERS = 150
BATCH = 4
WARM_BATCHES = 8    # per system, after the cold one
TAIL_K = 5          # jit cache must be constant across the last K batches


def _serve_one_batch(srv, fp, N, rng, batch):
    for _ in range(batch):
        srv.submit(fp, rng.standard_normal(N))
    t0 = time.perf_counter()
    served = srv.step()
    dt = time.perf_counter() - t0
    assert len(served) == batch
    return dt


def measure(n: int = 256, m: int = 4, iters: int = ITERS,
            batch: int = BATCH, warm_batches: int = WARM_BATCHES,
            use_kernel: bool = False) -> dict:
    """Serve 2 systems cold + ``warm_batches`` warm batches; return the
    raw numbers (latencies in seconds, jit-cache trajectory, store
    stats) without asserting — callers gate on what they care about."""
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    systems = [linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=s)
               for s in (0, 1)]
    store = FactorStore()
    srv = LinsysServer(store, solver="apc", iters=iters, batch=batch,
                       use_kernel=use_kernel,
                       # shared explicit params -> ONE executor for both
                       # systems, so system 2's cold batch isolates the
                       # prepare cost from the compile cost
                       gamma=1.0, eta=1.0)
    fps = [srv.register(s) for s in systems]

    t_cold = _serve_one_batch(srv, fps[0], systems[0].N, rng,
                              batch)                       # miss+compile
    t_cold2 = _serve_one_batch(srv, fps[1], systems[1].N, rng,
                               batch)                      # miss only

    warm, cache_sizes = [], []
    for i in range(warm_batches):
        fp, sys_ = fps[i % 2], systems[i % 2]
        warm.append(_serve_one_batch(srv, fp, sys_.N, rng, batch))
        cache_sizes.append(srv.jit_cache_size())
    t_warm = float(np.median(warm))
    tail = cache_sizes[-TAIL_K:]
    return {
        "n": n, "m": m, "iters": iters, "batch": batch,
        "use_kernel": use_kernel,
        "cold_s": t_cold, "cold2_s": t_cold2, "warm_s": t_warm,
        "speedup": t_cold / t_warm,
        "rhs_per_s": batch / t_warm,            # full batches: no padding
        "jit_cache_tail": tail,
        "zero_retrace": (-1 in tail) or len(set(tail)) == 1,
        "store_misses": store.stats.misses,
        "store_hits": store.stats.hits,
    }


def run(verbose: bool = True, n: int = 256, m: int = 4,
        use_kernel: bool = False):
    mm = measure(n=n, m=m, use_kernel=use_kernel)
    assert mm["zero_retrace"], \
        f"jit cache grew across steady-state batches: {mm['jit_cache_tail']}"
    assert mm["speedup"] >= 5.0, (
        f"warm-cache batch only {mm['speedup']:.1f}x faster than cold "
        f"({mm['cold_s'] * 1e3:.1f} ms vs {mm['warm_s'] * 1e3:.1f} ms)")
    assert mm["store_misses"] == 2 and mm["store_hits"] >= WARM_BATCHES

    retraces = "unknown" if -1 in mm["jit_cache_tail"] else 0
    tag = "kernel" if use_kernel else "unfused"
    rows = [
        (f"serve_traffic/cold_batch_{tag}", mm["cold_s"] * 1e6,
         f"n={n};m={m};prepare+compile;batch={BATCH}"),
        (f"serve_traffic/cold_batch_prepare_only_{tag}", mm["cold2_s"] * 1e6,
         "2nd system reuses the compiled executor"),
        (f"serve_traffic/warm_batch_{tag}", mm["warm_s"] * 1e6,
         f"speedup={mm['speedup']:.1f}x;retraces={retraces};"
         f"rhs_per_s={mm['rhs_per_s']:.1f}"),
    ]
    if verbose:
        print(f"[{tag}] cold  {mm['cold_s'] * 1e3:8.1f} ms   "
              f"(prepare + compile)")
        print(f"[{tag}] cold2 {mm['cold2_s'] * 1e3:8.1f} ms   (prepare "
              f"only, executor shared)")
        print(f"[{tag}] warm  {mm['warm_s'] * 1e3:8.1f} ms   "
              f"({mm['speedup']:.1f}x, {mm['rhs_per_s']:.1f} RHS/s, "
              f"jit cache {mm['jit_cache_tail']})")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
    run(use_kernel=True)
