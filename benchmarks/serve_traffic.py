"""Serve-traffic benchmark: cold vs warm-cache request latency + steady
state through ``LinsysServer``.

What the factor-store/serving subsystem claims, measured:

  * COLD request latency — the first batch for a system pays the
    one-time b-independent ``prepare`` (a store miss) AND the executor
    compile.  WARM latency — every later same-system batch is a store
    hit on an already-compiled executor, so only the per-RHS iterations
    remain.  The paper's cost split (expensive projection/factorization
    phase, cheap per-RHS iterations) is exactly this amortization; the
    acceptance bar is warm >= 5x below cold.
  * ZERO retraces in steady state — the compile-once executor cache is
    keyed by (solver, shapes, params, backend, use_kernel), so the jit
    cache size must be CONSTANT across the last K batches (asserted when
    the running jax can report it).
  * Steady-state throughput in RHS/s, padding excluded.

``measure()`` is the machine-readable core (also recorded in
BENCH_PR*.json by ``scripts/bench_ci.py``, which re-asserts the
zero-retrace invariant as a trend gate); ``use_kernel=True`` serves every
batch through the fused multi-RHS Pallas kernels.

``traffic()`` is the OPEN-LOOP closed-measurement harness the async
pipeline is gated on: requests arrive on a Poisson (or bursty) schedule
regardless of how fast the server drains them — the arrival process never
waits on completions, which is what exposes saturation — while latency is
measured per request from its SCHEDULED arrival to its completion.  It
drives either server (``server="sync"`` steps ``LinsysServer`` between
arrivals; ``server="async"`` submits into the ``AsyncLinsysServer``
pipeline at arrival time) and reports p50/p95/p99 latency, sustained
throughput, and the shed rate.  ``scripts/bench_ci.py`` runs the pair at
a rate where the sync loop saturates and gates async >= sync throughput.

``streaming()`` is the streaming-mode scenario: one registered system,
100 perturbed right-hand sides driven through ``solve_stream`` with
``warm_start=True`` — the warm-hit rate (gated at 1.0 for warm_rhs_ok
solvers) and steady-state zero-retrace are the system-mode refactor's
serving claims, recorded per server kind.

The async win is HOST-PARALLELISM dependent: at saturation the sync loop
never idles, so on a single-core host it already sits at the makespan
floor (total CPU work / 1 core) and no overlap can beat it — the
pipeline's gain comes from filling the cores the sync loop leaves idle
between device calls.  ``traffic()`` therefore records ``host_cpus`` and
the bench gate degrades from strict async>=sync to an overhead bound
(async >= 0.80x sync) when the host has a single core.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.data import linsys
from repro.solvers.pipeline import AsyncLinsysServer, Shed
from repro.solvers.serve import LinsysServer, solve_stream
from repro.solvers.store import FactorStore

ITERS = 150
BATCH = 4
WARM_BATCHES = 8    # per system, after the cold one
TAIL_K = 5          # jit cache must be constant across the last K batches


def _serve_one_batch(srv, fp, N, rng, batch):
    for _ in range(batch):
        srv.submit(fp, rng.standard_normal(N))
    t0 = time.perf_counter()
    served = srv.step()
    dt = time.perf_counter() - t0
    assert len(served) == batch
    return dt


def measure(n: int = 256, m: int = 4, iters: int = ITERS,
            batch: int = BATCH, warm_batches: int = WARM_BATCHES,
            use_kernel: bool = False) -> dict:
    """Serve 2 systems cold + ``warm_batches`` warm batches; return the
    raw numbers (latencies in seconds, jit-cache trajectory, store
    stats) without asserting — callers gate on what they care about."""
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    systems = [linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=s)
               for s in (0, 1)]
    store = FactorStore()
    srv = LinsysServer(store, solver="apc", iters=iters, batch=batch,
                       use_kernel=use_kernel,
                       # shared explicit params -> ONE executor for both
                       # systems, so system 2's cold batch isolates the
                       # prepare cost from the compile cost
                       gamma=1.0, eta=1.0)
    fps = [srv.register(s) for s in systems]

    t_cold = _serve_one_batch(srv, fps[0], systems[0].N, rng,
                              batch)                       # miss+compile
    t_cold2 = _serve_one_batch(srv, fps[1], systems[1].N, rng,
                               batch)                      # miss only

    warm, cache_sizes = [], []
    for i in range(warm_batches):
        fp, sys_ = fps[i % 2], systems[i % 2]
        warm.append(_serve_one_batch(srv, fp, sys_.N, rng, batch))
        cache_sizes.append(srv.jit_cache_size())
    t_warm = float(np.median(warm))
    tail = cache_sizes[-TAIL_K:]
    return {
        "n": n, "m": m, "iters": iters, "batch": batch,
        "use_kernel": use_kernel,
        "cold_s": t_cold, "cold2_s": t_cold2, "warm_s": t_warm,
        "speedup": t_cold / t_warm,
        "rhs_per_s": batch / t_warm,            # full batches: no padding
        "jit_cache_tail": tail,
        "zero_retrace": (-1 in tail) or len(set(tail)) == 1,
        "store_misses": store.stats.misses,
        "store_hits": store.stats.hits,
    }


# ---------------------------------------------------------------------------
# Open-loop traffic harness (Poisson / bursty arrivals, SLO measurement)
# ---------------------------------------------------------------------------


def host_cpus() -> int:
    """CPU cores actually available to this process (affinity-aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                       # non-Linux
        return os.cpu_count() or 1


def arrival_times(arrival: str, rate: float, n_requests: int,
                  seed: int = 0, burst: int = 8) -> np.ndarray:
    """Scheduled arrival offsets (seconds from t0) for an open-loop run.

    ``poisson``: exponential inter-arrivals at ``rate`` req/s.  ``bursty``:
    the same mean rate delivered as back-to-back bursts of ``burst``
    simultaneous requests (a Poisson burst process at rate/burst).  A
    non-positive or infinite rate degenerates to one burst at t=0 — the
    saturation probe.
    """
    if not np.isfinite(rate) or rate <= 0:
        return np.zeros(n_requests)
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    if arrival == "bursty":
        n_bursts = int(np.ceil(n_requests / burst))
        gaps = rng.exponential(burst / rate, size=n_bursts)
        return np.repeat(np.cumsum(gaps), burst)[:n_requests]
    raise ValueError(f"unknown arrival process {arrival!r}; "
                     "expected 'poisson' or 'bursty'")


def _traffic_setup(server, solver, systems, n, m, iters, batch, warm_start,
                   use_kernel, pipeline_depth, admit_capacity, seed):
    syss = [linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=s)
            for s in range(systems)]
    store = FactorStore()
    # explicit params where the solver allows it -> ONE shared executor
    prm = ({"gamma": 1.0, "eta": 1.0} if solver in ("apc", "consensus")
           else {})
    kw = dict(solver=solver, iters=iters, batch=batch,
              warm_start=warm_start, use_kernel=use_kernel, **prm)
    if server == "async":
        srv = AsyncLinsysServer(store, pipeline_depth=pipeline_depth,
                                admit_capacity=admit_capacity or 4096, **kw)
    elif server == "sync":
        srv = LinsysServer(store, **kw)
    else:
        raise ValueError(f"unknown server {server!r}")
    fps = [srv.register(s) for s in syss]
    rng = np.random.default_rng(seed + 1)
    return srv, store, syss, fps, rng


def _prime(srv, syss, fps, rng, batch, server):
    """One batch per system OFF the clock: prepare + compile are the cold
    costs ``measure()`` tracks; the traffic harness measures steady state."""
    for fp, s in zip(fps, syss):
        for _ in range(batch):
            srv.submit(fp, rng.standard_normal(s.N))
    if server == "async":
        srv.start()
        srv.drain()
        srv.reset_metrics()
    else:
        srv.drain()


def traffic(server: str = "async", arrival: str = "poisson",
            rate: float = 100.0, n_requests: int = 48, systems: int = 2,
            n: int = 256, m: int = 4, iters: int = 100, batch: int = BATCH,
            pipeline_depth: int = 2, admit_capacity: int = None,
            warm_start: bool = False, use_kernel: bool = False,
            solver: str = "apc", seed: int = 0, burst: int = 8) -> dict:
    """Open-loop arrivals, closed measurement: drive ``n_requests`` over
    ``systems`` distinct systems at ``rate`` req/s through either server
    and report the SLO numbers.

    Latency is scheduled-arrival -> completion (so a request that arrives
    while the sync loop is mid-batch is charged its queueing delay);
    throughput counts SERVED requests (shed excluded) over the span from
    first arrival to last completion; the jit cache is sampled after the
    priming batches and at the end — equal sizes == zero steady-state
    retraces.
    """
    jax.config.update("jax_enable_x64", True)
    srv, store, syss, fps, rng = _traffic_setup(
        server, solver, systems, n, m, iters, batch, warm_start,
        use_kernel, pipeline_depth, admit_capacity, seed)
    _prime(srv, syss, fps, rng, batch, server)
    cache0 = srv.jit_cache_size()

    arr = arrival_times(arrival, rate, n_requests, seed=seed, burst=burst)
    order = np.random.default_rng(seed + 2).integers(0, systems,
                                                     size=n_requests)
    rhs = [rng.standard_normal(syss[i].N) for i in order]

    lat, served, shed = [], 0, 0
    max_res = 0.0
    if server == "async":
        t0 = time.perf_counter()
        tickets = []
        for i in range(n_requests):
            wait = t0 + arr[i] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            tickets.append(srv.submit(fps[order[i]], rhs[i]))
        results = [t.result() for t in tickets]
        t_end = time.perf_counter()
        for r in results:
            if isinstance(r, Shed):
                shed += 1
            else:
                served += 1
                max_res = max(max_res, r.residual)
        lat = list(srv.latencies())
        srv.close()
    else:
        t0 = time.perf_counter()
        arrived_at = {}
        i = 0
        while served < n_requests:
            now = time.perf_counter() - t0
            while i < n_requests and arr[i] <= now:
                rid = srv.submit(fps[order[i]], rhs[i])
                arrived_at[rid] = arr[i]
                i += 1
            if srv.pending() == 0:
                if i < n_requests:
                    time.sleep(max(arr[i] - (time.perf_counter() - t0),
                                   1e-4))
                continue
            for r in srv.step():
                done = time.perf_counter() - t0
                lat.append(done - arrived_at[r.rid])
                served += 1
                max_res = max(max_res, r.residual)
        t_end = time.perf_counter()

    cache1 = srv.jit_cache_size()
    span = max(t_end - t0, 1e-9)
    lat = np.asarray(lat if lat else [0.0])
    q = np.percentile(lat, [50, 95, 99]) * 1e3
    return {
        "server": server, "arrival": arrival, "rate": float(rate),
        "n_requests": n_requests, "systems": systems, "n": n, "m": m,
        "iters": iters, "batch": batch, "pipeline_depth": pipeline_depth,
        "warm_start": warm_start, "use_kernel": use_kernel,
        "served": served, "shed": shed,
        "shed_rate": shed / n_requests,
        "throughput_rhs_s": served / span,
        "p50_ms": float(q[0]), "p95_ms": float(q[1]), "p99_ms": float(q[2]),
        "mean_ms": float(lat.mean() * 1e3),
        "max_residual": max_res, "duration_s": span,
        "host_cpus": host_cpus(),
        "jit_cache": (cache0, cache1),
        "zero_retrace": (-1 in (cache0, cache1)) or cache0 == cache1,
        "store_misses": store.stats.misses,
    }


def saturation_throughput(**kw) -> float:
    """Sync ``drain()`` throughput on a t=0 burst: the capacity of the
    one-batch-at-a-time loop.  Rates above this saturate it."""
    return traffic(server="sync", rate=float("inf"), **kw)[
        "throughput_rhs_s"]


def streaming(server: str = "sync", solver: str = "dhbm", n: int = 256,
              m: int = 4, iters: int = ITERS, n_requests: int = 100,
              perturb: float = 1e-3, seed: int = 0) -> dict:
    """Streaming-clients scenario: ONE registered system re-solved under
    ``n_requests`` perturbed right-hand sides (sensor-update traffic)
    through ``solve_stream``.

    Measures the warm-start gating end to end: with a ``warm_rhs_ok``
    solver (default dhbm) every post-priming batch must resume from the
    previous state, and the steady-state jit cache must stay constant.
    The first two requests prime the cold AND warm executor paths; only
    the remaining ``n_requests - 2`` are measured."""
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(seed)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=0)
    cls = {"sync": LinsysServer, "async": AsyncLinsysServer}[server]
    srv = cls(FactorStore(), solver=solver, iters=iters, batch=1,
              warm_start=True)
    fp = srv.register(sys_)
    b0 = rng.standard_normal(sys_.N)
    stream = [(fp, b0 + perturb * rng.standard_normal(sys_.N))
              for _ in range(n_requests)]
    solve_stream(srv, stream[:2])
    cache0 = srv.jit_cache_size()
    t0 = time.perf_counter()
    rep = solve_stream(srv, stream[2:])
    dt = time.perf_counter() - t0
    cache1 = srv.jit_cache_size()
    if hasattr(srv, "close"):
        srv.close()
    return {
        "server": server, "solver": solver, "n": n, "m": m, "iters": iters,
        "n_requests": n_requests, "perturb": perturb,
        "served": len(rep.served), "batches": rep.batches,
        "warm_batches": rep.warm_batches,
        "warm_hit_rate": rep.warm_hit_rate,
        "rhs_per_s": len(rep.served) / dt if dt > 0 else float("inf"),
        "max_residual": max((r.residual for r in rep.served),
                            default=float("nan")),
        "jit_cache": [cache0, cache1],
        "zero_retrace": cache0 < 0 or cache1 == cache0,
    }


def run(verbose: bool = True, n: int = 256, m: int = 4,
        use_kernel: bool = False):
    mm = measure(n=n, m=m, use_kernel=use_kernel)
    assert mm["zero_retrace"], \
        f"jit cache grew across steady-state batches: {mm['jit_cache_tail']}"
    assert mm["speedup"] >= 5.0, (
        f"warm-cache batch only {mm['speedup']:.1f}x faster than cold "
        f"({mm['cold_s'] * 1e3:.1f} ms vs {mm['warm_s'] * 1e3:.1f} ms)")
    assert mm["store_misses"] == 2 and mm["store_hits"] >= WARM_BATCHES

    retraces = "unknown" if -1 in mm["jit_cache_tail"] else 0
    tag = "kernel" if use_kernel else "unfused"
    rows = [
        (f"serve_traffic/cold_batch_{tag}", mm["cold_s"] * 1e6,
         f"n={n};m={m};prepare+compile;batch={BATCH}"),
        (f"serve_traffic/cold_batch_prepare_only_{tag}", mm["cold2_s"] * 1e6,
         "2nd system reuses the compiled executor"),
        (f"serve_traffic/warm_batch_{tag}", mm["warm_s"] * 1e6,
         f"speedup={mm['speedup']:.1f}x;retraces={retraces};"
         f"rhs_per_s={mm['rhs_per_s']:.1f}"),
    ]
    if verbose:
        print(f"[{tag}] cold  {mm['cold_s'] * 1e3:8.1f} ms   "
              f"(prepare + compile)")
        print(f"[{tag}] cold2 {mm['cold2_s'] * 1e3:8.1f} ms   (prepare "
              f"only, executor shared)")
        print(f"[{tag}] warm  {mm['warm_s'] * 1e3:8.1f} ms   "
              f"({mm['speedup']:.1f}x, {mm['rhs_per_s']:.1f} RHS/s, "
              f"jit cache {mm['jit_cache_tail']})")

    # open-loop Poisson traffic at a rate where the sync loop saturates:
    # the async pipeline must sustain at least the sync throughput with
    # its p50/p95/p99 on record (the BENCH gate re-asserts this)
    cap = saturation_throughput(n_requests=24, iters=100,
                                use_kernel=use_kernel)
    for srv_kind in ("sync", "async"):
        tr = traffic(server=srv_kind, rate=2.0 * cap, n_requests=32,
                     iters=100, use_kernel=use_kernel)
        rows.append((
            f"serve_traffic/{srv_kind}_p99_{tag}", tr["p99_ms"] * 1e3,
            f"rate={tr['rate']:.0f}rps;tp={tr['throughput_rhs_s']:.1f}rhs/s;"
            f"p50={tr['p50_ms']:.0f}ms;shed={tr['shed_rate']:.2f}"))
        if verbose:
            print(f"[{tag}] {srv_kind:5s} @{tr['rate']:6.0f} req/s: "
                  f"{tr['throughput_rhs_s']:6.1f} RHS/s   p50/p95/p99 "
                  f"{tr['p50_ms']:.0f}/{tr['p95_ms']:.0f}/"
                  f"{tr['p99_ms']:.0f} ms   shed {tr['shed_rate']:.2f}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
    run(use_kernel=True)
