"""Serve-traffic benchmark: cold vs warm-cache request latency + steady
state through ``LinsysServer``.

What the factor-store/serving subsystem claims, measured:

  * COLD request latency — the first batch for a system pays the
    one-time b-independent ``prepare`` (a store miss) AND the executor
    compile.  WARM latency — every later same-system batch is a store
    hit on an already-compiled executor, so only the per-RHS iterations
    remain.  The paper's cost split (expensive projection/factorization
    phase, cheap per-RHS iterations) is exactly this amortization; the
    acceptance bar is warm >= 5x below cold.
  * ZERO retraces in steady state — the compile-once executor cache is
    keyed by (solver, shapes, params, backend), so the jit cache size
    must be CONSTANT across the last K batches (asserted when the
    running jax can report it).
  * Steady-state throughput in RHS/s, padding excluded.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data import linsys
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore

ITERS = 150
BATCH = 4
WARM_BATCHES = 8    # per system, after the cold one
TAIL_K = 5          # jit cache must be constant across the last K batches


def _serve_one_batch(srv, fp, N, rng):
    for _ in range(BATCH):
        srv.submit(fp, rng.standard_normal(N))
    t0 = time.perf_counter()
    served = srv.step()
    dt = time.perf_counter() - t0
    assert len(served) == BATCH
    return dt


def run(verbose: bool = True, n: int = 256, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    systems = [linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=s)
               for s in (0, 1)]
    store = FactorStore()
    srv = LinsysServer(store, solver="apc", iters=ITERS, batch=BATCH,
                       # shared explicit params -> ONE executor for both
                       # systems, so system 2's cold batch isolates the
                       # prepare cost from the compile cost
                       gamma=1.0, eta=1.0)
    fps = [srv.register(s) for s in systems]

    t_cold = _serve_one_batch(srv, fps[0], systems[0].N, rng)   # miss+compile
    t_cold2 = _serve_one_batch(srv, fps[1], systems[1].N, rng)  # miss only

    warm, cache_sizes = [], []
    for i in range(WARM_BATCHES):
        fp, sys_ = fps[i % 2], systems[i % 2]
        warm.append(_serve_one_batch(srv, fp, sys_.N, rng))
        cache_sizes.append(srv.jit_cache_size())
    t_warm = float(np.median(warm))

    speedup = t_cold / t_warm
    tail = cache_sizes[-TAIL_K:]
    steady = (-1 in tail) or len(set(tail)) == 1
    assert steady, f"jit cache grew across steady-state batches: {tail}"
    assert speedup >= 5.0, (
        f"warm-cache batch only {speedup:.1f}x faster than cold "
        f"({t_cold * 1e3:.1f} ms vs {t_warm * 1e3:.1f} ms)")
    assert store.stats.misses == 2 and store.stats.hits >= WARM_BATCHES

    rhs_per_s = BATCH / t_warm              # full batches: no padding
    retraces = "unknown" if -1 in tail else 0
    rows = [
        ("serve_traffic/cold_batch", t_cold * 1e6,
         f"n={n};m={m};prepare+compile;batch={BATCH}"),
        ("serve_traffic/cold_batch_prepare_only", t_cold2 * 1e6,
         "2nd system reuses the compiled executor"),
        ("serve_traffic/warm_batch", t_warm * 1e6,
         f"speedup={speedup:.1f}x;retraces={retraces};"
         f"rhs_per_s={rhs_per_s:.1f}"),
    ]
    if verbose:
        print(f"cold  {t_cold * 1e3:8.1f} ms   (prepare + compile)")
        print(f"cold2 {t_cold2 * 1e3:8.1f} ms   (prepare only, executor "
              f"shared)")
        print(f"warm  {t_warm * 1e3:8.1f} ms   ({speedup:.1f}x, "
              f"{rhs_per_s:.1f} RHS/s, jit cache {tail})")
        print(f"store {store.stats}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
