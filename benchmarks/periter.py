"""Per-iteration cost benchmark (paper §3.3 / §4 complexity claims).

All methods have O(pn) per-iteration complexity per worker; this measures
actual per-iteration wall time of the jitted updates on the same system so
the convergence-time comparisons (Table 2) are wall-clock fair.  Also times
the Pallas kernel path (interpret mode — functional check, not TPU perf).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import apc, baselines
from repro.data import linsys


def _time(fn, *args, iters=50, warmup=3):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(verbose: bool = True, n: int = 512, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=50.0, seed=0)
    rows = []

    factors = apc.prepare(sys_)
    state = apc.init_state(factors)
    step = jax.jit(lambda s: apc.apc_step(factors, s, 1.3, 1.2))
    rows.append(("periter/apc", _time(step, state), f"n={n};m={m}"))

    stepk = jax.jit(lambda s: apc.apc_step(factors, s, 1.3, 1.2,
                                           use_kernel=True))
    rows.append(("periter/apc_pallas_interpret", _time(stepk, state, iters=5),
                 "interpret-mode"))

    x0 = jnp.zeros(sys_.n)
    g = jax.jit(lambda x: x - 1e-4 * baselines._full_grad(sys_, x))
    rows.append(("periter/dgd", _time(g, x0), f"n={n};m={m}"))

    if verbose:
        for r in rows:
            print(f"{r[0]:34s} {r[1]:10.1f} us   {r[2]}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
