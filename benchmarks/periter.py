"""Per-iteration cost benchmark (paper §3.3 / §4 complexity claims).

All methods have O(pn) per-iteration complexity per worker; this measures
actual per-iteration wall time of every registered solver's jitted ``step``
on the same system — through the unified prepare/init/step lifecycle — so
the convergence-time comparisons (Table 2) are wall-clock fair.  Also times
the Pallas kernel path (interpret mode — functional check, not TPU perf).
"""
from __future__ import annotations

import time

import jax

from repro import solvers
from repro.data import linsys
from repro.solvers.store import FactorStore


def _time(fn, *args, iters=50, warmup=3):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(verbose: bool = True, n: int = 512, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=50.0, seed=0)
    store = FactorStore(capacity=len(solvers.available()) + 1)
    rows = []

    for name in solvers.available():
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        factors = store.factors(s, sys_, **prm)
        state = s.init(factors, sys_.b_blocks, prm)
        step = jax.jit(lambda st, _f=factors, _p=prm, _s=s: _s.step(
            _f, sys_.b_blocks, st, _p))
        rows.append((f"periter/{name}", _time(step, state), f"n={n};m={m}"))

    # Pallas kernel path, interpret mode (functional check, not TPU perf);
    # use_kernel=True hands back pinv-augmented factors so the step takes
    # the actual kernel fast path
    s = solvers.get("apc")
    prm = {"gamma": 1.3, "eta": 1.2}
    factors = store.factors(s, sys_, use_kernel=True, **prm)
    state = s.init(factors, sys_.b_blocks, prm)
    stepk = jax.jit(lambda st: s.step(factors, sys_.b_blocks, st, prm,
                                      use_kernel=True))
    rows.append(("periter/apc_pallas_interpret", _time(stepk, state, iters=5),
                 "interpret-mode"))

    # batched multi-RHS step amortization (the serving hot path)
    import jax.numpy as jnp
    import numpy as np
    k = 8
    Bb = jnp.asarray(np.random.default_rng(0).standard_normal(
        (k, sys_.m, sys_.p)))
    states = jax.vmap(lambda b: s.init(factors, b, prm))(Bb)
    vstep = jax.jit(jax.vmap(lambda b, st: s.step(factors, b, st, prm),
                             in_axes=(0, 0)))
    rows.append((f"periter/apc_batch{k}", _time(vstep, Bb, states),
                 f"us per {k}-RHS step"))

    if verbose:
        for r in rows:
            print(f"{r[0]:34s} {r[1]:10.1f} us   {r[2]}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
