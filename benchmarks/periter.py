"""Per-iteration cost benchmark (paper §3.3 / §4 complexity claims).

All methods have O(pn) per-iteration complexity per worker; this measures
actual per-iteration wall time of every registered solver's jitted ``step``
on the same system — through the unified prepare/init/step lifecycle — so
the convergence-time comparisons (Table 2) are wall-clock fair.

``kernel_comparison`` is the machine-readable kernel-vs-unfused matrix
(projection family, batch 1 vs 16) that seeds the benchmark trajectory:
``scripts/bench_ci.py`` records it in BENCH_PR*.json and gates kernel >=
unfused at batch 16 so later PRs have a trend to regress against.  On
CPU lanes the kernels run in interpret mode — a functional trend
baseline, not TPU perf (the recorded ``interpret`` flag says which).

Three paths per (method, batch) cell since the engine autotune landed:
``unfused`` (use_kernel=False), ``kernel`` (the RAW fused kernels, pinned
via ``REPRO_KERNEL_ENGINE=fused`` so the PR5 trend keeps its meaning),
and ``dispatch`` (use_kernel=True through ``kops.use_fused`` — what the
serving executors actually compile).  ``dispatch_speedup_b{k}`` =
unfused/dispatch is the satellite regression number: the cimmino batch-1
cell, 0.88x when always-fused (BENCH_PR5), must sit at ~1.0x now that
dispatch falls back to the unfused step there.
"""
from __future__ import annotations

import time

import jax

from repro import solvers
from repro.data import linsys
from repro.solvers.store import FactorStore


def _time(fn, *args, iters=50, warmup=3):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def kernel_comparison(n: int = 512, m: int = 2, batches=(1, 16),
                      iters: int = 30,
                      methods=("apc", "consensus", "cimmino")) -> dict:
    """Fused-kernel vs unfused per-iteration times for the projection
    family at each RHS batch size.

    One jitted ``step_many`` per (method, batch, path); the kernel path
    runs on pinv-augmented factors from a store (augment-once), exactly
    the executor the serving layer uses.  Returns

        {"n", "m", "p", "interpret", "methods": {name: {
            "unfused_b{k}_us", "kernel_b{k}_us", "kernel_speedup_b{k}"}}}

    The default shape (p = n/m = 256 rows per worker, single BN tile) is
    the store-served worker block the paper's cost split targets — big
    enough that the per-step Gram solves the kernel path eliminates
    dominate the unfused step.
    """
    import os

    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import block_projection as bp
    from repro.kernels import ops as kops

    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=0)
    store = FactorStore()
    out = {"n": n, "m": m, "p": sys_.p, "iters_timed": iters,
           "interpret": bp.default_interpret(), "methods": {}}
    for name in methods:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        factors = store.factors(s, sys_, use_kernel=True, **prm)
        family = "cimmino" if name == "cimmino" else "apc"
        per = {}
        for k in batches:
            Bb = jnp.asarray(np.random.default_rng(0).standard_normal(
                (k, sys_.m, sys_.p)))
            states = jax.vmap(lambda b: s.init(factors, b, prm))(Bb)
            unfused = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                              _s.step_many(_f, _B, sts, _p,
                                           use_kernel=False))
            fused = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                            _s.step_many(_f, _B, sts, _p, use_kernel=True))
            dispatch = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                               _s.step_many(_f, _B, sts, _p,
                                            use_kernel=True))
            tu = _time(unfused, states, iters=iters)
            # RAW kernel timing: pin the engine so the trace can't fall
            # back to the unfused step (the dispatch row measures that)
            prev = os.environ.get(kops.ENGINE_ENV)
            os.environ[kops.ENGINE_ENV] = "fused"
            try:
                tk = _time(fused, states, iters=iters)
            finally:
                if prev is None:
                    os.environ.pop(kops.ENGINE_ENV, None)
                else:
                    os.environ[kops.ENGINE_ENV] = prev
            td = _time(dispatch, states, iters=iters)
            per[f"unfused_b{k}_us"] = round(tu, 2)
            per[f"kernel_b{k}_us"] = round(tk, 2)
            per[f"kernel_speedup_b{k}"] = round(tu / tk, 4)
            per[f"dispatch_b{k}_us"] = round(td, 2)
            per[f"dispatch_speedup_b{k}"] = round(tu / td, 4)
            per[f"engine_b{k}"] = ("fused" if kops.use_fused(
                family, sys_.p, sys_.N, k, Bb.dtype) else "unfused")
        out["methods"][name] = per
    return out


def sparse_comparison(n: int = 768, m: int = 4, bandwidth: int = 8,
                      iters: int = 30,
                      methods=("cimmino", "dgd")) -> dict:
    """Sparse-vs-densified per-iteration times on a banded system.

    The compressed ``SparseBlocks`` operand contracts over the support
    width ``w`` instead of ``n``; at the default shape (>= 90% zero
    entries, w/n ~ 0.3) the sparse step must not lose to the densified
    twin it is numerically identical to — that ratio is the
    ``sparse_ge_densified`` trend gate in ``scripts/bench_ci.py``.
    Returns

        {"n", "m", "p", "sparsity", "support_width", "methods": {name: {
            "sparse_us", "dense_us", "sparse_speedup"}}}
    """
    jax.config.update("jax_enable_x64", True)
    sp = linsys.banded_system(n=n, m=m, bandwidth=bandwidth, seed=0)
    dn = sp.densified()
    store = FactorStore(capacity=2 * len(methods) + 1)
    out = {"n": n, "m": m, "p": sp.p, "bandwidth": bandwidth,
           "sparsity": round(sp.sparsity, 4),
           "support_width": int(sp.cols.shape[1]), "iters_timed": iters,
           "methods": {}}
    for name in methods:
        s = solvers.get(name)
        prm = s.resolve_params(sp)
        times = {}
        for tag, sys_ in (("sparse", sp), ("dense", dn)):
            factors = store.factors(s, sys_, **prm)
            state = s.init(factors, sys_.b_blocks, prm)
            step = jax.jit(lambda st, _f=factors, _p=prm, _s=s,
                           _b=sys_.b_blocks: _s.step(_f, _b, st, _p))
            times[tag] = _time(step, state, iters=iters)
        out["methods"][name] = {
            "sparse_us": round(times["sparse"], 2),
            "dense_us": round(times["dense"], 2),
            "sparse_speedup": round(times["dense"] / times["sparse"], 4),
        }
    return out


def sparse_kernel_comparison(n: int = 768, m: int = 4, bandwidth: int = 8,
                             iters: int = 30, batches=(1, 16),
                             methods=("apc", "cimmino")) -> dict:
    """Fused compressed-support kernels vs the unfused sparse step.

    The PR 9 tentpole: on a >= 90%-sparse banded system the kernel path
    contracts (p, w) vals / (w, p) compressed-pinv tiles instead of
    falling back to the dense engine.  Three paths per (method, batch)
    cell, mirroring ``kernel_comparison``: ``unfused``, raw ``kernel``
    (engine pinned fused), and ``dispatch`` (what ``use_fused`` picks).
    ``scripts/bench_ci.py`` gates dispatch >= unfused at batch 16.
    """
    import os

    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import block_projection as bp
    from repro.kernels import ops as kops

    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.banded_system(n=n, m=m, bandwidth=bandwidth, seed=0)
    store = FactorStore()
    out = {"n": n, "m": m, "p": sys_.p, "bandwidth": bandwidth,
           "sparsity": round(sys_.sparsity, 4),
           "support_width": int(sys_.cols.shape[1]), "iters_timed": iters,
           "interpret": bp.default_interpret(), "methods": {}}
    for name in methods:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        factors = store.factors(s, sys_, use_kernel=True, **prm)
        family = ("cimmino" if name == "cimmino" else "apc") + "_sparse"
        w = int(factors.A.vals.shape[2])
        per = {}
        for k in batches:
            Bb = jnp.asarray(np.random.default_rng(0).standard_normal(
                (k, sys_.m, sys_.p)))
            states = jax.vmap(lambda b: s.init(factors, b, prm))(Bb)
            unfused = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                              _s.step_many(_f, _B, sts, _p,
                                           use_kernel=False))
            fused = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                            _s.step_many(_f, _B, sts, _p, use_kernel=True))
            dispatch = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                               _s.step_many(_f, _B, sts, _p,
                                            use_kernel=True))
            tu = _time(unfused, states, iters=iters)
            prev = os.environ.get(kops.ENGINE_ENV)
            os.environ[kops.ENGINE_ENV] = "fused"
            try:
                tk = _time(fused, states, iters=iters)
            finally:
                if prev is None:
                    os.environ.pop(kops.ENGINE_ENV, None)
                else:
                    os.environ[kops.ENGINE_ENV] = prev
            td = _time(dispatch, states, iters=iters)
            per[f"unfused_b{k}_us"] = round(tu, 2)
            per[f"kernel_b{k}_us"] = round(tk, 2)
            per[f"kernel_speedup_b{k}"] = round(tu / tk, 4)
            per[f"dispatch_b{k}_us"] = round(td, 2)
            per[f"dispatch_speedup_b{k}"] = round(tu / td, 4)
            per[f"engine_b{k}"] = ("fused" if kops.use_fused(
                family, sys_.p, sys_.N, k, factors.A.vals.dtype, w=w)
                else "unfused")
        out["methods"][name] = per
    return out


def fused_residual_comparison(n: int = 512, m: int = 4, bandwidth: int = 8,
                              k: int = 16, iters: int = 30,
                              methods=("apc", "cimmino")) -> dict:
    """Fused in-step residual vs a separate ||AX - b|| pass, batch ``k``.

    ``step_many_residual`` harvests the residual from the worker
    contraction the step already does; the separate pass re-reads the
    full operand for a second ``bmatvec_many``.  The gate in
    ``scripts/bench_ci.py``: fused >= separate at batch 16.
    """
    import jax.numpy as jnp
    import numpy as np
    from repro.core import blockops
    from repro.kernels import block_projection as bp

    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.banded_system(n=n, m=m, bandwidth=bandwidth, seed=0)
    store = FactorStore()
    out = {"n": n, "m": m, "k": k, "iters_timed": iters,
           "interpret": bp.default_interpret(), "methods": {}}
    A_op = sys_.A_op
    for name in methods:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        factors = store.factors(s, sys_, use_kernel=True, **prm)
        Bb = jnp.asarray(np.random.default_rng(0).standard_normal(
            (k, sys_.m, sys_.p)))
        states = jax.vmap(lambda b: s.init(factors, b, prm))(Bb)
        fused = jax.jit(lambda sts, _f=factors, _p=prm, _s=s, _B=Bb:
                        _s.step_many_residual(_f, _B, sts, _p))

        def _separate(sts, _f=factors, _p=prm, _s=s, _B=Bb):
            nxt = _s.step_many(_f, _B, sts, _p, use_kernel=True)
            r = blockops.bmatvec_many(A_op, _s.extract(nxt)) - _B
            return nxt, jnp.sum(r * r, axis=(1, 2))

        separate = jax.jit(_separate)
        tf = _time(fused, states, iters=iters)
        ts = _time(separate, states, iters=iters)
        out["methods"][name] = {
            "fused_us": round(tf, 2), "separate_us": round(ts, 2),
            "fused_speedup": round(ts / tf, 4),
        }
    return out


def run(verbose: bool = True, n: int = 512, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=50.0, seed=0)
    store = FactorStore(capacity=len(solvers.available()) + 1)
    rows = []

    for name in solvers.available():
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        factors = store.factors(s, sys_, **prm)
        state = s.init(factors, sys_.b_blocks, prm)
        step = jax.jit(lambda st, _f=factors, _p=prm, _s=s: _s.step(
            _f, sys_.b_blocks, st, _p))
        rows.append((f"periter/{name}", _time(step, state), f"n={n};m={m}"))

    # fused Pallas engine vs the unfused step, batch 1 and 16 (interpret
    # mode off-TPU — functional trend, not TPU perf); same matrix as the
    # BENCH_PR5.json trend gate
    cmp_ = kernel_comparison()
    mode = "interpret" if cmp_["interpret"] else "compiled"
    for name, per in cmp_["methods"].items():
        for k in (1, 16):
            rows.append((f"periter/{name}_kernel_b{k}",
                         per[f"kernel_b{k}_us"],
                         f"{mode};unfused={per[f'unfused_b{k}_us']:.1f}us;"
                         f"speedup={per[f'kernel_speedup_b{k}']:.2f}x"))
            rows.append((f"periter/{name}_dispatch_b{k}",
                         per[f"dispatch_b{k}_us"],
                         f"{mode};engine={per[f'engine_b{k}']};"
                         f"vs_unfused={per[f'dispatch_speedup_b{k}']:.2f}x"))

    # sparse fused kernels vs the unfused sparse step (PR 9 tentpole)
    skc = sparse_kernel_comparison()
    smode = "interpret" if skc["interpret"] else "compiled"
    for name, per in skc["methods"].items():
        for k in (1, 16):
            rows.append((f"periter/{name}_sparse_dispatch_b{k}",
                         per[f"dispatch_b{k}_us"],
                         f"{smode};engine={per[f'engine_b{k}']};"
                         f"vs_unfused={per[f'dispatch_speedup_b{k}']:.2f}x;"
                         f"sparsity={skc['sparsity']:.0%}"))

    # fused in-step residual vs a separate ||AX-b|| pass at batch 16
    frc = fused_residual_comparison()
    for name, per in frc["methods"].items():
        rows.append((f"periter/{name}_fused_residual_b16",
                     per["fused_us"],
                     f"separate={per['separate_us']:.1f}us;"
                     f"speedup={per['fused_speedup']:.2f}x"))

    # sparse execution path vs its densified parity twin (the system-mode
    # refactor's perf claim: contracting over w support columns beats n)
    sc = sparse_comparison()
    for name, per in sc["methods"].items():
        rows.append((f"periter/{name}_sparse", per["sparse_us"],
                     f"dense={per['dense_us']:.1f}us;"
                     f"speedup={per['sparse_speedup']:.2f}x;"
                     f"sparsity={sc['sparsity']:.0%};w={sc['support_width']}"))

    if verbose:
        for r in rows:
            print(f"{r[0]:34s} {r[1]:10.1f} us   {r[2]}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
