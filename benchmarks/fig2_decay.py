"""Figure 2 reproduction: relative-error decay of every method.

Runs all solvers with their optimal parameters on the QC324 and ORSIRR 1
proxies and writes the error histories to CSV (benchmarks/out/fig2_*.csv)
plus an ASCII sketch — the offline stand-in for the paper's matplotlib
figure.  Asserts APC reaches the target error first.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro import solvers
from repro.data import linsys

OUT = os.path.join(os.path.dirname(__file__), "out")

RUNS = {
    "qc324": 4000,
    "orsirr1": 8000,
}

# registry names, ordered as in the paper's figure legend
METHODS = ["dgd", "dnag", "dhbm", "cimmino", "consensus", "apc", "pdhbm"]


def _solve_all(sys_, iters):
    return {solvers.get(name).paper_name: solvers.get(name).solve(
        sys_, iters=iters) for name in METHODS}


def _ascii_plot(hists, iters, width=70, height=16):
    lines = [[" "] * width for _ in range(height)]
    lo, hi = -12.0, 2.0
    for sym, (name, h) in zip("dnhbcAP", hists.items()):
        e = np.maximum(np.asarray(h.errors), 1e-15)
        for j in range(width):
            t = int(j / width * (len(e) - 1))
            y = np.log10(e[t])
            row = int((hi - y) / (hi - lo) * (height - 1))
            if 0 <= row < height:
                lines[row][j] = sym
    print("   log10 rel-error   "
          + " ".join(f"{s}={n}" for s, n in zip("dnhbcAP", hists)))
    for i, row in enumerate(lines):
        yl = hi - i * (hi - lo) / (height - 1)
        print(f"{yl:6.1f} |" + "".join(row))
    print("       +" + "-" * width + f"> iters (0..{iters})")


def run(verbose: bool = True, iters_scale: float = 1.0):
    jax.config.update("jax_enable_x64", True)
    os.makedirs(OUT, exist_ok=True)
    summary = []
    for prob, iters in RUNS.items():
        iters = max(100, int(iters * iters_scale))
        sys_ = linsys.ALL_PROBLEMS[prob]()
        t0 = time.time()
        hists = _solve_all(sys_, iters)
        dt = time.time() - t0
        path = os.path.join(OUT, f"fig2_{prob}.csv")
        e = {k: np.maximum(np.asarray(h.errors), 1e-16)
             for k, h in hists.items()}
        with open(path, "w") as f:
            f.write("iter," + ",".join(e) + "\n")
            for t in range(iters):
                f.write(f"{t}," + ",".join(f"{e[k][t]:.6e}" for k in e) + "\n")
        finals = {k: float(v[-1]) for k, v in e.items()}
        best = min(finals, key=finals.get)
        summary.append((prob, finals, dt))
        if verbose:
            print(f"\n=== {prob} (iters={iters}, {dt:.1f}s) "
                  f"final errors: " +
                  " ".join(f"{k}={v:.2e}" for k, v in finals.items()))
            _ascii_plot(hists, iters)
            print(f"   -> fastest: {best} (csv: {path})")
    return summary


def csv_rows():
    rows = []
    for prob, finals, dt in run(verbose=False, iters_scale=0.25):
        apc_err = finals["APC"]
        hbm_err = finals["D-HBM"]
        rows.append((f"fig2/{prob}", dt * 1e6,
                     f"apc_final={apc_err:.2e};dhbm_final={hbm_err:.2e}"))
    return rows


if __name__ == "__main__":
    run()
