"""Chaos benchmark: kill and add workers on a schedule while an
``ElasticRuntime`` keeps the solve converging.

The scenario drives the full PR 10 membership surface against one
oracle — the uninterrupted run on the original partition:

  * at iteration 100 a worker is KILLED (``mark_dead``): the runtime
    re-lowers the selection-weight schedule over the survivors and,
    by the redundant exactness invariant, loses ZERO iterations;
  * at iteration 150 a replacement JOINS: the fleet returns to its
    previous alive count, so the runtime reassigns holders without
    touching state or the compiled scan (still zero loss);
  * at iteration 200 a second join GROWS the fleet: the rows are
    repartitioned and the iterate is lifted into the new layout — the
    one step that may genuinely cost iterations (the lift restarts
    solver momentum), so ``iters_lost`` is the headline number.

Reported per scenario: iterations-to-tolerance vs the oracle
(``iters_lost``), the final relative error against the oracle solution,
and the engine jit-cache sizes after the last membership change vs at
the end of the run (``retrace_delta`` — the steady-state retrace gate:
membership changes may compile NEW engines, but once the fleet settles
every further segment re-enters cached scans).

    PYTHONPATH=src python benchmarks/chaos.py
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import solvers
from repro.data import linsys
from repro.runtime.fault import HeartbeatMonitor
from repro.solvers.capability import ExecutionPlan
from repro.solvers.store import FactorStore

ITERS = 400
SEGMENT = 25
TOL = 1e-8
KILL_AT, REPLACE_AT, GROW_AT = 50, 75, 100
KILL_WORKER = 3


def _to_tol(residuals: np.ndarray, tol: float):
    hit = np.nonzero(residuals <= tol)[0]
    return int(hit[0]) + 1 if hit.size else None


def chaos(n: int = 256, m: int = 8, iters: int = ITERS,
          segment: int = SEGMENT, tol: float = TOL):
    """Run the kill/replace/grow schedule; return the measured record."""
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=0)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=iters, tol=tol, plan=ExecutionPlan(),
                     **prm)
    oracle_res = np.asarray(oracle.residuals)

    mon = HeartbeatMonitor(n_workers=m)
    rt = solvers.ElasticRuntime(
        s, sys_, plan=ExecutionPlan(redundancy=2, store=FactorStore()),
        monitor=mon, segment=segment, tol=tol, **prm)

    marks = [(KILL_AT, lambda: mon.mark_dead(KILL_WORKER)),
             (REPLACE_AT, lambda: mon.join(resynced=True)),
             (GROW_AT, lambda: mon.join(resynced=True)),
             (iters, lambda: None)]
    res_parts, done, t_solve = [], 0, 0.0
    sizes_after_change = None
    for upto, act in marks:
        if upto > done:
            t0 = time.perf_counter()
            rep = rt.run(iters=upto - done)
            t_solve += time.perf_counter() - t0
            res_parts.append(np.asarray(rep.residuals))
            done = upto
        act()
        if sizes_after_change is None and done > GROW_AT:
            # first segment after the last membership change has run:
            # every engine is built — from here the caches must be flat
            sizes_after_change = dict(rt.engine_cache_sizes())
    residuals = np.concatenate(res_parts)
    sizes_end = dict(rt.engine_cache_sizes())

    chaos_tt, oracle_tt = _to_tol(residuals, tol), _to_tol(oracle_res, tol)
    lost = (None if chaos_tt is None or oracle_tt is None
            else chaos_tt - oracle_tt)
    x, xo = np.asarray(rep.x), np.asarray(oracle.x)
    return {
        "n": n, "m": m, "iters": iters, "segment": segment, "tol": tol,
        "schedule": {"kill_at": KILL_AT, "replace_at": REPLACE_AT,
                     "grow_at": GROW_AT},
        "events": [e.kind for e in rt.events],
        "fleet_final": int(rt.sys.m),
        "relowerings": rt.relowerings,
        "repartitions": rt.repartitions,
        "reused_blocks": rt.reused_blocks,
        "prepared_blocks": rt.prepared_blocks,
        "oracle_to_tol": oracle_tt,
        "chaos_to_tol": chaos_tt,
        "iters_lost": lost,
        "rel_err_vs_oracle": float(np.linalg.norm(x - xo)
                                   / np.linalg.norm(xo)),
        "final_residual": float(residuals[-1]),
        "us_per_iter": t_solve / iters * 1e6,
        "engine_cache_after_change": sizes_after_change,
        "engine_cache_end": sizes_end,
        "retrace_delta": sum(sizes_end.values())
        - sum(sizes_after_change.values()),
    }


def death_only(n: int = 256, m: int = 8, iters: int = ITERS,
               segment: int = SEGMENT, tol: float = TOL):
    """Kill one covered worker mid-run, nothing else: the exactness
    invariant says this loses ZERO iterations vs the oracle."""
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=0)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=iters, tol=tol, plan=ExecutionPlan(),
                     **prm)
    mon = HeartbeatMonitor(n_workers=m)
    rt = solvers.ElasticRuntime(
        s, sys_, plan=ExecutionPlan(redundancy=2), monitor=mon,
        segment=segment, tol=tol, **prm)
    t0 = time.perf_counter()
    r1 = rt.run(iters=KILL_AT)
    mon.mark_dead(KILL_WORKER)
    r2 = rt.run(iters=iters - KILL_AT)
    dt = time.perf_counter() - t0
    residuals = np.concatenate([np.asarray(r1.residuals),
                                np.asarray(r2.residuals)])
    oracle_res = np.asarray(oracle.residuals)
    return {
        "iters_lost": _to_tol(residuals, tol) - _to_tol(oracle_res, tol),
        "history_exact": bool(np.allclose(residuals, oracle_res,
                                          rtol=1e-6, atol=1e-12)),
        "us_per_iter": dt / iters * 1e6,
    }


def run(verbose: bool = True):
    rows = []
    d = death_only()
    rows.append(("chaos/apc/death_only", d["us_per_iter"],
                 f"iters_lost={d['iters_lost']};"
                 f"history_exact={d['history_exact']}"))
    c = chaos()
    rows.append((
        "chaos/apc/kill_replace_grow", c["us_per_iter"],
        f"iters_lost={c['iters_lost']};to_tol={c['chaos_to_tol']}"
        f"(oracle {c['oracle_to_tol']});fleet={c['m']}->"
        f"{c['fleet_final']};retrace_delta={c['retrace_delta']}"))
    if verbose:
        for row in rows:
            print(f"{row[0]:32s} {row[1]:10.1f} us/iter   {row[2]}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
