"""Straggler-tolerant redundant execution: per-iteration cost and
iterations-to-tolerance vs straggler rate and redundancy r.

Two claims are measured on the default problem:

  * Exactness (solvers/redundant.py invariant): iters-to-tol is INVARIANT
    to the straggler rate — dropping covered workers never slows
    convergence in iteration count.  The ``derived`` column carries
    ``to_tol`` per (r, rate) so the CSV shows it directly.
  * The jitted ``lax.scan`` over precomputed selection-weight masks is
    measurably faster per iteration than the legacy host loop that
    ``core/coding.py:solve_redundant`` used to run (selection weights
    rebuilt and a jitted step re-dispatched from Python every iteration,
    residual pulled to host each step) — ``straggler/legacy_loop_r2`` vs
    ``straggler/apc/r2/rate0.3``.

Timing follows benchmarks/mesh_scaling.py: the scan is built and jitted
ONCE per configuration and repeat executions of that same callable are
timed, so trace/compile and schedule-lowering costs drop out and the
number is pure per-iteration execution time; the legacy loop likewise
warms its jitted step in-call before its timed window.

    PYTHONPATH=src python benchmarks/straggler.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.data import linsys
from repro.solvers import redundant
from repro.solvers.store import FactorStore

ITERS = 200
REPS = 5
TOL = 1e-8
RATES = (0.0, 0.3, 1.0)
RS = (2, 3)


def _default_problem(n: int = 256, m: int = 8):
    return linsys.conditioned_gaussian(n=n, m=m, cond=20.0, seed=0)


def _schedule(m: int, rate: float, seed: int = 0):
    rng = np.random.default_rng(seed)

    def sched(t):
        a = np.ones(m, bool)
        if rng.random() < rate:
            a[rng.integers(0, m)] = False
        return a

    return sched


def _time_compiled(run, *args):
    """us/iteration of repeat executions of one already-built callable."""
    jax.block_until_ready(run(*args))            # compile + warm
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = run(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / (REPS * ITERS) * 1e6


def _redundant_setup(solver, sys_, r: int, store: FactorStore):
    """Replicated factors/b, initial state, and the step context."""
    prm = solver.resolve_params(sys_)
    assign = redundant.Assignment(m=sys_.m, r=r)
    frep = solver.red_factors(store.factors(solver, sys_, **prm), assign)
    _, b_rep = redundant.replicate_system(sys_, assign)
    dtype = sys_.A_blocks.dtype
    W_all = jnp.asarray(
        redundant.selection_weights(np.ones(sys_.m, bool), sys_.m, r), dtype)
    state0 = solver.red_init(frep, b_rep, prm, W_all, redundant._LOCAL)
    return prm, frep, b_rep, state0, dtype


def _compiled_plain(solver, sys_, store: FactorStore):
    prm = solver.resolve_params(sys_)
    factors = store.factors(solver, sys_, **prm)
    state0 = solver.init(factors, sys_.b_blocks, prm)
    A, b = sys_.A_blocks, sys_.b_blocks
    b_norm = jnp.sqrt(jnp.sum(b * b))

    @jax.jit
    def run(state):
        def body(st, _):
            st = solver.step(factors, b, st, prm)
            rr = jnp.einsum("mpn,n->mp", A, solver.extract(st)) - b
            return st, jnp.sqrt(jnp.sum(rr * rr)) / b_norm

        return jax.lax.scan(body, state, None, length=ITERS)

    return run, state0


def _compiled_redundant(solver, sys_, r: int, rate: float,
                        store: FactorStore):
    prm, frep, b_rep, state0, dtype = _redundant_setup(solver, sys_, r,
                                                       store)
    alive = redundant.resolve_schedule(_schedule(sys_.m, rate), sys_.m, ITERS)
    W_seq = jnp.asarray(redundant.schedule_weights(alive, r), dtype)
    A, b = sys_.A_blocks, sys_.b_blocks
    b_norm = jnp.sqrt(jnp.sum(b * b))

    @jax.jit
    def run(state, Ws):
        def body(st, Wt):
            st = solver.red_step(frep, b_rep, st, prm, Wt, redundant._LOCAL)
            rr = jnp.einsum("mpn,n->mp", A, solver.extract(st)) - b
            return st, jnp.sqrt(jnp.sum(rr * rr)) / b_norm

        return jax.lax.scan(body, state, Ws)

    return run, state0, W_seq


def _legacy_loop_per_iter(solver, sys_, r: int, rate: float,
                          store: FactorStore, warmup: int = 5):
    """The pre-scan reference driver: identical per-iteration math (the
    same jitted redundant step), but orchestrated the way the old
    ``core/coding.py`` host loop was — selection weights rebuilt in Python
    every iteration, the step re-dispatched per call, and the residual
    pulled to host each step.  The jitted step is warmed in-call so the
    timed window holds no compilation."""
    prm, frep, b_rep, state, dtype = _redundant_setup(solver, sys_, r, store)
    step = jax.jit(lambda st, W: solver.red_step(frep, b_rep, st, prm, W,
                                                 redundant._LOCAL))
    sched = _schedule(sys_.m, rate)
    A, b = sys_.A_blocks, sys_.b_blocks
    b_norm = float(jnp.sqrt(jnp.sum(b * b)))

    def one_iter(state, t):
        W = jnp.asarray(
            redundant.selection_weights(sched(t), sys_.m, r), dtype)
        state = step(state, W)
        rr = jnp.einsum("mpn,n->mp", A, solver.extract(state)) - b
        res = float(jnp.sqrt(jnp.sum(rr * rr))) / b_norm
        return state, res

    for t in range(warmup):
        state, _ = one_iter(state, t)
    t0 = time.perf_counter()
    for t in range(ITERS):
        state, _ = one_iter(state, t)
    return (time.perf_counter() - t0) / ITERS * 1e6


def run(verbose: bool = True, n: int = 256, m: int = 8):
    jax.config.update("jax_enable_x64", True)
    sys_ = _default_problem(n=n, m=m)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    # one content-addressed store: every configuration below shares the
    # SAME factorization (first call is the only miss)
    store = FactorStore()
    rows = []

    run_p, st0 = _compiled_plain(s, sys_, store)
    res0 = s.solve(sys_, iters=ITERS, tol=TOL,
                   plan=solvers.ExecutionPlan(store=store), **prm)
    rows.append(("straggler/apc/plain", _time_compiled(run_p, st0),
                 f"n={n};m={m};to_tol={res0.iters_to_tol}"))
    for r in RS:
        for rate in RATES:
            res = s.solve(sys_, iters=ITERS, tol=TOL,
                          plan=solvers.ExecutionPlan(
                              redundancy=r,
                              alive_schedule=_schedule(m, rate),
                              store=store),
                          **prm)
            # exactness: convergence never degrades.  Check the documented
            # contract (history match to 1e-6 relative) — the integer
            # iters_to_tol is reported in the CSV, not asserted, since a
            # crossing inside the fp noise band may legitimately shift it.
            assert np.allclose(np.asarray(res.residuals),
                               np.asarray(res0.residuals),
                               rtol=1e-6, atol=1e-12), (r, rate)
            run_r, st_r, W_seq = _compiled_redundant(s, sys_, r, rate, store)
            rows.append((f"straggler/apc/r{r}/rate{rate}",
                         _time_compiled(run_r, st_r, W_seq),
                         f"n={n};m={m};to_tol={res.iters_to_tol}"))

    # legacy host loop (what core/coding.py shipped before the scan)
    per_legacy = _legacy_loop_per_iter(s, sys_, 2, 0.3, store)
    scan_r2 = next(v for k, v, _ in rows if k == "straggler/apc/r2/rate0.3")
    rows.append(("straggler/legacy_loop_r2", per_legacy,
                 f"n={n};m={m};vs_scan_speedup="
                 f"{per_legacy / max(scan_r2, 1e-9):.1f}x"))

    if verbose:
        for row in rows:
            print(f"{row[0]:32s} {row[1]:10.1f} us/iter   {row[2]}")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
