"""Benchmark aggregator: one line of CSV per benchmark —
``name,us_per_call,derived``.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table2     # one suite
"""
from __future__ import annotations

import sys
import time

from benchmarks import batch_rhs, chaos, fig2_decay, mesh_scaling, \
    periter, roofline, serve_traffic, straggler, table1_rates, table2_times

SUITES = {
    "table1": table1_rates,
    "table2": table2_times,
    "fig2": fig2_decay,
    "periter": periter,
    "batch_rhs": batch_rhs,
    "mesh_scaling": mesh_scaling,
    "straggler": straggler,
    "serve_traffic": serve_traffic,
    "roofline": roofline,
    "chaos": chaos,
}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    names = argv if argv else list(SUITES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        mod = SUITES[name]
        try:
            for row in mod.csv_rows():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:  # repro: allow[R007] sweep reports per-suite errors and keeps going; no futures here
            print(f"{name}/ERROR,0,{e!r}")
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
