"""Table 1 reproduction: optimal convergence rates per method.

For every benchmark problem, print the closed-form optimal rate rho of each
registered solver (kappa(A^T A) for the gradient family, kappa(X) /
mu_min(X) for the projection family) — the exact quantities of paper
Table 1 — plus the derived convergence time T = 1/(-log rho).  Rates come
from ONE ``spectral.rates_summary`` pass per problem, keyed through the
registry's ``paper_name``s (``Solver.theoretical_rate`` returns the same
closed forms; tests/test_solvers_registry.py pins the two in sync).
"""
from __future__ import annotations

import time

import jax

from repro import solvers
from repro.core import spectral
from repro.data import linsys

PROBLEMS = ["qc324", "orsirr1", "ash608", "std_gaussian", "nonzero_mean",
            "tall_gaussian"]
# registry order follows the paper's table (M-ADMM has no closed-form rho)
METHODS = ["dgd", "dnag", "dhbm", "consensus", "cimmino", "apc"]


def run(verbose: bool = True):
    jax.config.update("jax_enable_x64", True)
    rows = []
    for prob in PROBLEMS:
        t0 = time.time()
        sys_ = linsys.ALL_PROBLEMS[prob]()
        # one spectral analysis per problem (rates_summary keys are the
        # registry's paper_name display names)
        summary = spectral.rates_summary(sys_)
        s = {name: summary[solvers.get(name).paper_name] for name in METHODS}
        s["kappa_X"] = summary["kappa_X"]
        s["kappa_AtA"] = summary["kappa_AtA"]
        dt_us = (time.time() - t0) * 1e6
        rows.append((prob, s, dt_us))
        if verbose:
            rates = "  ".join(
                f"{solvers.get(m).paper_name}={s[m]:.6f}" for m in METHODS)
            print(f"{prob:14s} kX={s['kappa_X']:.3e} "
                  f"kAtA={s['kappa_AtA']:.3e}  {rates}")
    return rows


def csv_rows():
    out = []
    for prob, s, dt_us in run(verbose=False):
        t_apc = spectral.convergence_time(s["apc"])
        out.append((f"table1/{prob}", dt_us,
                    f"rho_APC={s['apc']:.6f};T_APC={t_apc:.3g}"))
    return out


if __name__ == "__main__":
    run()
