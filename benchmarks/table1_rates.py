"""Table 1 reproduction: optimal convergence rates per method.

For every benchmark problem, print the closed-form optimal rate rho of each
method from the spectra (kappa(A^T A) for the gradient family, kappa(X) /
mu_min(X) for the projection family) — the exact quantities of paper
Table 1 — plus the derived convergence time T = 1/(-log rho).
"""
from __future__ import annotations

import time

import jax

from repro.core import spectral
from repro.data import linsys

PROBLEMS = ["qc324", "orsirr1", "ash608", "std_gaussian", "nonzero_mean",
            "tall_gaussian"]
METHODS = ["DGD", "D-NAG", "D-HBM", "Consensus", "B-Cimmino", "APC"]


def run(verbose: bool = True):
    jax.config.update("jax_enable_x64", True)
    rows = []
    for prob in PROBLEMS:
        t0 = time.time()
        sys_ = linsys.ALL_PROBLEMS[prob]()
        s = spectral.rates_summary(sys_)
        dt_us = (time.time() - t0) * 1e6
        rows.append((prob, s, dt_us))
        if verbose:
            rates = "  ".join(f"{m}={s[m]:.6f}" for m in METHODS)
            print(f"{prob:14s} kX={s['kappa_X']:.3e} "
                  f"kAtA={s['kappa_AtA']:.3e}  {rates}")
    return rows


def csv_rows():
    out = []
    for prob, s, dt_us in run(verbose=False):
        t_apc = spectral.convergence_time(s["APC"])
        out.append((f"table1/{prob}", dt_us,
                    f"rho_APC={s['APC']:.6f};T_APC={t_apc:.3g}"))
    return out


if __name__ == "__main__":
    run()
