"""Regenerate the §Dry-run/§Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts (baseline + optimized).

    PYTHONPATH=src python -m benchmarks.make_experiments_tables
prints the markdown blocks to paste/refresh.
"""
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(name):
    with open(os.path.join(REPO, name)) as f:
        return {(r["arch"], r["shape"], r["mesh"]): r for r in json.load(f)}


def fmt(r):
    if r is None or r["status"] == "FAILED":
        return None
    if r["status"] == "skipped":
        return "skip"
    f = r["roofline"]
    return f


def table(base, opt, mesh):
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful | roofline-frac | vs baseline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(opt):
        a, s, m = key
        if m != mesh:
            continue
        r = opt[key]
        b = base.get(key)
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | — | — | — | skipped "
                         f"(sub-quadratic-only shape) | — | — | — |")
            continue
        f = r["roofline"]
        gain = ""
        if b is not None and b.get("status") == "ok":
            fb = b["roofline"]
            t0 = max(fb["t_compute"], fb["t_memory"], fb["t_collective"])
            t1 = max(f["t_compute"], f["t_memory"], f["t_collective"])
            gain = f"{t0 / t1:.2f}x"
        lines.append(
            f"| {a} | {s} | {f['t_compute']:.2e} | {f['t_memory']:.2e} | "
            f"{f['t_collective']:.2e} | {f['bottleneck']} | "
            f"{f['useful_ratio']:.2f} | {100*f['roofline_fraction']:.2f}% | "
            f"{gain} |")
    return "\n".join(lines)


def memtable(opt, mesh):
    lines = ["| arch | shape | arg bytes/dev | temp bytes/dev | compile s |",
             "|---|---|---|---|---|"]
    for key in sorted(opt):
        a, s, m = key
        r = opt[key]
        if m != mesh or r["status"] != "ok":
            continue
        mem = r.get("memory") or {}
        arg = mem.get("argument_bytes")
        tmp = mem.get("temp_bytes")
        ab = f"{arg/2**30:.2f} GiB" if arg else "n/a"
        tb = f"{tmp/2**30:.2f} GiB" if tmp else "n/a"
        lines.append(f"| {a} | {s} | {ab} | {tb} | {r.get('compile_s')} |")
    return "\n".join(lines)


def main():
    base = load("dryrun_baseline.json")
    opt = load("dryrun_optimized.json")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Roofline — mesh {mesh} (optimized; last column = "
              f"dominant-term speedup vs paper-faithful baseline)\n")
        print(table(base, opt, mesh))
    print("\n### Per-device memory (single-pod, optimized)\n")
    print(memtable(opt, "16x16"))


if __name__ == "__main__":
    main()
