"""Live roofline for the Pallas kernel engine (§Roofline).

For each kernel cell (dense/sparse x apc/cimmino at a representative
shape) this builds the analytic bytes-vs-FLOPs model from the *actual*
tile schedule ``ops.pick_tiles`` resolves, measures the machine's
streaming bandwidth and matmul peak as ceilings, times the real fused
pair, and reports arithmetic intensity, the predicted bottleneck, and
roofline attainment (predicted-best time / measured time).

No artifact is required: the table is computed live by default.  The
old dry-run replay (``dryrun_baseline.json`` from ``repro.launch.dryrun``)
is still available behind ``--from-json`` for the per-(arch x mesh)
three-term analysis, but nothing in ``benchmarks.run`` depends on it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = os.path.join(REPO, "dryrun_baseline.json")


# ---------------------------------------------------------------------------
# measured ceilings
# ---------------------------------------------------------------------------


def _best_of(fn, n=5):
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def measured_bandwidth_bps() -> float:
    """Achievable streaming bandwidth: time a jitted y = x + 1 copy."""
    x = jnp.zeros((8 * 1024 * 1024,), jnp.float32)  # 32 MiB
    f = jax.jit(lambda a: a + 1.0)  # repro: allow[R001] one-shot ceiling probe: built once, timed, discarded
    f(x).block_until_ready()
    t = _best_of(lambda: f(x).block_until_ready())
    return 2 * x.nbytes / t  # one read + one write


def measured_flops_ps() -> float:
    """Achievable f32 compute: time a jitted square matmul."""
    n = 768
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda u, v: u @ v)  # repro: allow[R001] one-shot ceiling probe: built once, timed, discarded
    f(a, a).block_until_ready()
    t = _best_of(lambda: f(a, a).block_until_ready(), n=3)
    return 2.0 * n ** 3 / t


# ---------------------------------------------------------------------------
# analytic bytes/FLOPs per fused pair, from the resolved tile schedule
# ---------------------------------------------------------------------------


def _pad(v, m):
    return int(-(-v // m) * m)


def pair_model(family: str, m: int, p: int, n: int, k: int,
               tiles, itemsize_ab: int = 4, itemsize_x: int = 4,
               w: int | None = None):
    """(flops, bytes) for one gather+scatter pair across ``m`` workers.

    ``w`` switches to the compressed-support traffic (the sparse pair
    contracts over w_pad instead of n_pad, plus the XLA gather/scatter
    glue on the full-width state).  Byte counts follow the 3D grid
    schedule: an (A|B) tile is resident once per k-tile sweep, the
    state tiles are re-read once per opposing sublane tile.
    """
    bn, bp, bk = tiles
    lane = _pad(n if w is None else w, 128)
    p_pad, k_pad = _pad(p, 8), _pad(k, 8)
    bn = min(bn, lane)
    k_sweeps = -(-k_pad // bk)
    cim = family.startswith("cimmino")
    # contraction: gather (k,p,lane) + scatter (k,lane,p), 2 flops/MAC
    flops = m * 4.0 * k_pad * p_pad * lane
    a_bytes = p_pad * lane * itemsize_ab * k_sweeps       # A tiles
    b_bytes = lane * p_pad * itemsize_ab * k_sweeps       # B (pinv) tiles
    nstate = 1 if cim else 2                              # xbar vs (x, xbar)
    g_state = nstate * k_pad * lane * (p_pad // bp) * itemsize_x
    s_state = (0 if cim else nstate * k_pad * lane * itemsize_x)
    u_bytes = k_pad * p_pad * (1 + lane // bn) * itemsize_x
    y_bytes = k_pad * lane * itemsize_x
    bytes_ = m * (a_bytes + b_bytes + g_state + s_state + u_bytes + y_bytes)
    if w is not None:  # XLA glue: gather x[:, cols] in, scatter-add out
        glue = m * k_pad * _pad(w, 128) * 2 * itemsize_x + 2 * k * n * itemsize_x
        bytes_ += glue
    return flops, bytes_


# ---------------------------------------------------------------------------
# cells: build real operands, time the real jitted pair
# ---------------------------------------------------------------------------


def _dense_cell(family: str, p: int, n: int, k: int, rng):
    from repro.kernels import ops
    A = jnp.asarray(rng.standard_normal((p, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    Xb = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    if family == "apc":
        def call():
            u = ops.proj_gather(A, X, Xb)
            return ops.proj_scatter(B, X, Xb, u, 0.9).block_until_ready()
    else:
        bsh = jnp.asarray(rng.standard_normal((k, p)), jnp.float32)

        def call():
            u = ops.cimmino_gather(A, Xb)
            return ops.cimmino_scatter(B, bsh - u).block_until_ready()
    return call, dict(m=1, p=p, n=n, k=k, w=None)


def _sparse_cell(family: str, k: int, rng):
    from repro import solvers
    from repro.data import linsys
    from repro.kernels import ops
    from repro.solvers.store import FactorStore
    sys_ = linsys.banded_system(n=512, m=4, bandwidth=8, seed=0,
                                dtype=jnp.float32)
    s = solvers.get(family)
    f = FactorStore().factors(s, sys_, use_kernel=True,
                              **s.resolve_params(sys_))
    m, p, w = f.A.vals.shape
    X = jnp.asarray(rng.standard_normal((k, sys_.N)), jnp.float32)
    Xb = jnp.asarray(rng.standard_normal((k, sys_.N)), jnp.float32)
    if family == "apc":
        def call():
            outs = [ops.sparse_proj_update(f.A.vals[i], f.A.cols[i],
                                           f.B[i], X, Xb, 0.9)[0]
                    for i in range(m)]
            return outs[-1].block_until_ready()
    else:
        bsh = jnp.asarray(rng.standard_normal((m, k, p)), jnp.float32)

        def call():
            outs = [ops.sparse_cimmino_update(f.A.vals[i], f.A.cols[i],
                                              f.B[i], bsh[i], Xb)[0]
                    for i in range(m)]
            return outs[-1].block_until_ready()
    return call, dict(m=m, p=p, n=sys_.N, k=k, w=w)


CELLS = [
    ("dense/apc", "apc", False, dict(p=64, n=1024, k=16)),
    ("dense/cimmino", "cimmino", False, dict(p=64, n=1024, k=16)),
    ("sparse/apc", "apc", True, dict(k=16)),
    ("sparse/cimmino", "cimmino", True, dict(k=16)),
]


def live_cells(verbose: bool = True, out=sys.stdout):
    from repro.kernels import block_projection as bp_mod
    from repro.kernels import ops
    interp = bp_mod.default_interpret()
    bw = measured_bandwidth_bps()
    peak = measured_flops_ps()
    rng = np.random.default_rng(0)
    rows = []
    for name, family, sparse, shp in CELLS:
        call, dims = (_sparse_cell(family, shp["k"], rng) if sparse
                      else _dense_cell(family, rng=rng, **shp))
        lane_src = dims["w"] if sparse else dims["n"]
        tiles = ops.pick_tiles(_pad(lane_src, 128), _pad(dims["p"], 8),
                               _pad(dims["k"], 8), jnp.float32,
                               interpret=interp)
        flops, bytes_ = pair_model(family, dims["m"], dims["p"], dims["n"],
                                   dims["k"], tiles, w=dims["w"])
        call()  # compile/warm
        t_meas = _best_of(call, n=3)
        t_mem, t_comp = bytes_ / bw, flops / peak
        t_roof = max(t_mem, t_comp)
        rows.append(dict(
            name=name, shape=f"m{dims['m']}p{dims['p']}n{dims['n']}"
            + (f"w{dims['w']}" if dims["w"] else "") + f"k{dims['k']}",
            tiles=list(tiles), flops=flops, bytes=bytes_,
            intensity=flops / bytes_,
            bound="memory" if t_mem >= t_comp else "compute",
            t_mem=t_mem, t_comp=t_comp, t_meas=t_meas,
            attainment=t_roof / t_meas, interpret=interp))
    if verbose:
        print(f"ceilings: {bw/1e9:.1f} GB/s stream, {peak/1e9:.1f} GFLOP/s "
              f"(interpret={interp})", file=out)
        hdr = (f"{'cell':16s} {'shape':20s} {'tiles':>14s} {'AI':>6s} "
               f"{'bound':>7s} {'t_roof':>9s} {'t_meas':>9s} {'attain':>7s}")
        print(hdr, file=out)
        print("-" * len(hdr), file=out)
        for r in rows:
            print(f"{r['name']:16s} {r['shape']:20s} "
                  f"{str(tuple(r['tiles'])):>14s} {r['intensity']:6.1f} "
                  f"{r['bound']:>7s} {max(r['t_mem'], r['t_comp']):9.2e} "
                  f"{r['t_meas']:9.2e} {r['attainment']:7.3f}", file=out)
    return rows


# ---------------------------------------------------------------------------
# optional replay of the dry-run artifact (legacy three-term analysis)
# ---------------------------------------------------------------------------


def load(path: str = DEFAULT_JSON):
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} missing — run: PYTHONPATH=src python -m "
            "repro.launch.dryrun --both-meshes --json dryrun_baseline.json")
    with open(path) as f:
        return json.load(f)


def render(records, mesh: str = "16x16", out=sys.stdout):
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'bound':>7s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr, file=out)
    print("-" * len(hdr), file=out)
    for r in records:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"{'— skipped (' + r['reason'][:40] + '...)'}", file=out)
            continue
        if r["status"] != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} FAILED", file=out)
            continue
        f = r["roofline"]
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{f['t_compute']:9.2e} {f['t_memory']:9.2e} "
              f"{f['t_collective']:9.2e} {f['bottleneck'][:7]:>7s} "
              f"{f['useful_ratio']:7.3f} "
              f"{100*f['roofline_fraction']:6.2f}%", file=out)


def replay(path: str, out=sys.stdout):
    recs = load(path)
    for mesh in ("16x16", "2x16x16"):
        if not any(r.get("mesh") == mesh for r in recs):
            continue
        print(f"\n=== mesh {mesh} (replay of {os.path.basename(path)}) ===",
              file=out)
        render(recs, mesh, out=out)
    return recs


def run(verbose: bool = True):
    return live_cells(verbose=verbose)


def csv_rows():
    rows = []
    for r in live_cells(verbose=False):
        rows.append((f"roofline/{r['name']}", r["t_meas"] * 1e6,
                     f"bound={r['bound']};ai={r['intensity']:.1f};"
                     f"attain={r['attainment']:.3f}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--from-json", metavar="PATH", default=None,
                    help="replay a repro.launch.dryrun artifact instead "
                         "of the live kernel roofline")
    args = ap.parse_args(argv)
    if args.from_json:
        replay(args.from_json)
    else:
        run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
