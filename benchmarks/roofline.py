"""Roofline table (§Roofline): reads the dry-run artifact and renders the
per-(arch × shape × mesh) three-term analysis.

The compile pass itself is ``python -m repro.launch.dryrun --both-meshes
--json dryrun_baseline.json`` (30-60 min on this container); this benchmark
consumes its JSON so `benchmarks.run` stays fast.  ``--refresh-one`` runs a
single live cell through a subprocess as a freshness check.
"""
from __future__ import annotations

import json
import os
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = os.path.join(REPO, "dryrun_baseline.json")


def load(path: str = DEFAULT_JSON):
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"{path} missing — run: PYTHONPATH=src python -m "
            "repro.launch.dryrun --both-meshes --json dryrun_baseline.json")
    with open(path) as f:
        return json.load(f)


def render(records, mesh: str = "16x16", out=sys.stdout):
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'bound':>7s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr, file=out)
    print("-" * len(hdr), file=out)
    for r in records:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"{'— skipped (' + r['reason'][:40] + '...)'}", file=out)
            continue
        if r["status"] != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} FAILED", file=out)
            continue
        f = r["roofline"]
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{f['t_compute']:9.2e} {f['t_memory']:9.2e} "
              f"{f['t_collective']:9.2e} {f['bottleneck'][:7]:>7s} "
              f"{f['useful_ratio']:7.3f} "
              f"{100*f['roofline_fraction']:6.2f}%", file=out)


def markdown(records, mesh: str = "16x16"):
    lines = ["| arch | shape | t_compute (s) | t_memory (s) | "
             "t_collective (s) | bottleneck | useful | roofline-frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        f = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {f['t_compute']:.2e} | "
            f"{f['t_memory']:.2e} | {f['t_collective']:.2e} | "
            f"{f['bottleneck']} | {f['useful_ratio']:.3f} | "
            f"{100*f['roofline_fraction']:.2f}% |")
    return "\n".join(lines)


def run(verbose: bool = True, path: str = DEFAULT_JSON):
    recs = load(path)
    if verbose:
        for mesh in ("16x16", "2x16x16"):
            n = sum(1 for r in recs if r.get("mesh") == mesh)
            if not n:
                continue
            print(f"\n=== mesh {mesh} ===")
            render(recs, mesh)
    return recs


def csv_rows():
    t0 = time.time()
    try:
        recs = run(verbose=False)
    except FileNotFoundError:
        return [("roofline/all", 0.0, "missing-dryrun-json")]
    ok = sum(r["status"] == "ok" for r in recs)
    worst = None
    for r in recs:
        if r["status"] == "ok":
            rf = r["roofline"]["roofline_fraction"]
            if worst is None or rf < worst[1]:
                worst = (f"{r['arch']}/{r['shape']}", rf)
    return [("roofline/all", (time.time() - t0) * 1e6,
             f"cells_ok={ok};worst={worst[0]}:{100*worst[1]:.2f}%")]


if __name__ == "__main__":
    run()
