"""Table 2 reproduction: optimal convergence times T = 1/(-log rho).

Prints our measured T per (problem × method) next to the paper's published
values, with rho coming from one ``spectral.rates_summary`` pass per
problem keyed through the registry's ``paper_name``s (kept in sync with
``Solver.theoretical_rate`` by the registry tests).  The Matrix Market
problems are spectrum-matched
proxies (offline container — data/linsys.py), so OUR absolute numbers
differ from the paper's; the claims under test are (1) APC wins everywhere,
(2) often by orders of magnitude, (3) D-HBM is the closest competitor, and
(4) the gap explodes for nonzero-mean ensembles.  Those are asserted at the
bottom.
"""
from __future__ import annotations

import time

import jax

from repro import solvers
from repro.core import spectral
from repro.data import linsys

# Paper Table 2 (for the side-by-side print), keyed by registry name.
PAPER = {
    "qc324": {"dgd": 1.22e7, "dnag": 4.28e3, "dhbm": 2.47e3,
              "madmm": 1.07e7, "cimmino": 3.10e5, "apc": 3.93e2},
    "orsirr1": {"dgd": 2.98e9, "dnag": 6.68e4, "dhbm": 3.86e4,
                "madmm": 2.08e8, "cimmino": 2.69e7, "apc": 3.67e3},
    "ash608": {"dgd": 5.67, "dnag": 2.43, "dhbm": 1.64,
               "madmm": 1.28e1, "cimmino": 4.98, "apc": 1.53},
    "std_gaussian": {"dgd": 1.76e7, "dnag": 5.14e3, "dhbm": 2.97e3,
                     "madmm": 1.20e6, "cimmino": 1.46e7, "apc": 2.70e3},
    "nonzero_mean": {"dgd": 2.22e10, "dnag": 1.82e5, "dhbm": 1.05e5,
                     "madmm": 8.62e8, "cimmino": 9.29e8, "apc": 2.16e4},
    "tall_gaussian": {"dgd": 1.58e1, "dnag": 4.37, "dhbm": 2.78,
                      "madmm": 4.49e1, "cimmino": 1.13e1, "apc": 2.34},
}

# methods with a closed-form rho (M-ADMM has none; paper derives it
# numerically, so it is print-only above)
METHODS = ["dgd", "dnag", "dhbm", "cimmino", "apc"]


def run(verbose: bool = True):
    jax.config.update("jax_enable_x64", True)
    results = {}
    for prob in PAPER:
        sys_ = linsys.ALL_PROBLEMS[prob]()
        # one spectral analysis per problem; rates_summary keys are the
        # registry's paper_name display names
        summary = spectral.rates_summary(sys_)
        T = {m: spectral.convergence_time(
            summary[solvers.get(m).paper_name]) for m in METHODS}
        results[prob] = T
        if verbose:
            print(f"\n{prob}  (N={sys_.N}, n={sys_.n}, m={sys_.m})")
            print(f"  {'method':10s} {'T ours':>12s} {'T paper':>12s}")
            for m in METHODS:
                print(f"  {solvers.get(m).paper_name:10s} "
                      f"{T[m]:12.3e} {PAPER[prob][m]:12.3e}")

    # ---- the paper's comparative claims, checked on our instances --------
    claims = []
    for prob, T in results.items():
        others = [T[m] for m in METHODS if m != "apc"]
        claims.append(("APC fastest: " + prob, T["apc"] <= min(others) * 1.1))
        # "the closest competitor is D-HBM" — meaningful only where methods
        # actually separate (on ~condition-1 problems like ASH608 everything
        # converges in a handful of iterations, paper Table 2 row 3).
        if min(others) > 3.0 * T["apc"]:
            closest = min((m for m in METHODS if m != "apc"),
                          key=lambda m: T[m])
            claims.append((f"D-HBM closest competitor: {prob}",
                           closest == "dhbm"))
    g_std = results["std_gaussian"]["dhbm"] / results["std_gaussian"]["apc"]
    g_nzm = results["nonzero_mean"]["dhbm"] / results["nonzero_mean"]["apc"]
    claims.append(("nonzero-mean gap larger than standard", g_nzm > g_std))
    claims.append(("DGD orders of magnitude slower on qc324",
                   results["qc324"]["dgd"] / results["qc324"]["apc"] > 1e2))
    if verbose:
        print("\npaper-claim validation:")
        for name, ok in claims:
            print(f"  [{'PASS' if ok else 'FAIL'}] {name}")
    return results, claims


def csv_rows():
    t0 = time.time()
    results, claims = run(verbose=False)
    dt_us = (time.time() - t0) * 1e6 / max(len(results), 1)
    ok = sum(1 for _, c in claims if c)
    return [("table2/all", dt_us, f"claims_pass={ok}/{len(claims)}")]


if __name__ == "__main__":
    _, claims = run()
    failed = [n for n, ok in claims if not ok]
    raise SystemExit(1 if failed else 0)
