"""Table 2 reproduction: optimal convergence times T = 1/(-log rho).

Prints our measured T per (problem × method) next to the paper's published
values.  The Matrix Market problems are spectrum-matched proxies (offline
container — data/linsys.py), so OUR absolute numbers differ from the
paper's; the claims under test are (1) APC wins everywhere, (2) often by
orders of magnitude, (3) D-HBM is the closest competitor, and (4) the gap
explodes for nonzero-mean ensembles.  Those are asserted at the bottom.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import spectral
from repro.data import linsys

# Paper Table 2 (for the side-by-side print).
PAPER = {
    "qc324": {"DGD": 1.22e7, "D-NAG": 4.28e3, "D-HBM": 2.47e3,
              "M-ADMM": 1.07e7, "B-Cimmino": 3.10e5, "APC": 3.93e2},
    "orsirr1": {"DGD": 2.98e9, "D-NAG": 6.68e4, "D-HBM": 3.86e4,
                "M-ADMM": 2.08e8, "B-Cimmino": 2.69e7, "APC": 3.67e3},
    "ash608": {"DGD": 5.67, "D-NAG": 2.43, "D-HBM": 1.64,
               "M-ADMM": 1.28e1, "B-Cimmino": 4.98, "APC": 1.53},
    "std_gaussian": {"DGD": 1.76e7, "D-NAG": 5.14e3, "D-HBM": 2.97e3,
                     "M-ADMM": 1.20e6, "B-Cimmino": 1.46e7, "APC": 2.70e3},
    "nonzero_mean": {"DGD": 2.22e10, "D-NAG": 1.82e5, "D-HBM": 1.05e5,
                     "M-ADMM": 8.62e8, "B-Cimmino": 9.29e8, "APC": 2.16e4},
    "tall_gaussian": {"DGD": 1.58e1, "D-NAG": 4.37, "D-HBM": 2.78,
                      "M-ADMM": 4.49e1, "B-Cimmino": 1.13e1, "APC": 2.34},
}

METHODS = ["DGD", "D-NAG", "D-HBM", "B-Cimmino", "APC"]


def run(verbose: bool = True):
    jax.config.update("jax_enable_x64", True)
    results = {}
    for prob in PAPER:
        sys_ = linsys.ALL_PROBLEMS[prob]()
        s = spectral.rates_summary(sys_)
        T = {m: spectral.convergence_time(s[m]) for m in METHODS}
        results[prob] = T
        if verbose:
            print(f"\n{prob}  (N={sys_.N}, n={sys_.n}, m={sys_.m})")
            print(f"  {'method':10s} {'T ours':>12s} {'T paper':>12s}")
            for m in METHODS:
                print(f"  {m:10s} {T[m]:12.3e} {PAPER[prob][m]:12.3e}")

    # ---- the paper's comparative claims, checked on our instances --------
    claims = []
    for prob, T in results.items():
        others = [T[m] for m in METHODS if m != "APC"]
        claims.append(("APC fastest: " + prob, T["APC"] <= min(others) * 1.1))
        # "the closest competitor is D-HBM" — meaningful only where methods
        # actually separate (on ~condition-1 problems like ASH608 everything
        # converges in a handful of iterations, paper Table 2 row 3).
        if min(others) > 3.0 * T["APC"]:
            closest = min((m for m in METHODS if m != "APC"),
                          key=lambda m: T[m])
            claims.append((f"D-HBM closest competitor: {prob}",
                           closest == "D-HBM"))
    g_std = results["std_gaussian"]["D-HBM"] / results["std_gaussian"]["APC"]
    g_nzm = results["nonzero_mean"]["D-HBM"] / results["nonzero_mean"]["APC"]
    claims.append(("nonzero-mean gap larger than standard", g_nzm > g_std))
    claims.append(("DGD orders of magnitude slower on qc324",
                   results["qc324"]["DGD"] / results["qc324"]["APC"] > 1e2))
    if verbose:
        print("\npaper-claim validation:")
        for name, ok in claims:
            print(f"  [{'PASS' if ok else 'FAIL'}] {name}")
    return results, claims


def csv_rows():
    t0 = time.time()
    results, claims = run(verbose=False)
    dt_us = (time.time() - t0) * 1e6 / max(len(results), 1)
    ok = sum(1 for _, c in claims if c)
    return [("table2/all", dt_us, f"claims_pass={ok}/{len(claims)}")]


if __name__ == "__main__":
    _, claims = run()
    failed = [n for n, ok in claims if not ok]
    raise SystemExit(1 if failed else 0)
