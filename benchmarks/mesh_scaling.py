"""Mesh-backend scaling: per-iteration wall time vs worker shard count.

Runs the shard_map execution backend (``repro.solvers.mesh``) for a fixed
problem while the 'data' mesh axis grows through the divisors of m that fit
the device count.  Timing uses ``mesh_backend.compile_solve``: the jitted
scan is built ONCE per (solver, shard count) and repeat executions of that
same callable are timed, so trace/compile/placement costs drop out and the
reported number is pure per-iteration execution time.  On one CPU device
this only exercises the d=1 point; force a fleet with

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python benchmarks/mesh_scaling.py

(the __main__ entry sets that default itself).  On real hardware the psum
cost per iteration is m*p floats (worker axis) + n floats (model axis) vs
2pn matvec FLOPs — arithmetic intensity grows with n/m, so the curve should
flatten toward ideal scaling as n grows.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # force a multi-device host before jax wakes up
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")

import time

import jax

from repro import solvers
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers import mesh as mesh_backend

METHODS = ("apc", "dgd", "madmm")
ITERS = 100
REPS = 5


def _shard_counts(m: int):
    n_dev = len(jax.devices())
    return [d for d in range(1, m + 1) if m % d == 0 and d <= n_dev]


def run(verbose: bool = True, n: int = 256, m: int = 4):
    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=30.0, seed=0)
    n_dev = len(jax.devices())
    rows = []
    for name in METHODS:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        for d in _shard_counts(m):
            mesh = mesh_lib.solver_mesh(d, 1)
            cs = mesh_backend.compile_solve(s, sys_, mesh=mesh, iters=ITERS,
                                            **prm)
            jax.block_until_ready(cs.run(*cs.args))   # compile + warm
            t0 = time.perf_counter()
            for _ in range(REPS):
                out = cs.run(*cs.args)
            jax.block_until_ready(out)
            per_iter = (time.perf_counter() - t0) / (REPS * ITERS) * 1e6
            rows.append((f"mesh_scaling/{name}/shards{d}", per_iter,
                         f"n={n};m={m};devices={n_dev}"))
            if verbose:
                print(f"{name:8s} data={d}  {per_iter:9.1f} us/iter "
                      f"({n_dev} devices)")
    return rows


def csv_rows():
    return run(verbose=False)


if __name__ == "__main__":
    run()
