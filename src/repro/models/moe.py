"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch strategy (TPU-native, static shapes): instead of GShard's one-hot
dispatch einsum (O(T·E·C) memory — intractable at 1M tokens), tokens are
*sorted by expert id* and scattered into a capacity-padded (E, C, D) buffer.
Expert FFNs then run as one grouped einsum ``ecd,edf->ecf`` with the expert
axis sharded over the ``tensor`` mesh axis (expert parallelism); GSPMD
inserts the all-to-alls at the dispatch/combine boundaries.  Overflowing
tokens beyond capacity are dropped (standard capacity-factor semantics);
their residual path still carries them.

FLOP cost is the true MoE cost: E·C·D·F with E·C ≈ T·top_k·cf — not the
dense all-experts product.  This matters for the §Roofline useful-FLOPs
accounting.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .sharding import ParamSpec
from . import layers


def moe_abstract(cfg: ModelConfig):
    mo = cfg.moe
    D, F, E = cfg.d_model, mo.d_expert, mo.num_experts
    p = {
        "router": ParamSpec((D, E), ("fsdp", None)),
        "w_gate": ParamSpec((E, D, F), ("tensor", "fsdp", None)),
        "w_up": ParamSpec((E, D, F), ("tensor", "fsdp", None)),
        "w_down": ParamSpec((E, F, D), ("tensor", None, "fsdp")),
    }
    if mo.n_shared:
        p["shared"] = layers.swiglu_abstract(D, F * mo.n_shared)
    return p


def _capacity(tokens: int, mo: MoEConfig) -> int:
    c = int(tokens * mo.top_k * mo.capacity_factor / mo.num_experts)
    return max(8, (c + 7) // 8 * 8)   # sublane-aligned


def moe_apply(cfg: ModelConfig, p, x, rules=None):
    """x (B, S, D) -> (B, S, D).  Capacity-dropping top-k MoE.

    Two execution paths with identical semantics (up to which overflow
    tokens drop — capacity is per-shard in the sharded path, as in every
    production EP system):

      * global (default / smoke tests): pure-jnp gathers over the full
        token axis.
      * shard_map (used when ``rules.mesh`` is known): per-data-shard
        routing + expert-parallel FFN over the tensor axis, with ONE psum
        as the only cross-shard communication.  GSPMD-auto cannot localize
        a global argsort/gather (§Perf iteration 3) — this path removes
        the giant all-reduces it generates.
    """
    out = None
    # shard_map pays an FSDP weight-regather at its boundary — amortized
    # over train/prefill token counts, but a regression for single-token
    # decode (measured 10x on jamba decode_32k): decode keeps the global
    # path, whose gathers are tiny at T = batch.
    if (rules is not None and rules.mesh is not None and rules.tensor
            and x.shape[1] > 1):
        out = _moe_shard_map(cfg, p, x, rules)
    if out is None:
        out = _moe_global(cfg, p, x)
    if cfg.moe.n_shared:
        B, S, D = x.shape
        out = out + layers.swiglu_apply(p["shared"], x.reshape(B * S, D)) \
            .reshape(B, S, D)
    return out


def _moe_global(cfg: ModelConfig, p, x):
    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K, F = mo.num_experts, mo.top_k, mo.d_expert
    C = _capacity(T, mo)

    xf = x.reshape(T, D)
    logits = (xf @ p["router"]).astype(jnp.float32)            # (T, E)
    gates, eids = jax.lax.top_k(jax.nn.softmax(logits, -1), K)  # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch (gather-only: no scatter ops) ---------------
    # GSPMD cannot reshard scatters efficiently (it falls back to full
    # replication — the "Involuntary full rematerialization" warning, which
    # dominated the baseline collective term; §Perf iteration 2).  Both
    # dispatch and combine are therefore expressed as gathers driven by the
    # sort permutation and its inverse.
    flat_e = eids.reshape(-1)                                   # (T*K,)
    flat_tok = jnp.repeat(jnp.arange(T), K)                     # token of entry
    order = jnp.argsort(flat_e)                                 # stable
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    inv_order = jnp.argsort(order)                              # entry -> rank
    # position of each sorted entry within its expert group:
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E))     # (E,)
    pos = jnp.arange(T * K) - group_start[e_sorted]
    keep = pos < C                                              # drop overflow

    # dispatch: xe[e, c] = tokens of the c-th kept entry of expert e
    take = group_start[:, None] + jnp.arange(C)[None, :]        # (E, C)
    valid = take < jnp.append(group_start[1:], T * K)[:, None]
    take = jnp.minimum(take, T * K - 1)
    xe = jnp.where(valid[..., None],
                   xf[tok_sorted[take]], 0.0).astype(x.dtype)   # (E, C, D)

    # ---- expert FFNs (grouped einsum, expert-parallel) ------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, D)

    # ---- combine: inverse-permutation gather + weighted sum over slots --
    ye_flat = ye.reshape(E * C, D)
    slot = jnp.where(keep, e_sorted * C + pos, 0)
    contrib_sorted = jnp.where(keep[:, None], ye_flat[slot], 0.0)
    entry_out = contrib_sorted[inv_order].reshape(T, K, D)       # orig order
    out = jnp.einsum("tkd,tk->td", entry_out,
                     gates.astype(entry_out.dtype)).astype(x.dtype)
    return out.reshape(B, S, D)


# ---------------------------------------------------------------------------
# shard_map path: local routing, expert-parallel FFN, one psum
# ---------------------------------------------------------------------------


def _moe_local_partial(cfg: ModelConfig, xf, router, wg, wu, wd, tax):
    """Per-shard MoE: xf (T_loc, D) local tokens; wg/wu/wd (E_loc, D, F)
    this shard's experts.  Returns this shard's partial output (T_loc, D);
    the caller psums over the tensor axis."""
    mo = cfg.moe
    T, D = xf.shape
    E, K = mo.num_experts, mo.top_k
    E_loc = wg.shape[0]
    C = _capacity(T, mo)
    rank = jax.lax.axis_index(tax)

    logits = (xf @ router).astype(jnp.float32)
    gates, eids = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eids.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    inv_order = jnp.argsort(order)
    group_start_all = jnp.searchsorted(e_sorted, jnp.arange(E + 1))
    pos = jnp.arange(T * K) - group_start_all[:-1][e_sorted]
    keep = pos < C

    # dispatch only MY experts: rows [rank*E_loc, (rank+1)*E_loc)
    my_e = rank * E_loc + jnp.arange(E_loc)
    g_start = group_start_all[my_e]                    # (E_loc,)
    g_end = group_start_all[my_e + 1]
    take = g_start[:, None] + jnp.arange(C)[None, :]   # (E_loc, C)
    valid = take < g_end[:, None]
    take = jnp.minimum(take, T * K - 1)
    xe = jnp.where(valid[..., None], xf[tok_sorted[take]], 0.0) \
        .astype(xf.dtype)                              # (E_loc, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
        jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)             # (E_loc, C, D)

    local_e = e_sorted - rank * E_loc
    mine = (local_e >= 0) & (local_e < E_loc) & keep
    slot = jnp.where(mine, local_e * C + pos, 0)
    contrib_sorted = jnp.where(mine[:, None],
                               ye.reshape(E_loc * C, D)[slot], 0.0)
    entry_out = contrib_sorted[inv_order].reshape(T, K, D)
    return jnp.einsum("tkd,tk->td", entry_out,
                      gates.astype(entry_out.dtype)).astype(xf.dtype)


def _moe_shard_map(cfg: ModelConfig, p, x, rules):
    """shard_map wrapper; returns None when the shapes don't divide the
    mesh (the caller then falls back to the global path)."""
    from jax.sharding import PartitionSpec as P
    mo = cfg.moe
    mesh, tax = rules.mesh, rules.tensor
    baxes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    baxes = tuple(a for a in baxes if a in mesh.axis_names)
    n_b = 1
    for a in baxes:
        n_b *= mesh.shape[a]
    n_t = mesh.shape[tax]
    B, S, D = x.shape
    if (not baxes or B % n_b != 0 or mo.num_experts % n_t != 0):
        return None
    bspec = baxes if len(baxes) > 1 else baxes[0]

    def body(xl, router, wg, wu, wd):
        Bl, S_, D_ = xl.shape
        out = _moe_local_partial(cfg, xl.reshape(Bl * S_, D_), router,
                                 wg, wu, wd, tax)
        return jax.lax.psum(out, tax).reshape(Bl, S_, D_)

    w_spec = P(tax, None, None)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  w_spec, w_spec, w_spec),
        out_specs=P(bspec, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
