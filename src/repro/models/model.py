"""Model assembly: abstract params, caches, forward, loss, and the jit-able
``train_step`` / ``serve_step`` factories used by the launcher and dry-run.

Batch conventions (see launch/dryrun.py input_specs):
  train:   {"tokens": (B,S) i32, "labels": (B,S) i32 [, "patches"/"frames"]}
  prefill: {"tokens": (B,S)} + empty cache  -> logits of last position + cache
  decode:  {"token": (B,1)} + cache + cache_len -> next-token logits + cache
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sharding import ParamSpec, Rules, constrain
from . import layers, ssm as ssm_mod, transformer


# ---------------------------------------------------------------------------
# Abstract parameters
# ---------------------------------------------------------------------------


def model_abstract(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.padded_vocab
    p = {
        "embed": ParamSpec((V, D), ("tensor", "fsdp")),
        "decoder": transformer.decoder_abstract(cfg),
        "final_norm": layers.rmsnorm_abstract(D),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamSpec((D, V), ("fsdp", "tensor"))
    if cfg.is_encoder_decoder:
        p["encoder"] = transformer.encoder_abstract(cfg)
    return p


def _slot_cache_abstract(cfg: ModelConfig, kind: str, batch: int,
                         max_seq: int):
    if kind == "ssm":
        return {"attn": ssm_mod.ssm_cache_abstract(cfg, batch)}
    if cfg.attn_type == "mla":
        return {"attn": layers.mla_cache_abstract(cfg, batch, max_seq)}
    return {"attn": layers.gqa_cache_abstract(cfg, batch, max_seq)}


def cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode-cache pytree mirroring the decoder structure."""
    nd = cfg.moe.first_dense if cfg.moe else 0
    n_periods = (cfg.n_layers - nd) // len(cfg.pattern)
    c = {
        "prefix": [
            _slot_cache_abstract(cfg, "attn", batch, max_seq)
            for _ in range(nd)],
        "slots": [
            transformer._stack(
                _slot_cache_abstract(cfg, kind, batch, max_seq), n_periods)
            for kind in cfg.pattern],
    }
    if cfg.is_encoder_decoder:
        K, hd = cfg.n_kv_heads, cfg.head_dim
        Se = cfg.encoder_seq
        ax = ("batch", None, None, None)
        c["cross"] = {
            "prefix": [
                {"k": ParamSpec((batch, Se, K, hd), ax),
                 "v": ParamSpec((batch, Se, K, hd), ax)} for _ in range(nd)],
            "slots": [
                transformer._stack(
                    {"k": ParamSpec((batch, Se, K, hd), ax),
                     "v": ParamSpec((batch, Se, K, hd), ax)}, n_periods)
                for _ in cfg.pattern],
        }
    return c


def cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Materialize a zeroed decode cache (smoke tests / examples)."""
    dtype = dtype or cache_dtype(cfg)
    ab = cache_abstract(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, dtype), ab,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _lm_logits(cfg: ModelConfig, params, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def _cross_stack(cfg: ModelConfig, params, enc_out):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    dec = params["decoder"]
    prefix = [layers.cross_kv(cfg, sp["xattn"], enc_out)
              for sp in dec["prefix"]]
    slots = [jax.vmap(lambda sp: layers.cross_kv(cfg, sp, enc_out))(
        slot["xattn"]) for slot in dec["slots"]]
    return {"prefix": prefix, "slots": slots}


def forward(cfg: ModelConfig, params, batch, *, rules: Rules,
            train: bool = False):
    """Full-sequence forward -> logits (B, S_tokens, V)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(cfg, params, tokens).astype(jnp.dtype(cfg.dtype))
    n_prepend = 0
    if cfg.frontend == "vision" and "patches" in batch:
        patches = batch["patches"].astype(h.dtype)
        n_prepend = patches.shape[1]
        h = jnp.concatenate([patches, h], axis=1)
    h = constrain(h, rules, "batch", "seq_sp", None)

    cross_stack = None
    if cfg.is_encoder_decoder:
        enc_out = transformer.encoder_apply(
            cfg, params["encoder"], batch["frames"].astype(h.dtype),
            rules=rules)
        cross_stack = _cross_stack(cfg, params, enc_out)

    positions = jnp.arange(h.shape[1])
    h, _ = transformer.decoder_apply(
        cfg, params["decoder"], h, positions=positions, rules=rules,
        cross_kv_stack=cross_stack, train=train)
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if n_prepend:
        h = h[:, n_prepend:, :]
    return _lm_logits(cfg, params, h)


def loss_fn(cfg: ModelConfig, params, batch, *, rules: Rules):
    """Next-token cross entropy (labels = tokens shifted by caller)."""
    logits = forward(cfg, params, batch, rules=rules, train=True)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:      # mask vocab-pad columns
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, batch, cache, *, rules: Rules):
    """Process the prompt, fill the cache.  Returns (last_logits, cache)."""
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens).astype(jnp.dtype(cfg.dtype))
    h = constrain(h, rules, "batch", "seq_sp", None)
    cross_stack = None
    sub_cache = {k: v for k, v in cache.items() if k != "cross"}
    if cfg.is_encoder_decoder:
        enc_out = transformer.encoder_apply(
            cfg, params["encoder"], batch["frames"].astype(h.dtype),
            rules=rules)
        cross_stack = _cross_stack(cfg, params, enc_out)
    positions = jnp.arange(h.shape[1])
    h, new_cache = transformer.decoder_apply(
        cfg, params["decoder"], h, positions=positions, rules=rules,
        caches=sub_cache, cache_len=jnp.zeros((), jnp.int32),
        cross_kv_stack=cross_stack)
    if cfg.is_encoder_decoder:
        new_cache["cross"] = cross_stack_to_cache(cross_stack)
    h = layers.rmsnorm(params["final_norm"], h[:, -1:, :], cfg.norm_eps)
    return _lm_logits(cfg, params, h), new_cache


def cross_stack_to_cache(cross_stack):
    to_dict = lambda kv: {"k": kv[0], "v": kv[1]}
    return {"prefix": [to_dict(kv) for kv in cross_stack["prefix"]],
            "slots": [to_dict(kv) for kv in cross_stack["slots"]]}


def cache_to_cross_stack(cross_cache):
    to_kv = lambda d: (d["k"], d["v"])
    return {"prefix": [to_kv(d) for d in cross_cache["prefix"]],
            "slots": [to_kv(d) for d in cross_cache["slots"]]}


def decode_step(cfg: ModelConfig, params, token, cache, cache_len, *,
                rules: Rules):
    """One new token against a cache of length cache_len.  Returns
    (logits (B,1,V), new_cache)."""
    h = _embed(cfg, params, token).astype(jnp.dtype(cfg.dtype))
    cross_stack = None
    sub_cache = {k: v for k, v in cache.items() if k != "cross"}
    if cfg.is_encoder_decoder:
        cross_stack = cache_to_cross_stack(cache["cross"])
    positions = cache_len + jnp.arange(1)
    h, new_cache = transformer.decoder_apply(
        cfg, params["decoder"], h, positions=positions, rules=rules,
        caches=sub_cache, cache_len=cache_len, cross_kv_stack=cross_stack)
    if cfg.is_encoder_decoder:
        new_cache["cross"] = cache["cross"]
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _lm_logits(cfg, params, h), new_cache


# ---------------------------------------------------------------------------
# Parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count from the abstract tree.  active_only: replace
    each MoE layer's expert bank with (top_k + n_shared) experts — the 6·N·D
    'active parameters' convention for MoE FLOPs."""
    ab = model_abstract(cfg)
    leaves = jax.tree.leaves(ab, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = sum(int(np.prod(s.shape)) for s in leaves)
    if active_only and cfg.moe is not None:
        mo = cfg.moe
        D, F, E = cfg.d_model, mo.d_expert, mo.num_experts
        per_expert = 3 * D * F
        nd = mo.first_dense
        n_moe = sum(
            1 for s in range(len(cfg.pattern))
            if transformer._slot_is_moe(cfg, s)) * (
                (cfg.n_layers - nd) // len(cfg.pattern))
        total -= n_moe * (E - mo.top_k) * per_expert
    return total


def non_embedding_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = count_params(cfg, active_only)
    n -= cfg.padded_vocab * cfg.d_model        # input embedding table
    return n
