"""Mamba2 block via SSD (state-space duality), arXiv:2405.21060.

Train/prefill run the *chunked* SSD algorithm: the sequence is cut into
Q-length chunks; within a chunk the recurrence is evaluated as a masked
quadratic form (MXU-friendly), across chunks a short ``lax.scan`` carries the
(H, N, P) state.  Decode is the O(1) recurrence
    h <- exp(dt·A) h + dt · B ⊗ x,   y = C·h + D·x.

TPU adaptation notes (DESIGN.md §2): the chunk quadratic form is exactly a
(Q × Q) masked attention-like product — it maps onto the MXU the same way a
flash tile does, with chunk length Q=256 keeping every tile VMEM-resident.
Heads shard over the ``tensor`` mesh axis; the cross-chunk scan carries only
the (B, H, N, P) state so sequence length never enters live memory.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamSpec
from . import layers


def ssm_abstract(cfg: ModelConfig):
    sc = cfg.ssm
    D = cfg.d_model
    Din = sc.d_inner(D)
    H = sc.n_heads(D)
    N = sc.d_state
    conv_ch = Din + 2 * N
    return {
        "w_zx": ParamSpec((D, 2 * Din), ("fsdp", "tensor")),
        "w_bc": ParamSpec((D, 2 * N), ("fsdp", None)),
        "w_dt": ParamSpec((D, H), ("fsdp", None)),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D_skip": ParamSpec((H,), (None,), init="ones"),
        "conv_w": ParamSpec((sc.d_conv, conv_ch), (None, None)),
        "conv_b": ParamSpec((conv_ch,), (None,), init="zeros"),
        "norm": ParamSpec((Din,), (None,), init="ones"),
        "w_out": ParamSpec((Din, D), ("tensor", "fsdp")),
    }


def ssm_cache_abstract(cfg: ModelConfig, batch: int):
    sc = cfg.ssm
    D = cfg.d_model
    Din, H, N = sc.d_inner(D), sc.n_heads(D), sc.d_state
    return {
        "state": ParamSpec((batch, H, N, sc.head_dim), ("batch", None, None, None)),
        "conv": ParamSpec((batch, sc.d_conv - 1, Din + 2 * N),
                          ("batch", None, None)),
    }


def _causal_conv_train(w, b, u):
    """Depthwise causal conv over (B, L, C); width = w.shape[0]."""
    dw = w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (dw - 1, 0), (0, 0)))
    out = sum(u_pad[:, i:i + u.shape[1], :] * w[i] for i in range(dw))
    return out + b


def _causal_conv_step(w, b, conv_cache, u_new):
    """conv_cache (B, dw-1, C); u_new (B, 1, C) -> (out (B,1,C), new cache)."""
    dw = w.shape[0]
    window = jnp.concatenate([conv_cache, u_new], axis=1)       # (B, dw, C)
    out = jnp.einsum("btc,tc->bc", window, w)[:, None, :] + b
    return out, window[:, 1:, :]


def ssd_chunked(x, dt, A, B, C, *, chunk: int):
    """Chunked SSD scan.

    x (B,L,H,P) pre-scaled inputs; dt (B,L,H) post-softplus; A (H,) negative;
    B, C (B,L,N).  Returns (y (B,L,H,P), final_state (B,H,N,P)).
    """
    Bsz, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    r = lambda t, d: t.reshape(Bsz, nc, Q, *t.shape[2:])
    xc, dtc = r(x, 4), r(dt, 3)
    Bc, Cc = r(B, 3), r(C, 3)

    dA = dtc * A[None, None, None, :]                 # (B,c,Q,H) negative
    cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # ---- intra-chunk (masked quadratic form) -----------------------------
    # att[b,c,h,i,j] = exp(cs_i - cs_j) * (C_i . B_j) * dt_j,  j <= i
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]        # (B,c,Q,Q,H)
    idx = jnp.arange(Q)
    mask = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    seg = jnp.where(mask, seg, -jnp.inf)
    decay = jnp.exp(seg)                                      # (B,c,Q,Q,H)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # (B,c,Q,Q)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]       # (B,c,Q,Q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # ---- chunk states and inter-chunk recurrence -------------------------
    last = cs[:, :, -1:, :]                                   # (B,c,1,H)
    w_state = jnp.exp(last - cs) * dtc                        # (B,c,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, w_state, xc)
    chunk_decay = jnp.exp(last[:, :, 0, :])                   # (B,c,H)

    def body(h, inp):
        s_c, d_c = inp                                        # (B,H,N,P), (B,H)
        h_out = h                                             # state entering
        h = h * d_c[:, :, None, None] + s_c
        return h, h_out

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    hT, h_in = jax.lax.scan(
        body, h0,
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         chunk_decay.astype(jnp.float32).transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                      # (B,c,H,N,P)

    # ---- off-diagonal contribution ---------------------------------------
    h_dec = (jnp.exp(cs)[..., None, None] * h_in[:, :, None]).astype(x.dtype)
    y_off = jnp.einsum("bcin,bcihnp->bcihp", Cc, h_dec)       # (B,c,Q,H,P)
    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y, hT


def ssd_step(state, x, dt, A, B, C):
    """One-token recurrence.  state (B,H,N,P); x (B,H,P); dt (B,H); B,C (B,N)."""
    dA = jnp.exp(dt * A[None, :])                             # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", B, dt, x)
    state = state * dA[:, :, None, None] + upd.astype(state.dtype)
    y = jnp.einsum("bn,bhnp->bhp", C, state.astype(x.dtype))
    return state, y


def ssm_apply(cfg: ModelConfig, p, xres, *, cache=None):
    """Full Mamba2 block.  xres (B, S, D) -> (out, new_cache)."""
    sc = cfg.ssm
    Bsz, S, D = xres.shape
    Din = sc.d_inner(D)
    H, N, P = sc.n_heads(D), sc.d_state, sc.head_dim

    zx = xres @ p["w_zx"]
    z, xin = zx[..., :Din], zx[..., Din:]
    bc = xres @ p["w_bc"]
    dt_raw = xres @ p["w_dt"]
    conv_in = jnp.concatenate([xin, bc], axis=-1)             # (B,S,Din+2N)

    new_cache = None
    if cache is None or S > 1:
        conv_out = _causal_conv_train(p["conv_w"], p["conv_b"], conv_in)
        if cache is not None:       # prefill: keep the conv tail for decode
            new_cache = {"conv": conv_in[:, S - (sc.d_conv - 1):, :].astype(
                cache["conv"].dtype)}
    else:
        conv_out, conv_state = _causal_conv_step(
            p["conv_w"], p["conv_b"], cache["conv"], conv_in)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype)}
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :Din].reshape(Bsz, S, H, P)
    Bmat = conv_out[..., Din:Din + N]
    Cmat = conv_out[..., Din + N:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))

    if cache is None or S > 1:
        y, hT = ssd_chunked(xc, dt.astype(xc.dtype), A, Bmat, Cmat,
                            chunk=sc.chunk)
        if cache is not None:
            new_cache["state"] = hT.astype(cache["state"].dtype)
    else:
        state, y1 = ssd_step(cache["state"], xc[:, 0], dt[:, 0].astype(xc.dtype),
                             A, Bmat[:, 0], Cmat[:, 0])
        new_cache["state"] = state
        y = y1[:, None]
    y = y + p["D_skip"].astype(y.dtype)[None, None, :, None] * xc
    y = y.reshape(Bsz, S, Din)
    y = layers.rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"], new_cache
