"""Decoder stack: scan-over-periods with per-slot heterogeneous layers.

The layer list is described by a repeating *pattern* of slots (config
``layer_pattern``), e.g. Jamba's ("ssm","ssm","ssm","attn","ssm","ssm",
"ssm","ssm").  Weights are stacked per slot with a leading (n_periods,)
axis and the stack runs under one ``jax.lax.scan`` — compile time and HLO
size stay O(pattern), not O(n_layers), which is what keeps the 512-device
GSPMD dry-run tractable for 62-layer models.

Layers that cannot join the uniform scan (DeepSeek-V2's first dense layer)
are hoisted out as an unrolled prefix.

Remat: the scan body is wrapped in ``jax.checkpoint`` (nothing_saveable) for
training, so live activation memory is one period deep; everything else is
recomputed in the backward pass.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamSpec, Rules, constrain
from . import layers, moe, ssm


# ---------------------------------------------------------------------------
# Abstract parameter construction
# ---------------------------------------------------------------------------


def _stack(abstract, n: int):
    """Prepend a stacked (n,) layer axis to every ParamSpec in a pytree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), (None, *s.logical), s.init, s.scale),
        abstract, is_leaf=lambda x: isinstance(x, ParamSpec))


def _slot_abstract(cfg: ModelConfig, kind: str, is_moe: bool,
                   cross_attn: bool):
    d = {"ln1": layers.rmsnorm_abstract(cfg.d_model)}
    if kind == "attn":
        d["attn"] = (layers.mla_abstract(cfg) if cfg.attn_type == "mla"
                     else layers.gqa_abstract(cfg))
    else:
        d["attn"] = ssm.ssm_abstract(cfg)
    if cross_attn:
        d["ln_x"] = layers.rmsnorm_abstract(cfg.d_model)
        d["xattn"] = layers.gqa_abstract(cfg)
    if is_moe:
        d["ln2"] = layers.rmsnorm_abstract(cfg.d_model)
        d["mlp"] = moe.moe_abstract(cfg)
    elif cfg.d_ff > 0:
        d["ln2"] = layers.rmsnorm_abstract(cfg.d_model)
        d["mlp"] = (layers.gelu_mlp_abstract(cfg.d_model, cfg.d_ff)
                    if cfg.family == "audio"
                    else layers.swiglu_abstract(cfg.d_model, cfg.d_ff))
    return d


def _slot_is_moe(cfg: ModelConfig, slot: int) -> bool:
    if cfg.moe is None:
        return False
    return slot % cfg.moe.every_k == cfg.moe.every_k - 1 or cfg.moe.every_k == 1


def decoder_abstract(cfg: ModelConfig):
    nd = cfg.moe.first_dense if cfg.moe else 0
    n_scanned = cfg.n_layers - nd
    period = cfg.pattern
    assert n_scanned % len(period) == 0
    n_periods = n_scanned // len(period)
    xattn = cfg.is_encoder_decoder
    d = {
        "prefix": [
            _slot_abstract(cfg, "attn", False, xattn) for _ in range(nd)],
        "slots": [
            _stack(_slot_abstract(cfg, kind, _slot_is_moe(cfg, s), xattn),
                   n_periods)
            for s, kind in enumerate(period)],
    }
    return d


def encoder_abstract(cfg: ModelConfig):
    slot = {
        "ln1": layers.rmsnorm_abstract(cfg.d_model),
        "attn": layers.gqa_abstract(cfg),
        "ln2": layers.rmsnorm_abstract(cfg.d_model),
        "mlp": (layers.gelu_mlp_abstract(cfg.d_model, cfg.d_ff)
                if cfg.family == "audio"
                else layers.swiglu_abstract(cfg.d_model, cfg.d_ff)),
    }
    return {"slots": [_stack(slot, cfg.encoder_layers)],
            "final_norm": layers.rmsnorm_abstract(cfg.d_model)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_slot(cfg: ModelConfig, kind: str, sp, h, *, positions, rules,
                cache=None, cache_len=None, cross=None):
    """One residual block: (attn|ssm) [+ cross-attn] + (mlp|moe)."""
    new_cache = {}
    hn = layers.rmsnorm(sp["ln1"], h, cfg.norm_eps)
    if kind == "attn":
        if cfg.attn_type == "mla":
            a, c = layers.mla_apply(cfg, sp["attn"], hn, positions=positions,
                                    cache=None if cache is None else cache["attn"],
                                    cache_len=cache_len, rules=rules)
        else:
            a, c = layers.gqa_apply(cfg, sp["attn"], hn, positions=positions,
                                    cache=None if cache is None else cache["attn"],
                                    cache_len=cache_len, rules=rules)
    else:
        a, c = ssm.ssm_apply(cfg, sp["attn"], hn,
                             cache=None if cache is None else cache["attn"])
    if c is not None:
        new_cache["attn"] = c
    h = h + a.astype(h.dtype)
    if cross is not None:
        hx = layers.rmsnorm(sp["ln_x"], h, cfg.norm_eps)
        a, _ = layers.gqa_apply(cfg, sp["xattn"], hx, positions=positions,
                                cross=cross)
        h = h + a.astype(h.dtype)
    if "mlp" in sp:
        hn = layers.rmsnorm(sp["ln2"], h, cfg.norm_eps)
        if "router" in sp["mlp"]:
            f = moe.moe_apply(cfg, sp["mlp"], hn, rules=rules)
        elif "w_gate" in sp["mlp"]:
            f = layers.swiglu_apply(sp["mlp"], hn)
        else:
            f = layers.gelu_mlp_apply(sp["mlp"], hn)
        h = h + f.astype(h.dtype)
    if h.shape[1] > 1:
        h = constrain(h, rules, "batch", "seq_sp", None)
    return h, (new_cache or None)


def decoder_apply(cfg: ModelConfig, dec_params, h, *, positions, rules: Rules,
                  caches=None, cache_len=None, cross_kv_stack=None,
                  train: bool = False):
    """Run prefix layers then the scanned periods.

    caches: {"prefix": [cache, ...], "slots": [stacked-cache, ...]} or None.
    cross_kv_stack: {"prefix": [(k,v)...], "slots": [(k,v) stacked]} or None.
    Returns (h, new_caches).
    """
    period = cfg.pattern
    new_caches = {"prefix": [], "slots": []} if caches is not None else None

    for i, sp in enumerate(dec_params["prefix"]):
        cr = cross_kv_stack["prefix"][i] if cross_kv_stack else None
        c = caches["prefix"][i] if caches is not None else None
        h, nc = _apply_slot(cfg, "attn", sp, h, positions=positions,
                            rules=rules, cache=c, cache_len=cache_len,
                            cross=cr)
        if new_caches is not None:
            new_caches["prefix"].append(nc)

    def period_fwd(h, slot_params, slot_caches, slot_cross):
        ncs = []
        for s, kind in enumerate(period):
            cr = slot_cross[s] if slot_cross is not None else None
            c = slot_caches[s] if slot_caches is not None else None
            h, nc = _apply_slot(cfg, kind, slot_params[s], h,
                                positions=positions, rules=rules,
                                cache=c, cache_len=cache_len, cross=cr)
            ncs.append(nc)
        return h, ncs

    if caches is None and cross_kv_stack is None:
        body = lambda h, pp: (period_fwd(h, pp, None, None)[0], None)
        if train:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, dec_params["slots"])
    else:
        def body(h, xs):
            pp, cc, cr = xs
            h, ncs = period_fwd(h, pp, cc, cr)
            return h, ncs
        xs = (dec_params["slots"],
              caches["slots"] if caches is not None else _nones_like_scan(
                  dec_params["slots"]),
              cross_kv_stack["slots"] if cross_kv_stack else _nones_like_scan(
                  dec_params["slots"]))
        h, ncs = jax.lax.scan(body, h, xs)
        if new_caches is not None:
            new_caches["slots"] = ncs
    return h, new_caches


def _nones_like_scan(slots):
    """Scan xs placeholder: a list of Nones matching the slot structure
    (None is a valid empty-pytree leaf container for scan xs)."""
    return [None] * len(slots)


def encoder_apply(cfg: ModelConfig, enc_params, frames, *, rules: Rules):
    """frames (B, Se, D) precomputed embeddings (frontend stub)."""
    positions = jnp.arange(frames.shape[1])

    def body(h, sp):
        hn = layers.rmsnorm(sp["ln1"], h, cfg.norm_eps)
        a, _ = layers.gqa_apply(cfg, sp["attn"], hn, positions=positions,
                                causal=False)
        h = h + a
        hn = layers.rmsnorm(sp["ln2"], h, cfg.norm_eps)
        if "w_gate" in sp["mlp"]:
            h = h + layers.swiglu_apply(sp["mlp"], hn)
        else:
            h = h + layers.gelu_mlp_apply(sp["mlp"], hn)
        return h, None

    h, _ = jax.lax.scan(body, frames, enc_params["slots"][0])
    return layers.rmsnorm(enc_params["final_norm"], h, cfg.norm_eps)
