"""Logical-axis sharding: one table maps logical tensor axes to mesh axes.

Parameters and activations are annotated with *logical* axis names at the
point of definition; ``to_pspec`` resolves them against the active rule set
(which differs between the single-pod and multi-pod meshes only in what the
``batch``/``worker`` axes map to).  This is the MaxText/Flax-linen pattern
without the framework dependency.

Rules (production defaults):
  batch    -> ("pod", "data")  activations' batch dim (DP across pods too)
  fsdp     -> "data"           weight FSDP shard dim
  tensor   -> "model"          TP: heads / ffn / vocab / experts
  seq_sp   -> "model"          sequence-parallel residual stream between blocks
  kv_seq   -> "model"          decode KV-cache sequence dim (flash-decoding)
  layers   -> None             scan-stacked layer dim, never sharded
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: Tuple[str, ...] = ("data",)
    fsdp: Optional[str] = "data"
    tensor: Optional[str] = "model"
    seq_sp: Optional[str] = "model"
    kv_seq: Optional[str] = "model"
    # concrete mesh, when known — lets layers opt into shard_map subregions
    # (the MoE dispatch) instead of pure GSPMD-auto. None in smoke tests.
    mesh: Optional[Mesh] = dataclasses.field(default=None, compare=False)

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        v = getattr(self, logical)
        if isinstance(v, tuple):
            return v if len(v) > 1 else (v[0] if v else None)
        return v


def rules_for_mesh(mesh: Mesh) -> Rules:
    """Pick rules matching the mesh's axes (pod axis folds into batch/DP)."""
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    has_model = "model" in axes
    return Rules(
        batch=batch or (axes[0],),
        fsdp="data" if "data" in axes else None,
        tensor="model" if has_model else None,
        seq_sp="model" if has_model else None,
        kv_seq="model" if has_model else None,
        mesh=mesh,
    )


def to_pspec(logical_axes: Tuple[Optional[str], ...], rules: Rules) -> P:
    return P(*(rules.resolve(a) for a in logical_axes))


class ParamSpec(NamedTuple):
    """Abstract parameter: shape + logical axes + init scale."""
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones
    scale: float = 1.0

    def sds(self, dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)


def pspec_tree(abstract, rules: Rules):
    """Map a pytree of ParamSpec to PartitionSpecs."""
    return jax.tree.map(lambda s: to_pspec(s.logical, rules), abstract,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def sds_tree(abstract, dtype):
    return jax.tree.map(lambda s: s.sds(dtype), abstract,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def sharding_tree(abstract, rules: Rules, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, to_pspec(s.logical, rules)), abstract,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def init_tree(abstract, key, dtype):
    """Materialize real parameters (smoke tests / examples only; the dry-run
    never calls this)."""
    leaves, treedef = jax.tree.flatten(
        abstract, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    import jax.numpy as jnp

    def make(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / (fan_in ** 0.5)
        return (jax.random.normal(k, spec.shape, dtype) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])


def constrain(x, rules: Rules, *logical_axes):
    """with_sharding_constraint by logical names (no-op outside jit mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, to_pspec(logical_axes, rules))
    except (ValueError, RuntimeError):
        return x
