"""Model zoo: the 10 assigned architectures as composable JAX modules.

Single source of truth per architecture is a ``ModelConfig``
(``repro.configs``); ``model.py`` turns a config into abstract parameters,
sharding specs, and the jit-able ``train_step`` / ``serve_step`` functions
used by the launcher, dry-run, and benchmarks.
"""
from . import model  # noqa: F401
