"""ModelConfig: one declarative record per architecture.

Covers every family in the assigned pool: dense GQA/MHA transformers,
MLA (DeepSeek-V2), MoE (routed + shared experts), SSM (Mamba2/SSD), hybrid
layer patterns (Jamba), VLM and audio backbones with stubbed frontends, and
encoder-decoder (Whisper).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared: int = 0             # always-on shared experts (DeepSeek-V2)
    every_k: int = 1              # MoE replaces the MLP on layers l % k == 0
    first_dense: int = 0          # leading layers that stay dense (DSv2: 1)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256              # SSD chunk length (train/prefill)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention flavour ---
    attn_type: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # --- state space ---
    ssm: Optional[SSMConfig] = None
    # --- hybrid layer pattern; () means ("attn",) * n_layers ---
    # slots drawn from {"attn", "ssm"}; pattern length must divide n_layers.
    layer_pattern: Tuple[str, ...] = ()
    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0       # > 0 => enc-dec; n_layers is the decoder
    encoder_seq: int = 1500       # precomputed frame count (audio stub)
    # --- multimodal stub ---
    frontend: str = "none"        # none | audio | vision
    num_patches: int = 0          # vision: patches prepended to the sequence
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 for clean tensor-parallel sharding (the
        standard Megatron/MaxText trick).  The loss masks the pad columns."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.layer_pattern if self.layer_pattern else ("attn",)

    @property
    def n_periods(self) -> int:
        period = len(self.pattern)
        assert self.n_layers % period == 0, (self.n_layers, period)
        return self.n_layers // period

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(s == "ssm" for s in self.pattern)

    @property
    def supports_long_decode(self) -> bool:
        """long_500k runs only for sub-quadratic-decode families: a pure SSM
        has O(1) state; a hybrid's few attention layers hold a sharded KV.
        Pure full-attention archs are skipped (DESIGN.md §shapes)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def layer_kind(self, l: int) -> str:
        return self.pattern[l % len(self.pattern)]

    def is_moe_layer(self, l: int) -> bool:
        if self.moe is None:
            return False
        if l < self.moe.first_dense:
            return False
        return (l - self.moe.first_dense) % self.moe.every_k == 0

    def param_count(self) -> int:
        """Analytic parameter count (roofline: MODEL_FLOPS = 6·N·D)."""
        from . import model as _m
        return _m.count_params(self)

    def active_param_count(self) -> int:
        from . import model as _m
        return _m.count_params(self, active_only=True)
