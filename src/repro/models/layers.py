"""Transformer building blocks: norms, RoPE, attention (GQA + MLA), MLPs.

Everything is functional: ``*_abstract(cfg)`` returns a pytree of
``ParamSpec`` (shapes + logical sharding axes), and ``*_apply(cfg, params,
...)`` is the forward.  No framework dependency; params are plain dicts so
scan-stacking, checkpointing, and sharding stay transparent.

Attention memory strategy (DESIGN.md §6): train/prefill use a chunked
online-softmax ("flash") attention written in pure JAX — a ``lax.scan`` over
KV blocks with running (max, sum, acc).  This bounds live memory to one
(Sq × blk) tile per step regardless of sequence length, which is what lets
prefill_32k compile inside the per-device HBM budget.  Decode (Sq == 1)
uses the direct einsum path over the (possibly seq-sharded) cache.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamSpec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_abstract(dim: int):
    return {"scale": ParamSpec((dim,), (None,), init="ones")}


def rmsnorm(params, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def l2norm(x, eps: float):
    """Per-head qk-norm (Qwen3 style), no learned scale on the head axis."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Rotary embedding.  x (..., S, H, d) with d even; positions (..., S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (pure JAX, GQA-aware)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def pick_blk(sk: int) -> int:
    # Prefer the largest tile that divides Sk: fewer scan steps means fewer
    # per-step copy/stat round-trips; a (4096 x 256)-f32 tile is ~4 MB —
    # comfortably VMEM-resident on the target (§Perf iteration 6).
    for b in (4096, 2048, 1024, 512, 256, 128, 64):
        if sk % b == 0:
            return b
    return sk


def _flash_fwd_impl(q, k, v, q_offset, causal, blk):
    """Online-softmax forward.  Returns (out (B,Sq,H,dv) in q.dtype,
    lse (B,K,G,Sq) f32) — the log-sum-exp is the only stat the backward
    needs; no (Sq × Sk) tensor survives the scan."""
    B, Sq, H, dq = q.shape
    Sk, K, dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // K
    qg = q.reshape(B, Sq, K, G, dq)
    scale = dq ** -0.5
    nblk = Sk // blk

    kb = k.reshape(B, nblk, blk, K, dq).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, K, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        j, k_j, v_j = xs
        k_pos = j * blk + jnp.arange(blk)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_j,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_j.dtype), v_j,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nblk), kb, vb))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, q_offset=0, causal=True, blk: int = 1024):
    """Flash attention with a block-recompute backward (custom VJP).

    Plain AD through the forward scan would checkpoint every per-block
    probability tile — O(Sq·Sk) residual memory and the dominant HBM-traffic
    term of the baseline dry-run (§Perf iteration 1).  The custom backward
    recomputes each tile from (q, k_j, lse) instead, saving only O(Sq·d)
    activations at ~1.3x the attention FLOPs.

    q (B,Sq,H,dq), k (B,Sk,K,dq), v (B,Sk,K,dv), H % K == 0; Sk % blk == 0.
    q_offset/causal/blk are static.
    """
    out, _ = _flash_fwd_impl(q, k, v, q_offset, causal, blk)
    return out


def _flash_vjp_fwd(q, k, v, q_offset, causal, blk):
    out, lse = _flash_fwd_impl(q, k, v, q_offset, causal, blk)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(q_offset, causal, blk, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, dq = q.shape
    Sk, K, dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // K
    scale = dq ** -0.5
    qg = q.reshape(B, Sq, K, G, dq)
    do = dout.reshape(B, Sq, K, G, dv)
    og = out.reshape(B, Sq, K, G, dv)
    # delta[b,k,g,q] = sum_d dout * out   (rowwise correction term)
    # NB: operands stay in their storage dtype with f32 ACCUMULATION —
    # casting them to f32 up front makes GSPMD all-gather f32 copies of
    # K/V across the sequence-parallel axis (2x wire bytes, §Perf iter 4).
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", do, og,
                       preferred_element_type=jnp.float32)
    nblk = Sk // blk
    kb = k.reshape(B, nblk, blk, K, dq).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, K, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def body(dq_acc, xs):
        j, k_j, v_j = xs
        k_pos = j * blk + jnp.arange(blk)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_j,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # normalized
        pb = p.astype(do.dtype)
        dv_j = jnp.einsum("bkgqt,bqkgd->btkd", pb, do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", do, v_j,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(k_j.dtype)
        dq_acc = dq_acc + jnp.einsum("bkgqt,btkd->bqkgd", ds, k_j,
                                     preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bkgqt,bqkgd->btkd", ds, qg,
                          preferred_element_type=jnp.float32)
        # store per-block K/V grads in storage dtype (each is written once;
        # no cross-block accumulation to lose)
        return dq_acc, (dk_j.astype(k_j.dtype), dv_j.astype(v_j.dtype))

    dq0 = jnp.zeros((B, Sq, K, G, dq), jnp.float32)
    dq_acc, (dks, dvs) = jax.lax.scan(body, dq0,
                                      (jnp.arange(nblk), kb, vb))
    dqf = dq_acc.reshape(B, Sq, H, dq).astype(q.dtype)
    dkf = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, K, dq).astype(k.dtype)
    dvf = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, K, dv).astype(v.dtype)
    return dqf, dkf, dvf


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def decode_attention(q, k, v, *, kv_len):
    """Direct attention for Sq == small (decode).  Cache may be seq-sharded;
    the softmax reductions over Sk then lower to psums under GSPMD."""
    B, Sq, H, dq = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, dq)
    scale = dq ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(k.shape[1])
    s = jnp.where((k_pos < kv_len)[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    dv = v.shape[-1]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def gqa_abstract(cfg: ModelConfig):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ParamSpec((D, H * hd), ("fsdp", "tensor")),
        "wk": ParamSpec((D, K * hd), ("fsdp", "tensor")),
        "wv": ParamSpec((D, K * hd), ("fsdp", "tensor")),
        "wo": ParamSpec((H * hd, D), ("tensor", "fsdp")),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        p["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return p


@dataclasses.dataclass
class KVCacheSpec:
    """Shape/sharding of one attention layer's decode cache."""
    k: ParamSpec
    v: ParamSpec


def gqa_cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    K, hd = cfg.n_kv_heads, cfg.head_dim
    ax = ("batch", "kv_seq", None, None)
    return {"k": ParamSpec((batch, max_seq, K, hd), ax),
            "v": ParamSpec((batch, max_seq, K, hd), ax)}


def cross_kv(cfg: ModelConfig, p, enc_out):
    """Project encoder output once into (k, v) — cached across decode steps."""
    B, Se, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, Se, K, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, K, hd)
    if cfg.qk_norm:
        k = l2norm(k, cfg.norm_eps) * p["k_norm"].astype(k.dtype)
    return k, v


def gqa_apply(cfg: ModelConfig, p, x, *, positions, cache=None, cache_len=None,
              cross=None, causal=True, rules=None):
    """x (B, S, D).  Three modes:

      train   (cache None):          flash attention over x itself.
      prefill (cache, S > 1):        flash over x + write cache at cache_len.
      decode  (cache, S == 1):       insert token, attend over the cache.

    cross: precomputed (k, v) from ``cross_kv`` (whisper cross-attention) —
    replaces self-attention KV entirely, non-causal, no rope.
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if cfg.qk_norm:
        q = l2norm(q, cfg.norm_eps) * p["q_norm"].astype(q.dtype)

    if cross is not None:
        k, v = cross
        out = decode_attention(q, k, v, kv_len=k.shape[1])
        return out.reshape(B, S, H * hd) @ p["wo"], None

    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        k = l2norm(k, cfg.norm_eps) * p["k_norm"].astype(k.dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        start = jnp.asarray(cache_len)
        z = jnp.zeros((), start.dtype)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (z, start, z, z))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (z, start, z, z))
        new_cache = {"k": ck, "v": cv}
        if S == 1:
            out = decode_attention(q, ck, cv, kv_len=start + S)
        else:
            # prefill: the fresh tokens are the whole valid cache content.
            out = flash_attention(q, k, v, 0, True, pick_blk(S))
    else:
        # NOTE: head-sharding (TP) constraints here were tried and REFUTED
        # (§Perf): unlike MLA — whose expanded K/V are ~5x the residual
        # width — GQA K/V match the residual width, so forcing TP merely
        # adds SP<->TP resharding on both sides of the flash region
        # (deepseek-7b t_coll 6.75 -> 7.18 s).  GSPMD's propagated layout
        # is kept.
        out = flash_attention(q, k, v, 0, causal, pick_blk(k.shape[1]))
    return out.reshape(B, S, H * hd) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_abstract(cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ParamSpec((D, qr), ("fsdp", None)),
        "q_norm": ParamSpec((qr,), (None,), init="ones"),
        "wq_b": ParamSpec((qr, H * (dn + dr)), (None, "tensor")),
        "wkv_a": ParamSpec((D, r + dr), ("fsdp", None)),
        "kv_norm": ParamSpec((r,), (None,), init="ones"),
        "wk_b": ParamSpec((r, H * dn), (None, "tensor")),
        "wv_b": ParamSpec((r, H * dv), (None, "tensor")),
        "wo": ParamSpec((H * dv, D), ("tensor", "fsdp")),
    }


def mla_cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    return {"ckv": ParamSpec((batch, max_seq, r), ("batch", "kv_seq", None)),
            "krope": ParamSpec((batch, max_seq, dr), ("batch", "kv_seq", None))}


def _mla_qkv(cfg, p, x, positions):
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    q = rmsnorm({"scale": p["q_norm"]}, x @ p["wq_a"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]                                  # (B, S, r + dr)
    ckv = rmsnorm({"scale": p["kv_norm"]}, kv[..., :cfg.kv_lora_rank],
                  cfg.norm_eps)
    krope = rope(kv[..., cfg.kv_lora_rank:][..., None, :], positions,
                 cfg.rope_theta)[..., 0, :]              # (B, S, dr) shared
    return q_nope, q_rope, ckv, krope


def mla_apply(cfg: ModelConfig, p, x, *, positions, cache=None,
              cache_len=None, rules=None):
    """Train/prefill: expand K/V from the latent and run flash.  Decode
    (S == 1): *absorbed* path — scores and values live in the compressed
    r-space; the cache stores only (ckv, krope) per token, which is the
    paper's KV-cache saving (r + dr floats/token, head-count independent)."""
    B, S, D = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    q_nope, q_rope, ckv, krope = _mla_qkv(cfg, p, x, positions)

    new_cache = None
    if cache is not None:
        start = jnp.asarray(cache_len)
        z = jnp.zeros((), start.dtype)
        cc = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (z, start, z))
        cr = jax.lax.dynamic_update_slice(
            cache["krope"], krope.astype(cache["krope"].dtype), (z, start, z))
        new_cache = {"ckv": cc, "krope": cr}
        if S == 1:
            wk_b = p["wk_b"].reshape(r, H, dn)
            wv_b = p["wv_b"].reshape(r, H, dv)
            # absorb W_UK into q:   q_c (B,S,H,r)
            q_c = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)
            Sk = cc.shape[1]
            kv_len = start + S
            scale = (dn + dr) ** -0.5
            s = (jnp.einsum("bshr,btr->bhst", q_c, cc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bshd,btd->bhst", q_rope, cr,
                              preferred_element_type=jnp.float32)) * scale
            k_pos = jnp.arange(Sk)
            s = jnp.where((k_pos < kv_len)[None, None, None, :], s, NEG_INF)
            prob = jax.nn.softmax(s, axis=-1)
            o_c = jnp.einsum("bhst,btr->bshr", prob.astype(cc.dtype), cc,
                             preferred_element_type=jnp.float32)
            out = jnp.einsum("bshr,rhd->bshd", o_c.astype(x.dtype), wv_b)
            return out.reshape(B, S, H * dv) @ p["wo"], new_cache

    # train / prefill: expand to per-head K, V and run flash.  The expanded
    # K/V are H·(dn+dr) wide — ~5x the residual stream — so attention here
    # is HEAD-sharded (TP): only the compact latent (r + dr per token)
    # crosses the sequence-parallel boundary; without this constraint GSPMD
    # all-gathers the full expanded K/V per layer (§Perf iteration 5).
    k_nope = jnp.einsum("btr,rhd->bthd", ckv, p["wk_b"].reshape(r, H, dn))
    v = jnp.einsum("btr,rhd->bthd", ckv, p["wv_b"].reshape(r, H, dv))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (*k_nope.shape[:3], dr))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if rules is not None:
        from .sharding import constrain
        q = constrain(q, rules, "batch", None, "tensor", None)
        k = constrain(k, rules, "batch", None, "tensor", None)
        v = constrain(v, rules, "batch", None, "tensor", None)
    out = flash_attention(q, k, v, 0, True, pick_blk(k.shape[1]))
    out = out.reshape(B, S, H * dv)
    if rules is not None:
        from .sharding import constrain
        out = constrain(out, rules, "batch", None, "tensor")
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_abstract(d_model: int, d_ff: int):
    return {"w_gate": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
            "w_up": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
            "w_down": ParamSpec((d_ff, d_model), ("tensor", "fsdp"))}


def swiglu_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_abstract(d_model: int, d_ff: int):
    return {"w_in": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
            "b_in": ParamSpec((d_ff,), (None,), init="zeros"),
            "w_out": ParamSpec((d_ff, d_model), ("tensor", "fsdp")),
            "b_out": ParamSpec((d_model,), (None,), init="zeros")}


def gelu_mlp_apply(p, x):
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    return h @ p["w_out"] + p["b_out"]
