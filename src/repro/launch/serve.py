"""Serving driver: batched prefill + decode loop with a continuous-batching
slot model.

The same ``model.prefill`` / ``model.decode_step`` functions that the
dry-run compiles at pod scale drive this CPU-scale loop.  Requests are
packed into a fixed slot batch; finished slots are refilled (continuous
batching); the KV cache is the dry-run's cache pytree.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 --batch 2 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import model, sharding
# the queue semantics are shared with the linear-system request server
# (solvers/serve.py owns them now; re-exported here for compatibility)
from repro.solvers.serve import take_group  # noqa: F401


def make_decode(cfg, rules):
    """Compile-once greedy decode step.

    Built OUTSIDE the per-batch loop: a ``jax.jit`` created inside
    ``generate_batch`` would be a fresh wrapper per batch, so every batch
    would retrace — hoisting it here keeps one jit cache across the whole
    serving run.
    """
    return jax.jit(lambda p, t, c, l: model.decode_step(
        cfg, p, t, c, l, rules=rules))


def generate_batch(cfg, params, prompts, max_new: int, rules, extra=None,
                   decode=None):
    """Greedy-decode a batch of same-length prompts.  Returns (B, max_new).

    Pass ``decode`` (from ``make_decode``) to reuse one jitted decode step
    across batches; omitting it builds a throwaway wrapper (fine for a
    single call, a retrace-per-batch bug inside a serving loop).
    """
    B, S = prompts.shape
    cache = model.init_cache(cfg, B, S + max_new,
                             jnp.dtype(cfg.dtype))
    batch = {"tokens": prompts}
    if extra:
        batch.update(extra)
    logits, cache = model.prefill(cfg, params, batch, cache, rules=rules)
    out = []
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1)
    if decode is None:
        decode = make_decode(cfg, rules)
    for i in range(max_new):
        out.append(tok)
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = mesh_lib.make_host_mesh()
    rules = sharding.rules_for_mesh(mesh)
    params = sharding.init_tree(model.model_abstract(cfg),
                                jax.random.PRNGKey(0), jnp.dtype(cfg.dtype))

    rng = np.random.default_rng(0)
    queue = deque(rng.integers(0, cfg.vocab_size, size=args.prompt_len)
                  for _ in range(args.requests))
    extra = {}
    if cfg.frontend == "vision":
        extra["patches"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio":
        extra["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))

    done, t0 = 0, time.time()
    decode = make_decode(cfg, rules)        # ONE jit across all batches
    with mesh:
        while queue:
            group, n_real = take_group(queue, args.batch)
            prompts = jnp.asarray(np.stack(group), jnp.int32)
            toks = generate_batch(cfg, params, prompts, args.max_new, rules,
                                  extra, decode=decode)
            done += n_real                      # padding is not traffic
            print(f"batch of {n_real} (+{len(group) - n_real} pad): "
                  f"generated {toks.shape[1]} tokens each; "
                  f"sample: {np.asarray(toks[0])[:8]}", flush=True)
    dt = time.time() - t0
    print(f"served {done} requests in {dt:.1f}s "
          f"({done * args.max_new / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
