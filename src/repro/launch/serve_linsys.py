"""Linear-system serving driver: a request stream through ``LinsysServer``.

Generates a handful of synthetic systems, registers them with the server
(content-addressed fingerprints), submits a seeded FIFO stream of
(fingerprint, rhs) requests, and drains it batch by batch — same-system
requests coalesce into ``solve_many`` groups, every factorization comes
from the ``FactorStore`` (persist it across runs with ``--store-dir``),
and the compile-once executor cache keeps steady-state serving at zero
retraces.  Throughput excludes padding.

    PYTHONPATH=src python -m repro.launch.serve_linsys --requests 12 \
        --systems 2 --batch 4 --solver apc --iters 400
    PYTHONPATH=src python -m repro.launch.serve_linsys --backend mesh \
        --store-dir /tmp/factors --warm-start
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import solvers
from repro.data import linsys
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="apc", choices=solvers.available())
    ap.add_argument("--backend", default="local", choices=["local", "mesh"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--systems", type=int, default=2,
                    help="distinct linear systems sharing the serve loop")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--cond", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-dir", default=None,
                    help="disk tier for the factor store (factorizations "
                         "survive restarts; re-run to see disk hits)")
    ap.add_argument("--store-capacity", type=int, default=8)
    ap.add_argument("--warm-start", action="store_true",
                    help="reuse a system's prior batch state for repeated "
                         "(any solver) or perturbed (gradient family / "
                         "Cimmino) right-hand sides")
    ap.add_argument("--use-kernel", action="store_true",
                    help="serve batches through the fused multi-RHS Pallas "
                         "kernels (projection solvers, either backend)")
    ap.add_argument("--x64", action=argparse.BooleanOptionalAction,
                    default=True)
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", args.x64)
    store = FactorStore(capacity=args.store_capacity,
                        directory=args.store_dir)
    srv = LinsysServer(store, solver=args.solver, iters=args.iters,
                       tol=args.tol, batch=args.batch, backend=args.backend,
                       warm_start=args.warm_start,
                       use_kernel=args.use_kernel)

    rng = np.random.default_rng(args.seed)
    fps, systems = [], []
    for i in range(args.systems):
        sys_ = linsys.conditioned_gaussian(n=args.n, m=args.workers,
                                           cond=args.cond, seed=args.seed + i)
        fp = srv.register(sys_)
        fps.append(fp)
        systems.append(sys_)
        print(f"registered system {i}: N={sys_.N} n={sys_.n} m={sys_.m} "
              f"fingerprint {fp[:16]}...")

    for _ in range(args.requests):
        i = int(rng.integers(0, args.systems))
        srv.submit(fps[i], rng.standard_normal(systems[i].N))

    t0 = time.time()
    n_bad = 0
    while True:
        tb = time.time()
        batch = srv.step()
        if not batch:
            break
        dt = time.time() - tb
        worst = max(r.residual for r in batch)
        n_bad += sum(r.residual >= args.tol for r in batch)
        print(f"batch {srv.stats.batches}: {len(batch)} request(s) "
              f"[{batch[0].fp[:8]}...] in {dt * 1e3:7.1f} ms  "
              f"worst residual {worst:.2e}"
              + ("  (warm)" if batch[0].warm else ""))
    dt = time.time() - t0

    st = srv.stats
    print(f"served {st.served} requests in {dt:.2f}s "
          f"({st.served / dt:.1f} RHS/s, padding excluded: "
          f"{st.padded} pad slot(s) over {st.batches} batches)")
    print(f"factor store: {store.stats}")
    print(f"executors built: {st.executor_builds}  "
          f"jit cache entries: {srv.jit_cache_size()}  "
          f"warm batches: {st.warm_batches}")
    if n_bad:
        print(f"WARNING: {n_bad} request(s) above tol={args.tol:.0e} — "
              f"raise --iters")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
