"""Linear-system serving driver: a request stream through ``LinsysServer``.

Generates a handful of synthetic systems, registers them with the server
(content-addressed fingerprints), submits a seeded FIFO stream of
(fingerprint, rhs) requests, and drains it batch by batch — same-system
requests coalesce into ``solve_many`` groups, every factorization comes
from the ``FactorStore`` (persist it across runs with ``--store-dir``),
and the compile-once executor cache keeps steady-state serving at zero
retraces.  Throughput excludes padding.

    PYTHONPATH=src python -m repro.launch.serve_linsys --requests 12 \
        --systems 2 --batch 4 --solver apc --iters 400
    PYTHONPATH=src python -m repro.launch.serve_linsys --backend mesh \
        --store-dir /tmp/factors --warm-start

``--async`` swaps in the pipelined ``AsyncLinsysServer``: requests are
submitted on an open-loop Poisson schedule (``--arrival-rate`` req/s; 0 =
all at t=0) and served by the overlapped admission/assembly/execution
stages (``--pipeline-depth`` in-flight batches, ``--admit-capacity``
bounds queued+in-flight requests — overflow is shed with an explicit
result, not queued).  The run ends with the SLO latency report
(p50/p95/p99) and the shed rate.

    PYTHONPATH=src python -m repro.launch.serve_linsys --async \
        --requests 24 --arrival-rate 50 --pipeline-depth 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import solvers
from repro.data import linsys
from repro.solvers.capability import ExecutionPlan
from repro.solvers.pipeline import AsyncLinsysServer, Shed
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="apc", choices=solvers.available())
    ap.add_argument("--backend", default="local", choices=["local", "mesh"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--systems", type=int, default=2,
                    help="distinct linear systems sharing the serve loop")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--cond", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-dir", default=None,
                    help="disk tier for the factor store (factorizations "
                         "survive restarts; re-run to see disk hits)")
    ap.add_argument("--store-capacity", type=int, default=8)
    ap.add_argument("--warm-start", action="store_true",
                    help="reuse a system's prior batch state for repeated "
                         "(any solver) or perturbed (gradient family / "
                         "Cimmino) right-hand sides")
    ap.add_argument("--use-kernel", action="store_true",
                    help="serve batches through the fused multi-RHS Pallas "
                         "kernels (projection solvers, either backend)")
    ap.add_argument("--x64", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="serve through the pipelined AsyncLinsysServer "
                         "(overlapped admission/assembly/execution)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s for "
                         "--async (0 = submit everything at t=0)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="concurrently-executing batches in --async mode")
    ap.add_argument("--admit-capacity", type=int, default=None,
                    help="admission bound (queued + in flight) in --async "
                         "mode; overflow requests are shed explicitly")
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", args.x64)
    store = FactorStore(capacity=args.store_capacity,
                        directory=args.store_dir)
    plan = ExecutionPlan(backend=args.backend, kernel=args.use_kernel)
    kw = dict(solver=args.solver, iters=args.iters, tol=args.tol,
              batch=args.batch, plan=plan, warm_start=args.warm_start)
    if args.async_:
        srv = AsyncLinsysServer(store, pipeline_depth=args.pipeline_depth,
                                admit_capacity=args.admit_capacity, **kw)
    else:
        srv = LinsysServer(store, **kw)

    rng = np.random.default_rng(args.seed)
    fps, systems = [], []
    for i in range(args.systems):
        sys_ = linsys.conditioned_gaussian(n=args.n, m=args.workers,
                                           cond=args.cond, seed=args.seed + i)
        fp = srv.register(sys_)
        fps.append(fp)
        systems.append(sys_)
        print(f"registered system {i}: N={sys_.N} n={sys_.n} m={sys_.m} "
              f"fingerprint {fp[:16]}...")

    picks = [int(rng.integers(0, args.systems))
             for _ in range(args.requests)]
    rhss = [rng.standard_normal(systems[i].N) for i in picks]

    n_bad = 0
    if args.async_:
        # open-loop Poisson arrivals: submission times never wait on
        # completions, so saturation shows up as queueing/shedding
        if args.arrival_rate > 0:
            arr = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                            size=args.requests))
        else:
            arr = np.zeros(args.requests)
        t0 = time.time()
        with srv:
            tickets = []
            for i in range(args.requests):
                wait = t0 + arr[i] - time.time()
                if wait > 0:
                    time.sleep(wait)
                tickets.append(srv.submit(fps[picks[i]], rhss[i]))
            results = [t.result() for t in tickets]
        dt = time.time() - t0
        n_shed = 0
        for r in results:
            if isinstance(r, Shed):
                n_shed += 1
                continue
            n_bad += r.residual >= args.tol
        rep = srv.latency_report()
        print(f"async pipeline (depth {srv.pipeline_depth}, capacity "
              f"{srv.admit_capacity}): {srv.stats.served} served / "
              f"{n_shed} shed over {srv.stats.batches} batches")
        print(f"latency p50/p95/p99 {rep['p50_ms']:.0f}/{rep['p95_ms']:.0f}"
              f"/{rep['p99_ms']:.0f} ms  mean {rep['mean_ms']:.0f} ms")
    else:
        for i in range(args.requests):
            srv.submit(fps[picks[i]], rhss[i])
        t0 = time.time()
        while True:
            tb = time.time()
            batch = srv.step()
            if not batch:
                break
            bt = time.time() - tb
            worst = max(r.residual for r in batch)
            n_bad += sum(r.residual >= args.tol for r in batch)
            print(f"batch {srv.stats.batches}: {len(batch)} request(s) "
                  f"[{batch[0].fp[:8]}...] in {bt * 1e3:7.1f} ms  "
                  f"worst residual {worst:.2e}"
                  + ("  (warm)" if batch[0].warm else ""))
        dt = time.time() - t0

    st = srv.stats
    print(f"served {st.served} requests in {dt:.2f}s "
          f"({st.served / dt:.1f} RHS/s, padding excluded: "
          f"{st.padded} pad slot(s) over {st.batches} batches)")
    print(f"factor store: {store.stats}")
    print(f"executors built: {st.executor_builds}  "
          f"jit cache entries: {srv.jit_cache_size()}  "
          f"warm batches: {st.warm_batches}")
    if n_bad:
        print(f"WARNING: {n_bad} request(s) above tol={args.tol:.0e} — "
              f"raise --iters")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
