import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory/cost/collective analysis.

The two lines above MUST precede every other import — jax locks the device
count at first initialization (see the multi-pod dry-run contract).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                   # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --multi-pod --json out.json
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro import configs
from repro.launch import analysis, cells, mesh as mesh_lib


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             compile_: bool = True, verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    ok, reason = cells.applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, meta = cells.lower_cell(arch, shape_name, mesh, cfg=cfg)
    rec["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        rec.update(status="lowered", **meta)
        return rec
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    roof = analysis.from_compiled(
        f"{arch}/{shape_name}", mesh.devices.shape, compiled,
        meta["model_flops"])
    rec.update(status="ok", **meta, roofline=roof.row(),
               collectives={k: v for k, v in roof.collectives.items() if v})
    if verbose:
        r = roof.row()
        print(f"  {arch:22s} {shape_name:12s} {rec['mesh']:8s} "
              f"compile {rec['compile_s']:6.1f}s  "
              f"t_comp {r['t_compute']:.3e}  t_mem {r['t_memory']:.3e}  "
              f"t_coll {r['t_collective']:.3e}  -> {r['bottleneck']}",
              flush=True)
    return rec


def run_solver_cell(*, multi_pod: bool, dtype: str = "float64",
                    n: int = 1 << 20, p: int = 2048) -> dict:
    """Roofline of one distributed APC iteration (the paper's workload) on
    the production mesh.  dtype float64 = paper-faithful (CPU LAPACK
    semantics); float32 = the beyond-paper TPU configuration (§Perf) —
    same algorithm, half the wire/HBM bytes, f64 reserved for the one-time
    spectral analysis.
    """
    import jax.numpy as jnp
    from repro.core import distributed
    from repro.launch import analysis

    if dtype == "float64":       # else SDS silently canonicalizes to f32
        jax.config.update("jax_enable_x64", True)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    worker_axes = ("pod", "data") if multi_pod else ("data",)
    m = 1
    for a in worker_axes:
        m *= mesh.shape[a]
    solver = distributed.make_sharded_apc(
        mesh, worker_axes=worker_axes, model_axis="model",
        gamma=1.26, eta=1.85)
    dt = jnp.dtype(dtype)
    sds = lambda shape: jax.ShapeDtypeStruct(shape, dt)
    t0 = time.time()
    with mesh:
        lowered = solver.step_fn().lower(
            sds((m, p, n)), sds((m, p, p)), sds((m, n)), sds((n,)))
        compiled = lowered.compile()
    # useful work: the paper's 2pn multiply-adds per worker per iteration
    model_flops = 2.0 * (2.0 * p * n) * m
    roof = analysis.from_compiled(
        f"apc-solver/{dtype}", mesh.devices.shape, compiled, model_flops)
    rec = {"arch": "apc-solver", "shape": f"iter_n{n}_p{p}_{dtype}",
           "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok",
           "model_flops": model_flops,
           "compile_s": round(time.time() - t0, 1),
           "roofline": roof.row(),
           "collectives": {k: v for k, v in roof.collectives.items() if v}}
    r = roof.row()
    print(f"  apc-solver {dtype:8s} {rec['mesh']:8s} m={m} p={p} n={n}  "
          f"t_comp {r['t_compute']:.3e}  t_mem {r['t_memory']:.3e}  "
          f"t_coll {r['t_collective']:.3e}  -> {r['bottleneck']}",
          flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--json", default=None, help="write records to this file")
    ap.add_argument("--no-compile", action="store_true",
                    help="lower only (fast structural check)")
    ap.add_argument("--solver", action="store_true",
                    help="run the APC-solver roofline cells instead of the "
                         "LM cells (float64 paper-faithful + float32)")
    args = ap.parse_args(argv)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.solver:
        records, failures = [], 0
        for mp in meshes:
            for dtype in ("float64", "float32"):
                try:
                    records.append(run_solver_cell(multi_pod=mp, dtype=dtype))
                except (ValueError, TypeError, KeyError, AttributeError,
                        NotImplementedError, RuntimeError) as e:
                    # RuntimeError covers XlaRuntimeError (lowering/compile
                    # failures); anything outside this set — including
                    # KeyboardInterrupt/SystemExit — is a system bug and
                    # must propagate, not read as a dry-run diagnostic
                    print(f"solver cell FAILED [{type(e).__name__}]",
                          file=sys.stderr)
                    traceback.print_exc()
                    failures += 1
        if args.json:
            with open(args.json, "w") as f:
                json.dump(records, f, indent=1)
        print(f"\nsolver dry-run: {len(records)} ok, {failures} FAILED")
        return 1 if failures else 0

    archs = [args.arch] if args.arch else configs.ARCHS
    shapes = [args.shape] if args.shape else list(cells.SHAPES)

    records, failures = [], 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   compile_=not args.no_compile)
                except (ValueError, TypeError, KeyError, AttributeError,
                        NotImplementedError, RuntimeError) as e:
                    # the cell failing to lower/compile IS the diagnostic
                    # this tool exists to surface; record class + repr so
                    # the JSON names the failure type
                    print(f"cell FAILED [{type(e).__name__}]",
                          file=sys.stderr)
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED",
                           "error_type": type(e).__name__,
                           "error": repr(e)}
                    failures += 1
                records.append(rec)
                if rec["status"] == "skipped":
                    print(f"  {arch:22s} {shape:12s} skipped: "
                          f"{rec['reason'][:60]}...", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(r["status"] in ("ok", "lowered") for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {failures} FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
