"""Distributed solve driver (the paper's workload as a service).

Partitions a linear system across workers, runs ANY registered solver from
``repro.solvers`` (APC by default) with its auto-tuned optimal parameters,
monitors the residual, and checkpoints the solver state for restart; a
checkpointed run resumes via ``--resume`` (warm start from the saved state).
``--use-mesh`` runs the same method through the shard_map mesh backend on
however many devices exist (force more with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``); ``--x64/--no-x64``
pins the float width explicitly so checkpoint dtypes are reproducible
across resumes.  ``--redundancy r`` (projection family, either backend)
replicates blocks r-redundantly for straggler tolerance, and
``--straggler-sim RATE`` stalls one random worker per iteration with that
probability — the run still matches the no-failure one exactly
(``repro.solvers.redundant``).

Usage:
    PYTHONPATH=src python -m repro.launch.solve --problem std_gaussian \
        --workers 4 --iters 500 --method apc
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import solvers
from repro.core import spectral
from repro.checkpoint import ckpt
from repro.data import linsys
from repro.launch import mesh as mesh_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="std_gaussian",
                    choices=sorted(linsys.ALL_PROBLEMS))
    ap.add_argument("--method", default="apc", choices=solvers.available(),
                    help="registered solver")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--redundancy", type=int, default=1,
                    help="r-redundant blocks for straggler tolerance "
                         "(projection-family methods, local or mesh)")
    ap.add_argument("--straggler-sim", type=float, default=0.0,
                    metavar="RATE",
                    help="per-iteration probability that one random worker "
                         "stalls (needs --redundancy >= 2 to stay covered)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--store-dir", default=None,
                    help="disk tier for the factor store — cached "
                         "factorizations survive restarts, so a resumed "
                         "run's prepare becomes a disk hit")
    ap.add_argument("--resume", action="store_true",
                    help="warm-start from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--use-mesh", action="store_true",
                    help="run --method through the shard_map mesh backend")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the per-worker update through the Pallas "
                         "block-projection kernels (projection-family "
                         "methods, local or mesh backend)")
    ap.add_argument("--x64", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="float64 math (default on; checkpoints record the "
                         "resulting dtypes — resume with the same setting)")
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", args.x64)
    sys_ = linsys.ALL_PROBLEMS[args.problem](seed=args.seed)
    # re-partition to the requested worker count, preserving the system's
    # mode (least-squares stays least-squares) and sparse structure
    was_sparse = sys_.is_sparse
    A, b = sys_.dense()
    from repro.core.partition import as_sparse, partition, pad_to_blocks
    A, b = pad_to_blocks(np.asarray(A), np.asarray(b), args.workers)
    sys_ = partition(A, b, args.workers, x_true=sys_.x_true, mode=sys_.mode)
    if was_sparse:
        sys_ = as_sparse(sys_)

    solver = solvers.get(args.method)
    params, rho = solver.analyze(sys_)   # one spectral pass for both
    print(f"problem {args.problem}: N={sys_.N} n={sys_.n} m={sys_.m}  "
          f"method={args.method}")
    print(f"optimal params {({k: round(v, 4) for k, v in params.items()})}"
          + (f"  rho={rho:.6f} "
             f"(T={spectral.convergence_time(rho):.1f} iters/decade)"
             if rho is not None else ""))

    t0 = time.time()
    if args.redundancy > 1 and not solver.supports_redundancy:
        ap.error(f"--redundancy needs a projection-family method "
                 f"(apc/consensus/cimmino); {args.method!r} does not "
                 "support redundant execution")
    alive_schedule = None
    if args.straggler_sim > 0.0:
        if args.redundancy < 2:
            ap.error("--straggler-sim needs --redundancy >= 2 (a stalled "
                     "worker is unrecoverable without a redundant holder)")
        rng = np.random.default_rng(args.seed)
        m, rate = sys_.m, args.straggler_sim

        def alive_schedule(t):
            a = np.ones(m, bool)
            if rng.random() < rate:
                a[rng.integers(0, m)] = False
            return a

    # ALL factor acquisition goes through the content-addressed store:
    # the solve's `factors is None` branch is a cache lookup (memory LRU +
    # the --store-dir disk tier), the resume path reuses the SAME entry
    # for its restore template, and both backends accept the host factors
    # (the redundant layer replicates them itself).  A resume that has to
    # re-prepare is counted as a cache miss (store.stats.resume_misses)
    # instead of silently repaying the b-independent work.
    store = solvers.FactorStore(directory=args.store_dir)
    warm = None
    if args.resume:
        if not args.ckpt_dir:
            ap.error("--resume requires --ckpt-dir")
        step = ckpt.latest_step(args.ckpt_dir)
        if step is None:
            print(f"WARNING: no checkpoint found in {args.ckpt_dir}; "
                  "starting cold")
        else:
            factors = store.factors(solver, sys_, resume=True, **params)
            probe = solver.init(factors, sys_.b_blocks, params)
            warm = ckpt.restore(args.ckpt_dir, probe)
            print(f"resuming from checkpointed state at iter {step} "
                  f"(factor store: {store.stats})")
    if args.redundancy > 1:
        print(f"redundant execution: r={args.redundancy}"
              + (f", straggler rate {args.straggler_sim}"
                 if args.straggler_sim else ", no simulated stragglers"))
    # the whole execution surface travels on ONE validated plan
    mesh = None
    if args.use_mesh:
        mesh = mesh_lib.solver_mesh_for(sys_.m)
        print(f"mesh backend: {tuple(mesh.shape.items())} over "
              f"{len(jax.devices())} device(s)")
    plan = solvers.ExecutionPlan(
        backend="mesh" if args.use_mesh else "local", mesh=mesh,
        kernel=args.use_kernel, redundancy=args.redundancy,
        alive_schedule=alive_schedule, warm_state=warm, store=store)
    res = solver.solve(sys_, iters=args.iters, plan=plan, **params)
    xbar, final_res = res.x, float(res.residuals[-1])
    if res.iters_to_tol != -1:
        print(f"reached residual < {res.tol:.0e} after "
              f"{res.iters_to_tol} iters")
    if args.ckpt_dir:
        total = int(res.state.t) if hasattr(res.state, "t") else args.iters
        ckpt.save(args.ckpt_dir, total, res.state)
        print(f"solver state checkpointed at iter {total}")

    err = (float(np.linalg.norm(np.asarray(xbar) - np.asarray(sys_.x_true)) /
                 np.linalg.norm(np.asarray(sys_.x_true)))
           if sys_.x_true is not None else float("nan"))
    print(f"done in {time.time()-t0:.2f}s: residual {final_res:.3e}  "
          f"rel-error {err:.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
