"""Distributed APC solve driver (the paper's workload as a service).

Partitions a linear system across the mesh's data axis, runs shard_map APC
with Theorem-1 optimal parameters, monitors the residual, and checkpoints
the solver state for restart.

Usage:
    PYTHONPATH=src python -m repro.launch.solve --problem std_gaussian \
        --workers 4 --iters 500
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import apc, coding, distributed, spectral
from repro.checkpoint import ckpt
from repro.data import linsys
from repro.launch import mesh as mesh_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="std_gaussian",
                    choices=sorted(linsys.ALL_PROBLEMS))
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--redundancy", type=int, default=1,
                    help="r-redundant blocks for straggler tolerance")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-mesh", action="store_true",
                    help="run the shard_map path on a device mesh")
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", True)
    sys_ = linsys.ALL_PROBLEMS[args.problem](seed=args.seed)
    # re-partition to the requested worker count
    A, b = sys_.dense()
    from repro.core.partition import partition, pad_to_blocks
    A, b = pad_to_blocks(np.asarray(A), np.asarray(b), args.workers)
    sys_ = partition(A, b, args.workers, x_true=sys_.x_true)

    X = spectral.x_matrix(sys_)
    mu_min, mu_max = spectral.mu_extremes(X)
    prm = spectral.apc_optimal(mu_min, mu_max)
    print(f"problem {args.problem}: N={sys_.N} n={sys_.n} m={sys_.m}  "
          f"kappa(X)={mu_max/mu_min:.3e}")
    print(f"optimal gamma={prm.gamma:.4f} eta={prm.eta:.4f} rho={prm.rho:.6f} "
          f"(T={spectral.convergence_time(prm.rho):.1f} iters/decade)")

    t0 = time.time()
    if args.redundancy > 1:
        xbar, residuals = coding.solve_redundant(
            sys_, args.redundancy, iters=args.iters,
            gamma=prm.gamma, eta=prm.eta)
        final_res = residuals[-1]
    elif args.use_mesh:
        mesh = mesh_lib.solver_mesh(args.workers)
        xbar, final_res = distributed.solve_on_mesh(
            mesh, sys_, iters=args.iters, gamma=prm.gamma, eta=prm.eta)
    else:
        res = apc.solve(sys_, iters=args.iters, gamma=prm.gamma, eta=prm.eta)
        xbar, final_res = res.x, float(res.residuals[-1])
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.iters, res.state)
            print(f"solver state checkpointed at iter {args.iters}")

    err = (float(np.linalg.norm(np.asarray(xbar) - np.asarray(sys_.x_true)) /
                 np.linalg.norm(np.asarray(sys_.x_true)))
           if sys_.x_true is not None else float("nan"))
    print(f"done in {time.time()-t0:.2f}s: residual {final_res:.3e}  "
          f"rel-error {err:.3e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
