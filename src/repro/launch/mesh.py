"""Mesh construction for the production pods.

Everything is a FUNCTION — importing this module never touches jax device
state, so tests/benches that want a single CPU device can import it safely.

Production target: TPU v5e pods, 256 chips each, mesh (16 data, 16 model);
multi-pod doubles up with a leading "pod" axis used as a second data-
parallel axis (DP across DCN, TP kept inside the pod ICI domain).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def solver_mesh(workers: int, model: int = 1) -> Mesh:
    """Mesh for the APC solver: 'data' = workers, 'model' = column shards."""
    return jax.make_mesh((workers, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
