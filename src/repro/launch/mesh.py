"""Mesh construction for the production pods.

Everything is a FUNCTION — importing this module never touches jax device
state, so tests/benches that want a single CPU device can import it safely.

Production target: TPU v5e pods, 256 chips each, mesh (16 data, 16 model);
multi-pod doubles up with a leading "pod" axis used as a second data-
parallel axis (DP across DCN, TP kept inside the pod ICI domain).

Explicit axis types (``jax.sharding.AxisType``) only exist on newer JAX
releases; on older installs ``make_compat_mesh`` silently falls back to the
default (auto) axis semantics so every driver keeps importing and running.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x — optional on the installed runtime
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_compat_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types when supported."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(shape))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_compat_mesh((data, model), ("data", "model"))


def solver_mesh(workers: int, model: int = 1) -> Mesh:
    """Mesh for the solver backend: 'data' = workers, 'model' = col shards."""
    return make_compat_mesh((workers, model), ("data", "model"))


def solver_mesh_for(workers: int, model: int = 1) -> Mesh:
    """Largest solver mesh the available devices support.

    The 'data' axis is the largest divisor of ``workers`` that fits the
    device count (the backend shards the m worker blocks over it, so it
    must divide m) — on a single-device host this degrades to a (1, 1)
    mesh and the backend still runs, just unsharded.
    """
    budget = max(1, len(jax.devices()) // max(1, model))
    data = max(d for d in range(1, workers + 1)
               if workers % d == 0 and d <= budget)
    return make_compat_mesh((data, model), ("data", "model"))
