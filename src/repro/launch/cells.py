"""Dry-run cells: (architecture × input shape) → lowered/compiled programs.

This module is import-safe (does not force a device count); the entrypoint
that needs 512 placeholder devices is ``launch/dryrun.py``.

Shapes (assigned set):
    train_4k      seq 4096,   global_batch 256   -> train_step
    prefill_32k   seq 32768,  global_batch 32    -> serve_step (prefill)
    decode_32k    seq 32768,  global_batch 128   -> serve_step (1 new token)
    long_500k     seq 524288, global_batch 1     -> serve_step (1 new token,
                  SSM/hybrid only — quadratic-KV archs are skipped)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model, sharding
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro import configs


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason if skipped."""
    sp = SHAPES[shape_name]
    if sp.name == "long_500k" and not cfg.supports_long_decode:
        return False, ("full-attention arch: 512k dense-KV decode is the "
                       "quadratic regime the shape list excludes "
                       "(DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocated)
# ---------------------------------------------------------------------------


def _batch_divisible(mesh: Optional[Mesh], rules: sharding.Rules,
                     B: int) -> bool:
    if mesh is None:
        return True
    axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    n = 1
    for a in axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return B % n == 0


def input_specs(cfg: ModelConfig, shape_name: str, rules: sharding.Rules,
                mesh: Optional[Mesh] = None):
    """Returns (sds_pytree, pspec_pytree) for the step function's inputs
    beyond params/opt-state (i.e. the batch / cache / token).

    If the global batch does not divide the data axes (long_500k has B=1),
    batch dims degrade to replicated — jit in_shardings require exact
    divisibility."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    if not _batch_divisible(mesh, rules, B):
        rules = dataclasses.replace(rules, batch=())
    bspec = sharding.to_pspec(("batch", None), rules)
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)

    if sp.kind == "train":
        sds = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
               "labels": jax.ShapeDtypeStruct((B, S), i32)}
        specs = {"tokens": bspec, "labels": bspec}
        if cfg.frontend == "vision":
            sds["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), dt)
            specs["patches"] = sharding.to_pspec(("batch", None, None), rules)
        if cfg.frontend == "audio":
            sds["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt)
            specs["frames"] = sharding.to_pspec(("batch", None, None), rules)
        return sds, specs

    cache_ab = model.cache_abstract(cfg, B, S)
    cache_sds = sharding.sds_tree(cache_ab, dt)
    cache_specs = sharding.pspec_tree(cache_ab, rules)

    if sp.kind == "prefill":
        sds = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        specs = {"tokens": bspec}
        if cfg.frontend == "vision":
            sds["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), dt)
            specs["patches"] = sharding.to_pspec(("batch", None, None), rules)
        if cfg.frontend == "audio":
            sds["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt)
            specs["frames"] = sharding.to_pspec(("batch", None, None), rules)
        return {"batch": sds, "cache": cache_sds}, \
               {"batch": specs, "cache": cache_specs}

    # decode: one token against a cache of length seq_len
    sds = {"token": jax.ShapeDtypeStruct((B, 1), i32),
           "cache": cache_sds,
           "cache_len": jax.ShapeDtypeStruct((), i32)}
    specs = {"token": bspec, "cache": cache_specs, "cache_len": P()}
    return sds, specs


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, rules: sharding.Rules,
                     acfg: Optional[adamw.AdamWConfig] = None):
    acfg = acfg or adamw.AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch, rules=rules))(params)
        new_params, new_state = adamw.update(acfg, grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    return train_step


def build_prefill_step(cfg: ModelConfig, rules: sharding.Rules):
    def serve_step(params, batch, cache):
        return model.prefill(cfg, params, batch, cache, rules=rules)
    return serve_step


def build_decode_step(cfg: ModelConfig, rules: sharding.Rules):
    def serve_step(params, token, cache, cache_len):
        return model.decode_step(cfg, params, token, cache, cache_len,
                                 rules=rules)
    return serve_step


# ---------------------------------------------------------------------------
# Lower + compile one cell on a mesh
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               cfg: Optional[ModelConfig] = None):
    """Lower (and return, uncompiled) the cell's step on `mesh`.

    Returns (lowered, meta) where meta carries analytic FLOPs for §Roofline.
    """
    cfg = cfg or configs.get(arch)
    ok, reason = applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) skipped: {reason}")
    rules = sharding.rules_for_mesh(mesh)
    sp = SHAPES[shape_name]
    dt = jnp.dtype(cfg.dtype)

    params_ab = model.model_abstract(cfg)
    params_sds = sharding.sds_tree(params_ab, dt)
    params_specs = sharding.pspec_tree(params_ab, rules)
    ns = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))

    in_sds, in_specs = input_specs(cfg, shape_name, rules, mesh)
    out_rules = rules
    if not _batch_divisible(mesh, rules, sp.global_batch):
        out_rules = dataclasses.replace(rules, batch=())

    with mesh:
        if sp.kind == "train":
            step = build_train_step(cfg, rules)
            opt_sds = adamw.abstract_state(params_sds)
            opt_specs = adamw.state_pspecs(params_specs)
            lowered = jax.jit(
                step,
                in_shardings=(ns(params_specs), ns(opt_specs), ns(in_specs)),
                out_shardings=(ns(params_specs), ns(opt_specs),
                               NamedSharding(mesh, P())),
            ).lower(params_sds, opt_sds, in_sds)
        elif sp.kind == "prefill":
            step = build_prefill_step(cfg, rules)
            logits_spec = NamedSharding(
                mesh, sharding.to_pspec(("batch", None, "tensor"), out_rules))
            lowered = jax.jit(
                step,
                in_shardings=(ns(params_specs), ns(in_specs["batch"]),
                              ns(in_specs["cache"])),
                out_shardings=(logits_spec, ns(in_specs["cache"])),
            ).lower(params_sds, in_sds["batch"], in_sds["cache"])
        else:
            step = build_decode_step(cfg, rules)
            logits_spec = NamedSharding(
                mesh, sharding.to_pspec(("batch", None, "tensor"), out_rules))
            lowered = jax.jit(
                step,
                in_shardings=(ns(params_specs), ns(in_specs["token"]),
                              ns(in_specs["cache"]),
                              NamedSharding(mesh, P())),
                out_shardings=(logits_spec, ns(in_specs["cache"])),
            ).lower(params_sds, in_sds["token"], in_sds["cache"],
                    in_sds["cache_len"])

    meta = cell_model_flops(cfg, shape_name)
    return lowered, meta


def cell_model_flops(cfg: ModelConfig, shape_name: str) -> dict:
    """Analytic useful FLOPs for the cell (§Roofline MODEL_FLOPS)."""
    sp = SHAPES[shape_name]
    n_active = model.non_embedding_params(cfg, active_only=True)
    tokens = sp.global_batch * (sp.seq_len if sp.kind != "decode" else 1)
    mult = 6 if sp.kind == "train" else 2
    return {
        "arch": cfg.name, "shape": shape_name, "kind": sp.kind,
        "n_params": model.count_params(cfg),
        "n_active_nonembed": n_active,
        "tokens": tokens,
        "model_flops": float(mult) * n_active * tokens,
    }
