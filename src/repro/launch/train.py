"""Training driver: config → mesh → data → train loop with checkpointing.

Runs at any scale — the same loop drives the CPU smoke examples and the
multi-pod config (where the mesh comes from launch/mesh.py and each host
feeds its batch shard).  Fault tolerance: atomic checkpoints every
``--ckpt-every`` steps and exact resume (data is a pure function of step).

Usage (CPU example — reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import ckpt
from repro.data import synthetic
from repro.launch import mesh as mesh_lib
from repro.models import model, sharding
from repro.optim import adamw, schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 + error-feedback gradient compression "
                         "(simulated roundtrip of the DP all-reduce payload)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = mesh_lib.make_host_mesh(args.data, args.model_axis)
    rules = sharding.rules_for_mesh(mesh)
    acfg = adamw.AdamWConfig(lr=args.lr)

    params_ab = model.model_abstract(cfg)
    dt = jnp.dtype(cfg.dtype)
    params = sharding.init_tree(params_ab, jax.random.PRNGKey(0), dt)
    opt_state = adamw.init(params)

    dcfg = synthetic.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                global_batch=args.batch)

    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            params, opt_state = ckpt.restore(
                args.ckpt_dir, (params, opt_state), step=last)
            start_step = last
            print(f"resumed from step {last}")

    def loss_of(p, batch):
        b = dict(batch)
        if cfg.frontend == "vision":
            b["patches"] = jnp.zeros(
                (batch["tokens"].shape[0], cfg.num_patches, cfg.d_model), dt)
        if cfg.frontend == "audio":
            b["frames"] = jnp.zeros(
                (batch["tokens"].shape[0], cfg.encoder_seq, cfg.d_model), dt)
        return model.loss_fn(cfg, p, b, rules=rules)

    from repro.optim import compress as compress_mod
    err_state = compress_mod.init_error(params) if args.compress_grads else None

    @jax.jit
    def train_step(params, opt_state, batch, lr_scale, err):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        if err is not None:
            grads, err = compress_mod.compress_decompress(grads, err)
        new_params, new_state = adamw.update(acfg, grads, opt_state, params,
                                             lr_scale=lr_scale)
        return new_params, new_state, loss, err

    t0 = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = synthetic.make_batch(dcfg, step)
            lr_s = schedule.linear_warmup_cosine(
                jnp.asarray(step, jnp.float32), warmup=max(args.steps // 10, 1),
                total=args.steps)
            params, opt_state, loss, err_state = train_step(
                params, opt_state, batch, lr_s, err_state)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {float(loss):.4f}  "
                      f"({time.time()-t0:.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
                print(f"checkpoint -> {path}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
