"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds (§Roofline):

    compute    = HLO_FLOPs            / (chips × PEAK_FLOPS)
    memory     = HLO_bytes            / (chips × HBM_BW)
    collective = collective_bytes     / (chips × ICI_BW)

``compiled.cost_analysis()`` counts while-loop (scan) bodies ONCE — wrong by
the layer count for scanned models (verified empirically; see
tests/test_analysis.py).  So this module implements a structured HLO cost
walker: it parses the post-optimization HLO text into computations, costs
each op (dot FLOPs from operand shapes + contracting dims, elementwise from
output sizes, fusion bytes from the fusion boundary), and multiplies loop
bodies by their ``known_trip_count``.  The same walk attributes collective
bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), trip-count weighted.

The walker is per-device: XLA post-SPMD-partitioning HLO is the per-device
program, so totals are multiplied by the device count for the whole-program
view (we report per-device terms divided by per-chip peak, which is the
same thing).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (conservative: 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\]{},\s/]*?\)?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    var: str
    shape: str
    opcode: str
    rest: str            # operands + attributes (raw tail of the line)

    def raw_operands(self) -> List[str]:
        # self.rest is the text AFTER "opcode(" — we start inside the parens.
        # Commas inside [dims] / {layout} annotations are not separators.
        # Parsed once per Op (cost_computation queries operands repeatedly).
        cached = self.__dict__.get("_raw_operands")
        if cached is not None:
            return cached
        depth, brackets, cur, out = 1, 0, "", []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out.append(cur)
                    break
            elif ch in "[{":
                brackets += 1
            elif ch in "]}":
                brackets -= 1
            if ch == "," and depth == 1 and brackets == 0:
                out.append(cur)
                cur = ""
            else:
                cur += ch
        result = [o.strip() for o in out if o.strip()]
        self.__dict__["_raw_operands"] = result
        return result

    def operands(self) -> List[str]:
        # Bare variable names.  Depending on the XLA version, operands print
        # either as "%name" or as "f32[128,256]{1,0} %name" — keep the last
        # token so both resolve against the symbol table.
        cached = self.__dict__.get("_operand_names")
        if cached is not None:
            return cached
        out = []
        for o in self.raw_operands():
            toks = o.split()
            out.append((toks[-1] if toks else o).lstrip("%"))
        self.__dict__["_operand_names"] = out
        return out

    def operand_shape(self, i: int, symtab: Dict[str, str]) -> str:
        """Shape text of operand i: inline if printed, else via symtab."""
        raw = self.raw_operands()
        if i >= len(raw):
            return ""
        if _SHAPE_RE.search(raw[i]):
            return raw[i]
        return symtab.get(self.operands()[i], "")


_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "iota", "partition-id", "replica-id",
              "rng-bit-generator", "optimization-barrier"}


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    current = None
    comment = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        s = comment.sub("", line).rstrip()
        if not s:
            continue
        mc = _COMP_RE.match(s.strip())
        if mc and s.strip().endswith("{"):
            current = mc.group(2)
            comps[current] = []
            continue
        if s.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        mo = _OP_RE.match(s)
        if mo:
            comps[current].append(
                Op(var=mo.group(1), shape=mo.group(2).strip(),
                   opcode=mo.group(3), rest=mo.group(4)))
    return comps


_ATTR_RE = {
    "lhs_contract": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "trip": re.compile(r'known_trip_count\D*?(\d+)'),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "cond": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult

    def coll_total(self) -> float:
        return float(sum(self.coll.values()))


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.shape)
    k = 1
    m = _ATTR_RE["lhs_contract"].search(op.rest)
    if m:
        dims = _first_shape_dims(op.operand_shape(0, symtab))
        for i in m.group(1).split(","):
            if i != "" and int(i) < len(dims):
                k *= dims[int(i)]
    return 2.0 * out_elems * k


def cost_computation(name: str, comps: Dict[str, List[Op]],
                     cache: Dict[str, Cost]) -> Cost:
    if name in cache:
        return cache[name]
    total = Cost()
    symtab = {op.var: op.shape for op in comps.get(name, [])}
    for op in comps.get(name, []):
        oc = op.opcode
        if oc in _ZERO_COST:
            continue
        if oc == "while":
            m = _ATTR_RE["trip"].search(op.rest)
            trip = int(m.group(1)) if m else 1
            for key in ("body", "cond"):
                mb = _ATTR_RE[key].search(op.rest)
                if mb and mb.group(1) in comps:
                    total.add(cost_computation(mb.group(1), comps, cache),
                              trip)
            continue
        if oc == "fusion":
            mb = _ATTR_RE["calls"].search(op.rest)
            called = comps.get(mb.group(1)) if mb else None
            if called is not None:
                inner = cost_computation(mb.group(1), comps, cache)
                total.flops += inner.flops
                for k in COLLECTIVES:
                    total.coll[k] += inner.coll[k]
            # bytes at the fusion boundary; an operand whose in-fusion
            # parameter is consumed ONLY by slicing ops contributes its
            # slice windows, not the whole array (stacked scan weights!).
            total.bytes += _shape_bytes(op.shape)
            operand_names = op.operands()
            param_var = {}
            if called is not None:
                for iop in called:
                    if iop.opcode == "parameter":
                        try:
                            idx = int(iop.rest.split(")")[0])
                            param_var[idx] = iop.var
                        except ValueError:
                            pass
            for i, o in enumerate(operand_names):
                full = _shape_bytes(op.operand_shape(i, symtab))
                if called is not None and i in param_var:
                    pv = param_var[i]
                    consumers = [iop for iop in called
                                 if pv in iop.operands()]
                    if consumers and all(
                            c.opcode in ("dynamic-slice", "slice", "gather")
                            for c in consumers):
                        full = min(full, sum(_shape_bytes(c.shape)
                                             for c in consumers))
                total.bytes += full
            continue
        if oc in ("call", "custom-call", "map", "reduce", "sort", "scatter",
                  "reduce-window", "select-and-scatter", "all-reduce",
                  "reduce-scatter", "all-reduce-start"):
            mb = _ATTR_RE["to_apply"].search(op.rest)
            if mb and mb.group(1) in comps:
                inner = cost_computation(mb.group(1), comps, cache)
                # reducer applied ~once per input element
                n_in = sum(_shape_elems(op.operand_shape(i, symtab))
                           for i in range(len(op.raw_operands()))) or 1
                total.flops += inner.flops * n_in
        if oc == "conditional":
            mb = _ATTR_RE["branches"].search(op.rest)
            if mb:
                branches = [b.strip().lstrip("%")
                            for b in mb.group(1).split(",")]
                costs = [cost_computation(b, comps, cache)
                         for b in branches if b in comps]
                if costs:
                    worst = max(costs, key=lambda c: c.flops)
                    total.add(worst)
            total.bytes += _shape_bytes(op.shape)
            continue
        base = oc.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if not oc.endswith("-done"):
                total.coll[base] += _shape_bytes(op.shape)
            total.bytes += _shape_bytes(op.shape)
            continue
        if oc in ("dot", "dot-general"):
            total.flops += _dot_flops(op, symtab)
        elif oc == "convolution":
            # rough: 2 * out_elems * kernel_elems / out_features
            total.flops += 2.0 * _shape_elems(op.shape)
        else:
            total.flops += _shape_elems(op.shape)   # elementwise estimate
        # ---- bytes: slicing ops touch only the window, not the operand ----
        if oc in ("dynamic-slice", "slice", "gather"):
            total.bytes += 2.0 * _shape_bytes(op.shape)
        elif oc == "dynamic-update-slice":
            upd = _shape_bytes(op.operand_shape(1, symtab))
            total.bytes += 2.0 * upd
        elif oc == "scatter":
            upd = sum(_shape_bytes(op.operand_shape(i, symtab))
                      for i in range(1, len(op.raw_operands())))
            total.bytes += 2.0 * upd
        else:
            total.bytes += _shape_bytes(op.shape)
            for i in range(len(op.raw_operands())):
                total.bytes += _shape_bytes(op.operand_shape(i, symtab))
    cache[name] = total
    return total


def hlo_cost(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = _COMP_RE.match(s)
            if m:
                entry = m.group(2)
                break
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    return cost_computation(entry, comps, {})


# ---------------------------------------------------------------------------
# Roofline record
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Roofline:
    name: str
    mesh_shape: tuple
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float           # whole-program 6·N·D analytic useful work
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def chips(self) -> int:
        return int(np.prod(self.mesh_shape))

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/dispatch/waste detector)."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU bound implied by the dominant term (others
        perfectly overlapped)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def row(self) -> dict:
        return {
            "name": self.name, "chips": self.chips,
            "flops_dev": self.flops_per_device,
            "hbm_bytes_dev": self.hbm_bytes_per_device,
            "coll_bytes_dev": self.collective_bytes_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(name, mesh_shape, compiled, model_flops,
                  hlo_text: Optional[str] = None) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost(text)
    return Roofline(
        name=name, mesh_shape=tuple(mesh_shape),
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.coll_total(),
        model_flops=model_flops,
        collectives={k: v for k, v in cost.coll.items() if v})
