"""Fault tolerance & elasticity runtime.

What runs where:

  * **Checkpoint/restart** — the driver loop (launch/train.py, launch/
    solve.py) saves atomically every ``ckpt_every`` steps via
    checkpoint/ckpt.py and resumes from ``latest_step`` on restart; data
    batches are pure functions of the step counter, so restart is exact.

  * **Heartbeats / straggler detection** — `HeartbeatMonitor` tracks
    per-worker progress timestamps.  In a real deployment these arrive via
    the cluster control plane (GRPC/borglet); here the monitor is driven by
    the solver loop and by fault-injection tests.  Policy: a worker silent
    for > ``timeout`` is marked dead; one slower than ``straggler_factor``×
    median is a straggler.

  * **Straggler mitigation** — with r-redundant blocks
    (repro.solvers.redundant) an iteration closes as soon as a covering
    subset of workers responded: the monitor produces the alive-mask,
    ``redundant.selection_weights`` reweights the master averaging.
    Semantically exact (see solvers/redundant.py docstring), so convergence
    is unaffected.  ``solve(..., alive_schedule=monitor)`` accepts a
    ``HeartbeatMonitor`` directly; its ``drop_set()`` is snapshotted when
    the schedule is lowered at launch, so a long-running deployment keeps
    masks fresh by solving in warm-started segments (one lowering each).

  * **Elastic re-mesh** — for LM training, device loss requires a new mesh:
    `ElasticPlan.shrink` computes the largest (data', model) mesh that fits
    the survivors, keeping the model axis intact (TP degree is a property
    of the checkpointed layout; the data axis is elastic).  The driver then
    restores the last checkpoint onto the new mesh — parameters are saved
    mesh-agnostically (full arrays per leaf), so any mesh can load them.

  * **Rejoin/resync** — a recovered APC worker must refresh its replicas'
    ``x_j`` from a live holder before re-entering the averaging set
    (coding.py invariant); `HeartbeatMonitor.rejoin` models that handshake.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional

import numpy as np


class MembershipEvent(NamedTuple):
    """One fleet-membership transition, as observed by the monitor.

    ``kind`` is ``"died"`` (explicit ``mark_dead`` or a ``sweep`` timeout
    — emitted once per worker until it rejoins), ``"rejoined"`` (a
    previously-dead worker back after the resync handshake), or
    ``"joined"`` (a NEW worker grew the fleet via ``join``).  ``alive``
    is the post-transition alive count — consumers that repartition use
    it without re-deriving monitor state.
    """
    kind: str
    worker: int
    alive: int


@dataclasses.dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout: float = 10.0            # seconds without progress => dead
    straggler_factor: float = 3.0    # x median iteration time => straggler
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)
    _durations: Dict[int, float] = dataclasses.field(default_factory=dict)
    _dead: set = dataclasses.field(default_factory=set)
    _events: List[MembershipEvent] = dataclasses.field(default_factory=list)

    def _emit(self, kind: str, worker: int, now: Optional[float] = None):
        self._events.append(MembershipEvent(
            kind=kind, worker=worker,
            alive=int(self.alive_mask(now).sum())))

    @property
    def dead(self) -> frozenset:
        """Workers currently evicted (sticky until ``rejoin``) — the
        membership truth an in-process driver keys alive masks off
        (heartbeat timeouts need real workers beating; the elastic
        runtime drives beats itself and uses explicit deaths only)."""
        return frozenset(self._dead)

    def poll_events(self) -> List[MembershipEvent]:
        """Drain the membership-event stream (ordered, each transition
        exactly once).  The elastic runtime polls this between solve
        segments and reacts: died -> re-lower the selection weights over
        the survivors, joined/rejoined -> repartition + warm-start."""
        events, self._events = self._events, []
        return events

    def beat(self, worker: int, now: Optional[float] = None,
             duration: Optional[float] = None):
        """Record progress.  A beat never readmits an explicitly-dead
        worker — its replicas may be stale, so readmission goes through the
        ``rejoin`` resync handshake."""
        now = time.monotonic() if now is None else now
        self._last[worker] = now
        if duration is not None:
            self._durations[worker] = duration

    def mark_dead(self, worker: int):
        """Explicitly evict a worker (sticky until ``rejoin``)."""
        if worker not in self._dead:
            self._dead.add(worker)
            self._emit("died", worker)

    def sweep(self, now: Optional[float] = None) -> np.ndarray:
        """Mark every timed-out worker dead and return the alive mask.

        This is the explicit state transition that ``alive_mask`` used to
        perform as a read side effect: once swept, a timed-out worker stays
        dead (even if heartbeats resume) until it ``rejoin``s with a resync.
        """
        now = time.monotonic() if now is None else now
        for w in range(self.n_workers):
            last = self._last.get(w)
            if (last is None or now - last > self.timeout) \
                    and w not in self._dead:
                self._dead.add(w)
                self._emit("died", w, now)
        return self.alive_mask(now)

    def rejoin(self, worker: int, *, resynced: bool):
        """A dead worker may only rejoin after resyncing its block state."""
        if not resynced:
            raise RuntimeError(
                f"worker {worker} must resync replicas before rejoining")
        if worker in self._dead:
            self._dead.discard(worker)
            self._last[worker] = time.monotonic()
            self._emit("rejoined", worker)
        else:
            self._last[worker] = time.monotonic()

    def join(self, *, resynced: bool = True) -> int:
        """Grow the fleet by one NEW worker and return its id.

        Unlike ``rejoin`` (a known worker returning to its old slot), a
        join changes the fleet SIZE — consumers must repartition.  The
        newcomer still owes the resync handshake: it holds no block
        state at all, so admitting it without one would be worse than a
        stale rejoin.
        """
        if not resynced:
            raise RuntimeError(
                "a joining worker must sync block state before admission")
        worker = self.n_workers
        self.n_workers += 1
        self._last[worker] = time.monotonic()
        self._emit("joined", worker)
        return worker

    def alive_mask(self, now: Optional[float] = None) -> np.ndarray:
        """PURE read: alive = not explicitly dead AND beaten within timeout.

        Two consecutive reads (same ``now``) always agree; death becomes
        sticky only through the explicit ``mark_dead`` / ``sweep`` paths.
        """
        now = time.monotonic() if now is None else now
        mask = np.ones(self.n_workers, dtype=bool)
        for w in range(self.n_workers):
            last = self._last.get(w)
            if w in self._dead or last is None or now - last > self.timeout:
                mask[w] = False
        return mask

    def stragglers(self, now: Optional[float] = None) -> np.ndarray:
        """Live workers slower than ``straggler_factor`` x the live median.

        Dead workers' stale durations are excluded from the median — one
        dead-slow worker must not inflate it and mask live stragglers — and
        a dead worker is never itself flagged (it is already excluded via
        the alive mask).
        """
        now = time.monotonic() if now is None else now
        alive = self.alive_mask(now)
        mask = np.zeros(self.n_workers, dtype=bool)
        live = {w: d for w, d in self._durations.items() if alive[w]}
        # quorum over the LIVE fleet: a heavily degraded fleet must not
        # lose straggler detection just because most workers are dead
        if len(live) >= max(2, int(alive.sum()) // 2):
            med = float(np.median(list(live.values())))
            for w, d in live.items():
                if d > self.straggler_factor * med:
                    mask[w] = True
        return mask

    def drop_set(self, now: Optional[float] = None) -> np.ndarray:
        """Workers to exclude this iteration: dead OR straggling (pure).

        ``now`` is resolved ONCE so both terms see the same instant — a
        worker straddling the timeout boundary must not be alive in one
        term and dead in the other within a single read.
        """
        now = time.monotonic() if now is None else now
        return ~self.alive_mask(now) | self.stragglers(now)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Largest legal mesh after losing devices (model axis preserved)."""
    data: int
    model: int
    dropped_hosts: int

    @staticmethod
    def shrink(n_devices_left: int, model: int) -> "ElasticPlan":
        if n_devices_left < model:
            raise RuntimeError(
                f"{n_devices_left} devices cannot sustain TP={model}; "
                "restore needs a smaller-TP checkpoint layout")
        data = n_devices_left // model
        return ElasticPlan(data=data, model=model,
                           dropped_hosts=n_devices_left - data * model)


def covering_ok(alive: np.ndarray, r: int) -> bool:
    """Can an r-redundant cyclic assignment still cover all blocks?

    Block j is lost iff workers {j, j-1, ..., j-r+1 (mod m)} are all dead —
    i.e. r cyclically-consecutive failures.
    """
    alive = np.asarray(alive, dtype=bool)
    m = len(alive)
    dead = ~alive
    if r >= m:
        return bool(alive.any())
    run = 0
    # unwrap: scan 2m to catch wrap-around runs
    for i in range(2 * m):
        run = run + 1 if dead[i % m] else 0
        if run >= r:
            return False
    return True
