from . import fault  # noqa: F401
