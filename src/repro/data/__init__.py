from . import linsys, synthetic  # noqa: F401
