"""Deterministic synthetic LM data pipeline.

Offline container => no real corpora.  The pipeline still exercises every
production concern: deterministic per-step batches (resumable from a step
counter alone — the checkpoint stores only ``step``), host-sharded
generation (each data-parallel host materializes only its shard), and
next-token label shifting.

Sequences are Zipf-distributed token streams with injected n-gram structure
so the loss actually decreases during the example training runs (a pure
uniform stream has constant entropy and makes smoke training look broken).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    ngram: int = 3          # repeat period injecting learnable structure
    seed: int = 1234


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng((cfg.seed, step))


def make_batch(cfg: DataConfig, step: int, *, host_id: int = 0,
               num_hosts: int = 1) -> dict:
    """Deterministic batch for `step`; host slice [host_id] of the global
    batch.  Returns {"tokens", "labels"} with labels next-token shifted."""
    assert cfg.global_batch % num_hosts == 0
    per_host = cfg.global_batch // num_hosts
    rng = _batch_rng(cfg, step)
    # draw the full global batch deterministically, slice this host's rows
    # (cheap: synthetic; a real loader would seek its shard instead).
    z = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)
    # inject n-gram copies: every position j >= ngram copies j-ngram with
    # probability 1/2 — a learnable bigram/trigram structure.
    mask = rng.random((cfg.global_batch, cfg.seq_len + 1)) < 0.5
    toks[:, cfg.ngram:] = np.where(mask[:, cfg.ngram:],
                                   toks[:, :-cfg.ngram], toks[:, cfg.ngram:])
    sl = slice(host_id * per_host, (host_id + 1) * per_host)
    return {"tokens": jnp.asarray(toks[sl, :-1]),
            "labels": jnp.asarray(toks[sl, 1:])}


def batches(cfg: DataConfig, start_step: int = 0, **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield make_batch(cfg, step, **kw)
        step += 1
