"""Linear-system problem generators for the APC experiments.

The paper evaluates on (a) randomly generated Gaussian systems and (b) three
Matrix Market problems (QC324, ORSIRR 1, ASH608).  This container is offline,
so for (b) we build *spectrum-controlled proxies*: synthetic matrices whose
size and condition structure match the published problems.  Both the paper's
published convergence times and ours are reported side by side in
EXPERIMENTS.md; the proxies reproduce the *ordering* and *order-of-magnitude
gaps* of Table 2, which is the paper's claim.

All generators return a ``BlockSystem`` ready for the solvers plus the ground
truth ``x_true`` so relative error (Fig. 2) can be tracked.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax.numpy as jnp

from repro.core.partition import BlockSystem, partition


def _finalize(A: np.ndarray, m: int, rng: np.random.Generator,
              dtype=jnp.float64) -> BlockSystem:
    """Draw x*, form b = A x*, partition into m row blocks."""
    N, n = A.shape
    x_true = rng.standard_normal(n)
    b = A @ x_true
    return partition(jnp.asarray(A, dtype=dtype), jnp.asarray(b, dtype=dtype),
                     m, x_true=jnp.asarray(x_true, dtype=dtype))


# ---------------------------------------------------------------------------
# Random ensembles (paper Table 2 rows 4-6)
# ---------------------------------------------------------------------------


def standard_gaussian(n: int = 500, m: int = 4, *, N: Optional[int] = None,
                      seed: int = 0, dtype=jnp.float64) -> BlockSystem:
    """i.i.d. N(0,1) entries.  Paper: 'STANDARD GAUSSIAN (500x500)'."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    A = rng.standard_normal((N, n))
    return _finalize(A, m, rng, dtype)


def nonzero_mean_gaussian(n: int = 500, m: int = 4, *, mean: float = 1.0,
                          N: Optional[int] = None, seed: int = 0,
                          dtype=jnp.float64) -> BlockSystem:
    """N(mean, 1) entries — the rank-one mean component inflates kappa(A^T A)
    dramatically while kappa(X) stays moderate; this is the regime where the
    paper reports the largest APC gap (Table 2 row 5)."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    A = rng.standard_normal((N, n)) + mean
    return _finalize(A, m, rng, dtype)


def tall_gaussian(N: int = 1000, n: int = 500, m: int = 4, *, seed: int = 0,
                  dtype=jnp.float64) -> BlockSystem:
    """Overdetermined consistent system.  Paper: 'STANDARD TALL GAUSSIAN'."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((N, n))
    return _finalize(A, m, rng, dtype)


# ---------------------------------------------------------------------------
# Spectrum-controlled proxies for the Matrix Market problems
# ---------------------------------------------------------------------------


def _spectrum_matrix(N: int, n: int, singvals: np.ndarray,
                     rng: np.random.Generator) -> np.ndarray:
    """A = U diag(s) V^T with Haar-random U, V and prescribed spectrum."""
    k = min(N, n)
    U, _ = np.linalg.qr(rng.standard_normal((N, k)))
    V, _ = np.linalg.qr(rng.standard_normal((n, k)))
    return (U * singvals) @ V.T


def _log_spectrum(k: int, cond: float) -> np.ndarray:
    """Log-uniformly spaced singular values in [1/cond, 1]."""
    return np.logspace(0.0, -np.log10(cond), k)


@dataclasses.dataclass(frozen=True)
class MatrixMarketProxy:
    name: str
    N: int
    n: int
    cond: float        # target kappa(A) — matches the published problem class
    m: int             # workers used in the paper's figures


# Condition numbers chosen to land kappa(A^T A) in the regime implied by the
# paper's published DGD convergence times (T_DGD ~ kappa(A^T A)/2):
#   QC324:    T_DGD = 1.22e7  -> kappa(A^T A) ~ 2.4e7 -> kappa(A) ~ 5e3
#   ORSIRR1:  T_DGD = 2.98e9  -> kappa(A^T A) ~ 6e9   -> kappa(A) ~ 7.7e4
#   ASH608:   T_DGD = 5.67    -> kappa(A^T A) ~ 9     -> kappa(A) ~ 3
MM_PROXIES = {
    "qc324": MatrixMarketProxy("QC324", 324, 324, 5.0e3, 4),
    "orsirr1": MatrixMarketProxy("ORSIRR 1", 1030, 1030, 7.7e4, 4),
    "ash608": MatrixMarketProxy("ASH608", 608, 188, 3.0, 4),
}


def matrix_market_proxy(key: str, m: Optional[int] = None, *, seed: int = 0,
                        dtype=jnp.float64) -> BlockSystem:
    """Spectrum-matched proxy for a Matrix Market problem (offline stand-in)."""
    spec = MM_PROXIES[key]
    rng = np.random.default_rng(seed)
    N, n = spec.N, spec.n
    m = spec.m if m is None else m
    # pad N up so m | N (duplication strategy documented in pad_to_blocks)
    rem = (-N) % m
    s = _log_spectrum(min(N, n), spec.cond)
    A = _spectrum_matrix(N, n, s, rng)
    if rem:
        idx = rng.integers(0, N, size=rem)
        A = np.concatenate([A, A[idx] * 1.0], axis=0)
    return _finalize(A, m, rng, dtype)


def conditioned_gaussian(n: int, m: int, cond: float, *, seed: int = 0,
                         N: Optional[int] = None,
                         dtype=jnp.float64) -> BlockSystem:
    """Gaussian-basis matrix with exactly prescribed condition number —
    workhorse for convergence-rate sweeps and property tests."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    s = _log_spectrum(min(N, n), cond)
    A = _spectrum_matrix(N, n, s, rng)
    return _finalize(A, m, rng, dtype)


ALL_PROBLEMS = {
    "qc324": lambda seed=0: matrix_market_proxy("qc324", seed=seed),
    "orsirr1": lambda seed=0: matrix_market_proxy("orsirr1", seed=seed),
    "ash608": lambda seed=0: matrix_market_proxy("ash608", seed=seed),
    "std_gaussian": lambda seed=0: standard_gaussian(seed=seed),
    "nonzero_mean": lambda seed=0: nonzero_mean_gaussian(seed=seed),
    "tall_gaussian": lambda seed=0: tall_gaussian(seed=seed),
}
