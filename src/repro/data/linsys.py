"""Linear-system problem generators for the APC experiments.

The paper evaluates on (a) randomly generated Gaussian systems and (b) three
Matrix Market problems (QC324, ORSIRR 1, ASH608).  This container is offline,
so for (b) we build *spectrum-controlled proxies*: synthetic matrices whose
size and condition structure match the published problems.  Both the paper's
published convergence times and ours are reported side by side in
EXPERIMENTS.md; the proxies reproduce the *ordering* and *order-of-magnitude
gaps* of Table 2, which is the paper's claim.

All generators return a ``BlockSystem`` ready for the solvers plus the ground
truth ``x_true`` so relative error (Fig. 2) can be tracked.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax.numpy as jnp

from repro.core.partition import BlockSystem, as_sparse, partition


def _finalize(A: np.ndarray, m: int, rng: np.random.Generator,
              dtype=jnp.float64) -> BlockSystem:
    """Draw x*, form b = A x*, partition into m row blocks.

    The system is consistent BY CONSTRUCTION (b = A x*), so it is tagged
    ``mode="square"`` even when tall — an exact solution exists and the
    plain residual ``‖Ax−b‖/‖b‖`` is the right convergence measure.
    """
    N, n = A.shape
    x_true = rng.standard_normal(n)
    b = A @ x_true
    return partition(jnp.asarray(A, dtype=dtype), jnp.asarray(b, dtype=dtype),
                     m, x_true=jnp.asarray(x_true, dtype=dtype),
                     mode="square")


# ---------------------------------------------------------------------------
# Random ensembles (paper Table 2 rows 4-6)
# ---------------------------------------------------------------------------


def standard_gaussian(n: int = 500, m: int = 4, *, N: Optional[int] = None,
                      seed: int = 0, dtype=jnp.float64) -> BlockSystem:
    """i.i.d. N(0,1) entries.  Paper: 'STANDARD GAUSSIAN (500x500)'."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    A = rng.standard_normal((N, n))
    return _finalize(A, m, rng, dtype)


def nonzero_mean_gaussian(n: int = 500, m: int = 4, *, mean: float = 1.0,
                          N: Optional[int] = None, seed: int = 0,
                          dtype=jnp.float64) -> BlockSystem:
    """N(mean, 1) entries — the rank-one mean component inflates kappa(A^T A)
    dramatically while kappa(X) stays moderate; this is the regime where the
    paper reports the largest APC gap (Table 2 row 5)."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    A = rng.standard_normal((N, n)) + mean
    return _finalize(A, m, rng, dtype)


def tall_gaussian(N: int = 1000, n: int = 500, m: int = 4, *, seed: int = 0,
                  noise: float = 0.0, dtype=jnp.float64) -> BlockSystem:
    """Overdetermined Gaussian system.  Paper: 'STANDARD TALL GAUSSIAN'.

    With ``noise=0`` (default) the system is CONSISTENT by construction
    (``b = A x*``, mode ``"square"``) — the paper's setting.  ``noise > 0``
    adds ``noise * e`` (i.i.d. standard normal ``e``) to ``b``: with
    ``N > n`` the perturbed system is inconsistent almost surely, so it is
    tagged ``mode="least_squares"`` and ``x_true`` becomes the LS optimum
    ``argmin ‖Ax−b‖`` (what the LS-capable solvers converge to).
    """
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((N, n))
    if noise == 0.0:
        return _finalize(A, m, rng, dtype)
    x_star = rng.standard_normal(n)          # same draw order as _finalize
    b = A @ x_star + noise * rng.standard_normal(N)
    x_ls = np.linalg.lstsq(A, b, rcond=None)[0]
    return partition(jnp.asarray(A, dtype=dtype), jnp.asarray(b, dtype=dtype),
                     m, x_true=jnp.asarray(x_ls, dtype=dtype),
                     mode="least_squares")


# ---------------------------------------------------------------------------
# Spectrum-controlled proxies for the Matrix Market problems
# ---------------------------------------------------------------------------


def _spectrum_matrix(N: int, n: int, singvals: np.ndarray,
                     rng: np.random.Generator) -> np.ndarray:
    """A = U diag(s) V^T with Haar-random U, V and prescribed spectrum."""
    k = min(N, n)
    U, _ = np.linalg.qr(rng.standard_normal((N, k)))
    V, _ = np.linalg.qr(rng.standard_normal((n, k)))
    return (U * singvals) @ V.T


def _log_spectrum(k: int, cond: float) -> np.ndarray:
    """Log-uniformly spaced singular values in [1/cond, 1]."""
    return np.logspace(0.0, -np.log10(cond), k)


@dataclasses.dataclass(frozen=True)
class MatrixMarketProxy:
    name: str
    N: int
    n: int
    cond: float        # target kappa(A) — matches the published problem class
    m: int             # workers used in the paper's figures


# Condition numbers chosen to land kappa(A^T A) in the regime implied by the
# paper's published DGD convergence times (T_DGD ~ kappa(A^T A)/2):
#   QC324:    T_DGD = 1.22e7  -> kappa(A^T A) ~ 2.4e7 -> kappa(A) ~ 5e3
#   ORSIRR1:  T_DGD = 2.98e9  -> kappa(A^T A) ~ 6e9   -> kappa(A) ~ 7.7e4
#   ASH608:   T_DGD = 5.67    -> kappa(A^T A) ~ 9     -> kappa(A) ~ 3
MM_PROXIES = {
    "qc324": MatrixMarketProxy("QC324", 324, 324, 5.0e3, 4),
    "orsirr1": MatrixMarketProxy("ORSIRR 1", 1030, 1030, 7.7e4, 4),
    "ash608": MatrixMarketProxy("ASH608", 608, 188, 3.0, 4),
}


def matrix_market_proxy(key: str, m: Optional[int] = None, *, seed: int = 0,
                        dtype=jnp.float64) -> BlockSystem:
    """Spectrum-matched proxy for a Matrix Market problem (offline stand-in)."""
    spec = MM_PROXIES[key]
    rng = np.random.default_rng(seed)
    N, n = spec.N, spec.n
    m = spec.m if m is None else m
    # pad N up so m | N (duplication strategy documented in pad_to_blocks)
    rem = (-N) % m
    s = _log_spectrum(min(N, n), spec.cond)
    A = _spectrum_matrix(N, n, s, rng)
    if rem:
        idx = rng.integers(0, N, size=rem)
        A = np.concatenate([A, A[idx] * 1.0], axis=0)
    return _finalize(A, m, rng, dtype)


def conditioned_gaussian(n: int, m: int, cond: float, *, seed: int = 0,
                         N: Optional[int] = None,
                         dtype=jnp.float64) -> BlockSystem:
    """Gaussian-basis matrix with exactly prescribed condition number —
    workhorse for convergence-rate sweeps and property tests."""
    rng = np.random.default_rng(seed)
    N = n if N is None else N
    s = _log_spectrum(min(N, n), cond)
    A = _spectrum_matrix(N, n, s, rng)
    return _finalize(A, m, rng, dtype)


# ---------------------------------------------------------------------------
# Block-sparse ensembles (ROADMAP item 3a: the Matrix Market problems the
# dense proxies stand in for are themselves sparse)
# ---------------------------------------------------------------------------


def banded_system(n: int = 512, m: int = 4, *, bandwidth: int = 8,
                  seed: int = 0, dtype=jnp.float64) -> BlockSystem:
    """Diagonally-dominant banded system (half-bandwidth ``bandwidth``).

    Each worker block touches only ~``p + 2*bandwidth`` of the ``n``
    columns, so the compressed sparse operand does a small fraction of
    the dense work; dominance keeps the system well conditioned.
    """
    rng = np.random.default_rng(seed)
    A = np.zeros((n, n))
    for off in range(-bandwidth, bandwidth + 1):
        d = rng.standard_normal(n - abs(off))
        A += np.diag(d, k=off)
    A += np.diag(np.abs(A).sum(axis=1) + 1.0)        # dominance
    return as_sparse(_finalize(A, m, rng, dtype))


def block_sparse_system(n: int = 512, m: int = 4, *, density: float = 0.1,
                        seed: int = 0, dtype=jnp.float64) -> BlockSystem:
    """Each worker block supported on its own random ``density * n``-column
    subset (every column covered by at least one block, so the system stays
    structurally square); Gaussian values on the support."""
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density={density} not in (0, 1]")
    rng = np.random.default_rng(seed)
    if n % m:
        raise ValueError(f"m={m} must divide n={n}")
    p = n // m
    w = max(int(round(density * n)), p)
    A = np.zeros((n, n))
    owners = rng.permutation(n).reshape(m, p)        # cover every column
    for i in range(m):
        extra = np.setdiff1d(np.arange(n), owners[i], assume_unique=False)
        pick = np.concatenate(
            [owners[i], rng.choice(extra, size=w - p, replace=False)])
        block = np.zeros((p, n))
        block[:, np.sort(pick)] = rng.standard_normal((p, w))
        A[i * p:(i + 1) * p] = block
    return as_sparse(_finalize(A, m, rng, dtype))


def sparse_matrix_market_proxy(key: str, m: Optional[int] = None, *,
                               bandwidth: int = 8, seed: int = 0,
                               dtype=jnp.float64) -> BlockSystem:
    """Sparse spectrum-controlled proxy for a Matrix Market problem.

    The prescribed log-spaced spectrum sits on the generalized diagonal
    and a banded perturbation well below the smallest singular value adds
    realistic off-diagonal structure, so the condition number stays in
    the published problem's regime while the matrix is genuinely sparse
    (the dense proxies in ``MM_PROXIES`` are Haar-rotated and dense).
    Tall problems (ASH608) duplicate rows to reach ``m | N``, exactly
    like :func:`matrix_market_proxy`.
    """
    spec = MM_PROXIES[key]
    rng = np.random.default_rng(seed)
    N, n = spec.N, spec.n
    m = spec.m if m is None else m
    k = min(N, n)
    s = _log_spectrum(k, spec.cond)
    A = np.zeros((N, n))
    A[np.arange(k), np.arange(k)] = s
    if N > k:                                        # tall: duplicate rows
        A[k:] = A[np.arange(N - k) % k]
    # keep the banded perturbation's spectral norm well under s_min so the
    # prescribed condition number survives (~2*eps*sqrt(2*bandwidth+1))
    eps = 0.02 * s.min()
    rows = np.arange(N)[:, None]
    cols = np.arange(-bandwidth, bandwidth + 1)[None, :] + (
        rows * n) // max(N, 1)
    valid = (cols >= 0) & (cols < n)
    pert = eps * rng.standard_normal(cols.shape) * valid
    np.add.at(A, (np.broadcast_to(rows, cols.shape)[valid],
                  cols[valid]), pert[valid])
    rem = (-A.shape[0]) % m
    if rem:
        idx = rng.integers(0, A.shape[0], size=rem)
        A = np.concatenate([A, A[idx] * 1.0], axis=0)
    return as_sparse(_finalize(A, m, rng, dtype))


ALL_PROBLEMS = {
    "qc324": lambda seed=0: matrix_market_proxy("qc324", seed=seed),
    "orsirr1": lambda seed=0: matrix_market_proxy("orsirr1", seed=seed),
    "ash608": lambda seed=0: matrix_market_proxy("ash608", seed=seed),
    "std_gaussian": lambda seed=0: standard_gaussian(seed=seed),
    "nonzero_mean": lambda seed=0: nonzero_mean_gaussian(seed=seed),
    "tall_gaussian": lambda seed=0: tall_gaussian(seed=seed),
    "tall_noisy": lambda seed=0: tall_gaussian(seed=seed, noise=0.5),
    "banded": lambda seed=0: banded_system(seed=seed),
    "block_sparse": lambda seed=0: block_sparse_system(seed=seed),
    "qc324_sparse": lambda seed=0: sparse_matrix_market_proxy("qc324",
                                                              seed=seed),
    "ash608_sparse": lambda seed=0: sparse_matrix_market_proxy("ash608",
                                                               seed=seed),
}
