"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

The projection-family worker updates, given the precomputed pseudoinverse
factor B_i = A_i^T (A_i A_i^T)^{-1}  (n x p):

APC / consensus (gather + scatter):

    d = xbar - x
    u = A d                  (p,)    gather pass
    y = x + gamma * (d - B u)        scatter pass

Block Cimmino (row projection):

    u = A xbar               (p,)    gather pass
    r = B (b - u)            (n,)    scatter pass

Every oracle is batch-polymorphic exactly like the kernels: row-vector
operands may carry a leading (k,) RHS axis (einsum '...' broadcasting), so
one reference covers the single-RHS and the multi-RHS kernel paths.
"""
from __future__ import annotations

import jax.numpy as jnp


def apc_gather_ref(A, x, xbar):
    """u = A (xbar - x).   A (p, n); x, xbar (n,) or (k, n)."""
    return jnp.einsum("pn,...n->...p", A, xbar - x)


def apc_scatter_ref(B, x, xbar, u, gamma):
    """y = x + gamma * ((xbar - x) - B u).   B (n, p); u (p,) or (k, p)."""
    d = xbar - x
    return x + gamma * (d - jnp.einsum("np,...p->...n", B, u))


def block_projection_ref(A, B, x, xbar, gamma):
    """Full fused worker update: y = x + gamma * P (xbar - x) with
    P = I - B A (note B A == A^T G^{-1} A)."""
    u = apc_gather_ref(A, x, xbar)
    return apc_scatter_ref(B, x, xbar, u, gamma)


def cimmino_gather_ref(A, xbar):
    """u = A xbar.   A (p, n); xbar (n,) or (k, n)."""
    return jnp.einsum("pn,...n->...p", A, xbar)


def cimmino_scatter_ref(B, v):
    """r = B v.   B (n, p); v (p,) or (k, p)."""
    return jnp.einsum("np,...p->...n", B, v)


def cimmino_update_ref(A, B, b, xbar):
    """Full fused row projection: r = B (b - A xbar)."""
    return cimmino_scatter_ref(B, b - cimmino_gather_ref(A, xbar))


def sparse_proj_update_ref(vals, cols, bvals, x, xbar, gamma):
    """Sparse fused APC update on the compressed support (the oracle for
    ``ops.sparse_proj_update``): vals (p, w) on global columns cols (w,);
    bvals (w, p) = B_i compressed to the support.  Returns (y, u)."""
    d = xbar - x
    u = jnp.einsum("pw,...w->...p", vals, d[..., cols])
    c = jnp.einsum("wp,...p->...w", bvals, u)
    y = x + gamma * d
    return y.at[..., cols].add(-gamma * c), u


def sparse_cimmino_update_ref(vals, cols, bvals, b, xbar):
    """Sparse fused Cimmino row projection (the oracle for
    ``ops.sparse_cimmino_update``).  Returns (r, u)."""
    u = jnp.einsum("pw,...w->...p", vals, xbar[..., cols])
    c = jnp.einsum("wp,...p->...w", bvals, b - u)
    r = jnp.zeros_like(xbar).at[..., cols].add(c)
    return r, u
