"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

The APC worker iteration, given the precomputed pseudoinverse factor
B_i = A_i^T (A_i A_i^T)^{-1}  (n x p):

    d = xbar - x
    u = A d                  (p,)    gather pass
    y = x + gamma * (d - B u)        scatter pass

Everything is expressed with 2-D row vectors (1, n) to match the TPU kernel
layout (lane dimension last).
"""
from __future__ import annotations

import jax.numpy as jnp


def apc_gather_ref(A, x, xbar):
    """u = A (xbar - x).   A (p, n); x, xbar (n,). Returns (p,)."""
    return A @ (xbar - x)


def apc_scatter_ref(B, x, xbar, u, gamma):
    """y = x + gamma * ((xbar - x) - B u).   B (n, p)."""
    d = xbar - x
    return x + gamma * (d - B @ u)


def block_projection_ref(A, B, x, xbar, gamma):
    """Full fused worker update: y = x + gamma * P (xbar - x) with
    P = I - B A (note B A == A^T G^{-1} A)."""
    u = apc_gather_ref(A, x, xbar)
    return apc_scatter_ref(B, x, xbar, u, gamma)
