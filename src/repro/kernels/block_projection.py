"""Pallas TPU kernels for the projection family's per-iteration hot spot.

The projection solvers' worker updates are two dependent GEMMs over the
worker's (p × n) block — *memory-bound* (arithmetic intensity ≈ 1 FLOP/byte
over A and B).  The kernels therefore optimize HBM traffic, not FLOPs:

  * ``apc_gather``:  U = (X̄ − X)·Aᵀ with the difference formed on the fly
    from (X, X̄) tiles — D is never materialized in HBM (saves 2kn reads +
    kn writes per iter).
  * ``apc_scatter``: Y = X + γ(D − U·Bᵀ) fusing the rank-p correction with
    the AXPY — again no D round-trip and no intermediate (k, n) buffer.
  * ``cimmino_gather`` / ``cimmino_scatter``: the block-Cimmino row
    projection r = B(b − A x̄) split the same way (gather U = X̄·Aᵀ,
    scatter R = V·Bᵀ) so the third projection solver shares the engine
    instead of rewriting its update onto the APC shape.

All four kernels are **multi-RHS**: the row-vector operands carry a leading
batch axis k (k = 1 for a plain solve), and the k right-hand sides stream
through the SAME VMEM residency of the A/B tile — one HBM read of A serves
the whole batch, which is what makes the ``solve_many`` / ``LinsysServer``
hot path fused rather than k replayed single-RHS kernels.

Tiling: the n axis is cut into lane-aligned BN-tiles (multiple of 128); the
p axis and the k batch live entirely in VMEM (p ≪ n by construction — each
worker's system is highly under-determined — and k is a serving batch).  A
tile of A (p × BN) occupies p·BN·4 bytes ≤ ~2 MB for p ≤ 512, well inside
the ~16 MB VMEM budget, and its (k, BN)·(BN, p) MXU work is aligned when
k, p, BN are multiples of (8, 8, 128).  The BN choice is autotuned by
``ops.pick_bn`` (measured, cached per (p, n, dtype), env-overridable).

The U accumulators use the sequential-grid property of TPU Pallas: every
grid step writes the same (k, p) output block, zero-initialized at j == 0.

All kernels are exposed through ``ops.py`` (padding + autotune + jit + vmap
over workers) and validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 512          # lane-axis tile; multiple of 128


def default_interpret() -> bool:
    """Pallas interpret-mode default, derived from the runtime.

    On a real TPU the kernels compile (interpret=False); everywhere else
    (CPU containers, GPU hosts) they run in interpret mode.  The env var
    ``REPRO_PALLAS_INTERPRET=0/1`` overrides both — e.g. force-compile on
    a TPU-less CI to catch lowering regressions, or force interpret on TPU
    while bisecting a numerics issue.  Resolved when a kernel first traces
    for a given shape; it is not a per-call toggle (pass ``interpret=``
    explicitly for that).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def _acc_dtype(dtype):
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _gather_kernel(x_ref, xbar_ref, a_ref, u_ref, *, acc_dtype):
    """Grid step j: U += (X̄ − X)[:, j·BN:(j+1)·BN] @ A[:, j·BN:(j+1)·BN]ᵀ."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    d = (xbar_ref[...] - x_ref[...]).astype(acc_dtype)      # (k, BN)
    a = a_ref[...].astype(acc_dtype)                        # (p, BN)
    # (k, BN) @ (BN, p) on the MXU; accumulate in acc_dtype.
    u_ref[...] += jax.lax.dot_general(
        d, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype).astype(u_ref.dtype)


def _scatter_kernel(x_ref, xbar_ref, b_ref, u_ref, g_ref, y_ref, *,
                    acc_dtype):
    """Grid step j: Y_j = X_j + γ·(D_j − U·B_jᵀ)."""
    d = xbar_ref[...] - x_ref[...]                          # (k, BN)
    u = u_ref[...].astype(acc_dtype)                        # (k, p)
    b = b_ref[...].astype(acc_dtype)                        # (BN, p)
    bu = jax.lax.dot_general(
        u, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)                   # (k, BN)
    gamma = g_ref[0, 0].astype(acc_dtype)
    y = x_ref[...].astype(acc_dtype) + gamma * (d.astype(acc_dtype) - bu)
    y_ref[...] = y.astype(y_ref.dtype)


def _cim_gather_kernel(xbar_ref, a_ref, u_ref, *, acc_dtype):
    """Grid step j: U += X̄[:, j·BN:(j+1)·BN] @ A[:, j·BN:(j+1)·BN]ᵀ."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    xb = xbar_ref[...].astype(acc_dtype)                    # (k, BN)
    a = a_ref[...].astype(acc_dtype)                        # (p, BN)
    u_ref[...] += jax.lax.dot_general(
        xb, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype).astype(u_ref.dtype)


def _cim_scatter_kernel(v_ref, b_ref, r_ref, *, acc_dtype):
    """Grid step j: R_j = V·B_jᵀ  (the rank-p row projection write-out)."""
    v = v_ref[...].astype(acc_dtype)                        # (k, p)
    b = b_ref[...].astype(acc_dtype)                        # (BN, p)
    r = jax.lax.dot_general(
        v, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)                   # (k, BN)
    r_ref[...] = r.astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def apc_gather(A, x, xbar, *, bn: int = DEFAULT_BN,
               interpret: Optional[bool] = None):
    """U = (X̄ − X) Aᵀ.   A (p, n); X, X̄ (k, n) lane-layout.  n % bn == 0.

    k is the RHS batch (k = 1 for a plain solve): every batch row reuses
    the A tile already resident in VMEM, so one A read serves all k.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = A.shape
    k = x.shape[0]
    assert n % bn == 0, (n, bn)
    acc = _acc_dtype(A.dtype)
    kernel = functools.partial(_gather_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),      # x
            pl.BlockSpec((k, bn), lambda j: (0, j)),      # xbar
            pl.BlockSpec((p, bn), lambda j: (0, j)),      # A
        ],
        out_specs=pl.BlockSpec((k, p), lambda j: (0, 0)),  # U (accumulated)
        out_shape=jax.ShapeDtypeStruct((k, p), A.dtype),
        interpret=interpret,
    )(x, xbar, A)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def apc_scatter(B, x, xbar, u, gamma, *, bn: int = DEFAULT_BN,
                interpret: Optional[bool] = None):
    """Y = X + γ(D − U Bᵀ).   B (n, p); X, X̄ (k, n); U (k, p); γ (1, 1)."""
    if interpret is None:
        interpret = default_interpret()
    n, p = B.shape
    k = x.shape[0]
    assert n % bn == 0, (n, bn)
    acc = _acc_dtype(B.dtype)
    kernel = functools.partial(_scatter_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),      # x
            pl.BlockSpec((k, bn), lambda j: (0, j)),      # xbar
            pl.BlockSpec((bn, p), lambda j: (j, 0)),      # B
            pl.BlockSpec((k, p), lambda j: (0, 0)),       # U (replicated)
            pl.BlockSpec((1, 1), lambda j: (0, 0)),       # gamma scalar
        ],
        out_specs=pl.BlockSpec((k, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), x.dtype),
        interpret=interpret,
    )(x, xbar, B, u, gamma)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def cimmino_gather(A, xbar, *, bn: int = DEFAULT_BN,
                   interpret: Optional[bool] = None):
    """U = X̄ Aᵀ.   A (p, n); X̄ (k, n).  The Cimmino gather pass A x̄."""
    if interpret is None:
        interpret = default_interpret()
    p, n = A.shape
    k = xbar.shape[0]
    assert n % bn == 0, (n, bn)
    acc = _acc_dtype(A.dtype)
    kernel = functools.partial(_cim_gather_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((k, bn), lambda j: (0, j)),      # xbar
            pl.BlockSpec((p, bn), lambda j: (0, j)),      # A
        ],
        out_specs=pl.BlockSpec((k, p), lambda j: (0, 0)),  # U (accumulated)
        out_shape=jax.ShapeDtypeStruct((k, p), A.dtype),
        interpret=interpret,
    )(xbar, A)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def cimmino_scatter(B, v, *, bn: int = DEFAULT_BN,
                    interpret: Optional[bool] = None):
    """R = V Bᵀ.   B (n, p); V (k, p).  The Cimmino scatter pass B v."""
    if interpret is None:
        interpret = default_interpret()
    n, p = B.shape
    k = v.shape[0]
    assert n % bn == 0, (n, bn)
    acc = _acc_dtype(B.dtype)
    kernel = functools.partial(_cim_scatter_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((k, p), lambda j: (0, 0)),       # v (replicated)
            pl.BlockSpec((bn, p), lambda j: (j, 0)),      # B
        ],
        out_specs=pl.BlockSpec((k, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), v.dtype),
        interpret=interpret,
    )(v, B)
