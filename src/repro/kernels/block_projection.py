"""Pallas TPU kernels for the APC worker iteration (DESIGN.md §2).

The worker update  y = x + γ·(d − B(A d)),  d = x̄ − x  is two dependent
GEMVs over the worker's (p × n) block — *memory-bound* (arithmetic intensity
≈ 1 FLOP/byte over A and B).  The kernels therefore optimize HBM traffic,
not FLOPs:

  * ``apc_gather``:  u = A·d with d formed on the fly from (x, x̄) tiles —
    d is never materialized in HBM (saves 2n reads + n writes per iter).
  * ``apc_scatter``: y = x + γ(d − B·u) fusing the rank-p correction with
    the AXPY — again no d round-trip and no intermediate (n,) vector.

Tiling: the n axis is cut into lane-aligned BN-tiles (multiple of 128); the
p axis lives entirely in VMEM (p is small by construction — each worker's
system is highly under-determined, p ≪ n).  A tile of A (p × BN) occupies
p·BN·4 bytes ≤ ~2 MB for p ≤ 512, well inside the ~16 MB VMEM budget, and
its (BN, p)·(p,) MXU work is aligned when p, BN are multiples of (8, 128).

The u accumulator uses the sequential-grid property of TPU Pallas: every
grid step writes the same (1, p) output block, zero-initialized at j == 0.

Both kernels are exposed through ``ops.py`` (padding + jit + vmap over
workers) and validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 512          # lane-axis tile; multiple of 128


def default_interpret() -> bool:
    """Pallas interpret-mode default, derived from the runtime.

    On a real TPU the kernels compile (interpret=False); everywhere else
    (CPU containers, GPU hosts) they run in interpret mode.  The env var
    ``REPRO_PALLAS_INTERPRET=0/1`` overrides both — e.g. force-compile on
    a TPU-less CI to catch lowering regressions, or force interpret on TPU
    while bisecting a numerics issue.  Resolved when a kernel first traces
    for a given shape; it is not a per-call toggle (pass ``interpret=``
    explicitly for that).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def _gather_kernel(x_ref, xbar_ref, a_ref, u_ref, *, acc_dtype):
    """Grid step j: u += A[:, j·BN:(j+1)·BN] @ (x̄ − x)[j·BN:(j+1)·BN]."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    d = (xbar_ref[...] - x_ref[...]).astype(acc_dtype)      # (1, BN)
    a = a_ref[...].astype(acc_dtype)                        # (p, BN)
    # (1, BN) @ (BN, p) on the MXU; accumulate in acc_dtype.
    u_ref[...] += jax.lax.dot_general(
        d, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype).astype(u_ref.dtype)


def _scatter_kernel(x_ref, xbar_ref, b_ref, u_ref, g_ref, y_ref, *,
                    acc_dtype):
    """Grid step j: y_j = x_j + γ·(d_j − (B_j u))."""
    d = xbar_ref[...] - x_ref[...]                          # (1, BN)
    u = u_ref[...].astype(acc_dtype)                        # (1, p)
    b = b_ref[...].astype(acc_dtype)                        # (BN, p)
    bu = jax.lax.dot_general(
        u, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)                   # (1, BN)
    gamma = g_ref[0, 0].astype(acc_dtype)
    y = x_ref[...].astype(acc_dtype) + gamma * (d.astype(acc_dtype) - bu)
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def apc_gather(A, x, xbar, *, bn: int = DEFAULT_BN,
               interpret: Optional[bool] = None):
    """u = A (x̄ − x).   A (p, n); x, x̄ (1, n) lane-layout.  n % bn == 0."""
    if interpret is None:
        interpret = default_interpret()
    p, n = A.shape
    assert n % bn == 0, (n, bn)
    acc = jnp.float64 if A.dtype == jnp.float64 else jnp.float32
    kernel = functools.partial(_gather_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda j: (0, j)),      # x
            pl.BlockSpec((1, bn), lambda j: (0, j)),      # xbar
            pl.BlockSpec((p, bn), lambda j: (0, j)),      # A
        ],
        out_specs=pl.BlockSpec((1, p), lambda j: (0, 0)),  # u (accumulated)
        out_shape=jax.ShapeDtypeStruct((1, p), A.dtype),
        interpret=interpret,
    )(x, xbar, A)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def apc_scatter(B, x, xbar, u, gamma, *, bn: int = DEFAULT_BN,
                interpret: Optional[bool] = None):
    """y = x + γ(d − B u).   B (n, p); x, x̄ (1, n); u (1, p); γ (1, 1)."""
    if interpret is None:
        interpret = default_interpret()
    n, p = B.shape
    assert n % bn == 0, (n, bn)
    acc = jnp.float64 if B.dtype == jnp.float64 else jnp.float32
    kernel = functools.partial(_scatter_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda j: (0, j)),      # x
            pl.BlockSpec((1, bn), lambda j: (0, j)),      # xbar
            pl.BlockSpec((bn, p), lambda j: (j, 0)),      # B
            pl.BlockSpec((1, p), lambda j: (0, 0)),       # u (replicated)
            pl.BlockSpec((1, 1), lambda j: (0, 0)),       # gamma scalar
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(x, xbar, B, u, gamma)
