"""Pallas TPU kernels for the projection family's per-iteration hot spot.

The projection solvers' worker updates are two dependent GEMMs over the
worker's (p × n) block — *memory-bound* (arithmetic intensity ≈ 1 FLOP/byte
over A and B).  The kernels therefore optimize HBM traffic, not FLOPs:

  * ``apc_gather``:  U = (X̄ − X)·Aᵀ with the difference formed on the fly
    from (X, X̄) tiles — D is never materialized in HBM (saves 2kn reads +
    kn writes per iter).
  * ``apc_scatter``: Y = X + γ(D − U·Bᵀ) fusing the rank-p correction with
    the AXPY — again no D round-trip and no intermediate (k, n) buffer.
  * ``cimmino_gather`` / ``cimmino_scatter``: the block-Cimmino row
    projection r = B(b − A x̄) split the same way (gather U = X̄·Aᵀ,
    scatter R = V·Bᵀ) so the third projection solver shares the engine
    instead of rewriting its update onto the APC shape.

All four kernels are **multi-RHS**: the row-vector operands carry a leading
batch axis k (k = 1 for a plain solve), and the k right-hand sides stream
through the SAME VMEM residency of the A/B tile — one HBM read of A serves
the whole batch, which is what makes the ``solve_many`` / ``LinsysServer``
hot path fused rather than k replayed single-RHS kernels.

Tiling: three axes are cut independently.  The n axis streams in
lane-aligned BN tiles (multiple of 128); the p axis and the k batch may be
cut into BP / BK sublane tiles (multiples of 8) when they outgrow VMEM —
by default both stay whole (p ≪ n by construction and k is a serving
batch), reproducing the original single-residency schedule.  A tile of A
(BP × BN) occupies BP·BN·4 bytes ≤ ~2 MB for BP ≤ 512, well inside the
~16 MB VMEM budget, and its (BK, BN)·(BN, BP) MXU work is aligned when
BK, BP, BN are multiples of (8, 8, 128).  All three tiles are autotuned by
``ops.pick_tiles`` (measured, cached per (k, p, n, dtype), pins
``REPRO_KERNEL_BN`` / ``REPRO_KERNEL_BP`` / ``REPRO_KERNEL_BK``).

Accumulation dtype follows the *compute* operand (x / x̄ / u), not the
stored A/B tiles: under ``precision="mixed"`` the A and B streams are
bf16 in HBM (half the bytes of the memory-bound pipe) while every MXU
contraction accumulates in f32 and the iterate stays f32.

The U accumulators use the sequential-grid property of TPU Pallas: every
grid step that revisits an output block accumulates into it, with the
block zero-initialized on the first visit.

**Sparse fused pair.**  A ``SparseBlocks`` worker block stores its values
compressed on the support: vals (p, w) on w global columns ``cols``.  The
compressed vals block IS a dense (p, w) tile, so the sparse kernels are
the SAME contractions with the lane axis n replaced by the (padded)
support width w — one VMEM residency of the vals/Bvals tile per grid
step, streamed exactly like the dense A/B tiles:

  * ``sparse_gather``          U = (X̄ₛ − Xₛ)·valsᵀ     (= apc_gather)
  * ``sparse_cimmino_gather``  U = X̄ₛ·valsᵀ            (= cimmino_gather)
  * ``sparse_scatter``         C = U·Bvalsᵀ            (= cimmino_scatter)

The support gather Xₛ = X[:, cols] / scatter-add back to the n axis are
XLA ops around the kernels (TPU has no lane-axis hardware gather; the
compressed contraction is where the bytes are).  ``ops.sparse_proj_update``
and ``ops.sparse_cimmino_update`` assemble the full sparse worker updates.

All kernels are exposed through ``ops.py`` (padding + autotune + jit + vmap
over workers) and validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 512          # lane-axis tile; multiple of 128


def default_interpret() -> bool:
    """Pallas interpret-mode default, derived from the runtime.

    On a real TPU the kernels compile (interpret=False); everywhere else
    (CPU containers, GPU hosts) they run in interpret mode.  The env var
    ``REPRO_PALLAS_INTERPRET=0/1`` overrides both — e.g. force-compile on
    a TPU-less CI to catch lowering regressions, or force interpret on TPU
    while bisecting a numerics issue.  Resolved when a kernel first traces
    for a given shape; it is not a per-call toggle (pass ``interpret=``
    explicitly for that).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() != "tpu"


def _acc_dtype(dtype):
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _tiles(size: int, tile: Optional[int], axis: str) -> int:
    tile = size if tile is None else tile
    assert size % tile == 0, (axis, size, tile)
    return tile


def _gather_kernel(x_ref, xbar_ref, a_ref, u_ref, *, acc_dtype):
    """Grid (i, l, j): U[i, l] += (X̄ − X)[i, j] @ A[l, j]ᵀ."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    d = (xbar_ref[...] - x_ref[...]).astype(acc_dtype)      # (BK, BN)
    a = a_ref[...].astype(acc_dtype)                        # (BP, BN)
    # (BK, BN) @ (BN, BP) on the MXU; accumulate in acc_dtype.
    u_ref[...] += jax.lax.dot_general(
        d, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype).astype(u_ref.dtype)


def _scatter_kernel(x_ref, xbar_ref, b_ref, u_ref, g_ref, y_ref, *,
                    acc_dtype):
    """Grid (i, j, l): Y[i, j] = X + γD at l == 0, then −= γ·U[i, l]·B[j, l]ᵀ."""
    l = pl.program_id(2)
    gamma = g_ref[0, 0].astype(acc_dtype)

    @pl.when(l == 0)
    def _init():
        x = x_ref[...].astype(acc_dtype)
        d = xbar_ref[...].astype(acc_dtype) - x             # (BK, BN)
        y_ref[...] = (x + gamma * d).astype(y_ref.dtype)

    u = u_ref[...].astype(acc_dtype)                        # (BK, BP)
    b = b_ref[...].astype(acc_dtype)                        # (BN, BP)
    bu = jax.lax.dot_general(
        u, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)                   # (BK, BN)
    y = y_ref[...].astype(acc_dtype) - gamma * bu
    y_ref[...] = y.astype(y_ref.dtype)


def _cim_gather_kernel(xbar_ref, a_ref, u_ref, *, acc_dtype):
    """Grid (i, l, j): U[i, l] += X̄[i, j] @ A[l, j]ᵀ."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    xb = xbar_ref[...].astype(acc_dtype)                    # (BK, BN)
    a = a_ref[...].astype(acc_dtype)                        # (BP, BN)
    u_ref[...] += jax.lax.dot_general(
        xb, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype).astype(u_ref.dtype)


def _cim_scatter_kernel(v_ref, b_ref, r_ref, *, acc_dtype):
    """Grid (i, j, l): R[i, j] += V[i, l]·B[j, l]ᵀ (rank-BP write-out)."""
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        r_ref[...] = jnp.zeros_like(r_ref)

    v = v_ref[...].astype(acc_dtype)                        # (BK, BP)
    b = b_ref[...].astype(acc_dtype)                        # (BN, BP)
    r = jax.lax.dot_general(
        v, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)                   # (BK, BN)
    r_ref[...] = (r_ref[...].astype(acc_dtype) + r).astype(r_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "bk", "interpret"))
def apc_gather(A, x, xbar, *, bn: int = DEFAULT_BN,
               bp: Optional[int] = None, bk: Optional[int] = None,
               interpret: Optional[bool] = None):
    """U = (X̄ − X) Aᵀ.   A (p, n); X, X̄ (k, n) lane-layout.  n % bn == 0.

    k is the RHS batch (k = 1 for a plain solve): every batch row reuses
    the A tile already resident in VMEM, so one A read serves all k.
    ``bp``/``bk`` (default: whole axis) cut the p / k axes into sublane
    tiles; the n axis is innermost so each U block accumulates across its
    BN stream.  Output and accumulation dtypes follow x (the compute
    stream), so a bf16-stored A contracts into an f32 U.
    """
    if interpret is None:
        interpret = default_interpret()
    p, n = A.shape
    k = x.shape[0]
    assert n % bn == 0, (n, bn)
    bp = _tiles(p, bp, "p")
    bk = _tiles(k, bk, "k")
    acc = _acc_dtype(x.dtype)
    kernel = functools.partial(_gather_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, p // bp, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, l, j: (i, j)),   # x
            pl.BlockSpec((bk, bn), lambda i, l, j: (i, j)),   # xbar
            pl.BlockSpec((bp, bn), lambda i, l, j: (l, j)),   # A
        ],
        out_specs=pl.BlockSpec((bk, bp), lambda i, l, j: (i, l)),
        out_shape=jax.ShapeDtypeStruct((k, p), x.dtype),
        interpret=interpret,
    )(x, xbar, A)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "bk", "interpret"))
def apc_scatter(B, x, xbar, u, gamma, *, bn: int = DEFAULT_BN,
                bp: Optional[int] = None, bk: Optional[int] = None,
                interpret: Optional[bool] = None):
    """Y = X + γ(D − U Bᵀ).   B (n, p); X, X̄ (k, n); U (k, p); γ (1, 1).

    The p axis is innermost: each Y block starts as the fused AXPY
    X + γD on its first visit and accumulates the −γ·U·Bᵀ rank
    correction across the BP stream.
    """
    if interpret is None:
        interpret = default_interpret()
    n, p = B.shape
    k = x.shape[0]
    assert n % bn == 0, (n, bn)
    bp = _tiles(p, bp, "p")
    bk = _tiles(k, bk, "k")
    acc = _acc_dtype(x.dtype)
    kernel = functools.partial(_scatter_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, n // bn, p // bp),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j, l: (i, j)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, l: (i, j)),   # xbar
            pl.BlockSpec((bn, bp), lambda i, j, l: (j, l)),   # B
            pl.BlockSpec((bk, bp), lambda i, j, l: (i, l)),   # U
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),     # gamma scalar
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), x.dtype),
        interpret=interpret,
    )(x, xbar, B, u, gamma)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "bk", "interpret"))
def cimmino_gather(A, xbar, *, bn: int = DEFAULT_BN,
                   bp: Optional[int] = None, bk: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """U = X̄ Aᵀ.   A (p, n); X̄ (k, n).  The Cimmino gather pass A x̄."""
    if interpret is None:
        interpret = default_interpret()
    p, n = A.shape
    k = xbar.shape[0]
    assert n % bn == 0, (n, bn)
    bp = _tiles(p, bp, "p")
    bk = _tiles(k, bk, "k")
    acc = _acc_dtype(xbar.dtype)
    kernel = functools.partial(_cim_gather_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, p // bp, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, l, j: (i, j)),   # xbar
            pl.BlockSpec((bp, bn), lambda i, l, j: (l, j)),   # A
        ],
        out_specs=pl.BlockSpec((bk, bp), lambda i, l, j: (i, l)),
        out_shape=jax.ShapeDtypeStruct((k, p), xbar.dtype),
        interpret=interpret,
    )(xbar, A)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bp", "bk", "interpret"))
def cimmino_scatter(B, v, *, bn: int = DEFAULT_BN,
                    bp: Optional[int] = None, bk: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """R = V Bᵀ.   B (n, p); V (k, p).  The Cimmino scatter pass B v."""
    if interpret is None:
        interpret = default_interpret()
    n, p = B.shape
    k = v.shape[0]
    assert n % bn == 0, (n, bn)
    bp = _tiles(p, bp, "p")
    bk = _tiles(k, bk, "k")
    acc = _acc_dtype(v.dtype)
    kernel = functools.partial(_cim_scatter_kernel, acc_dtype=acc)
    return pl.pallas_call(
        kernel,
        grid=(k // bk, n // bn, p // bp),
        in_specs=[
            pl.BlockSpec((bk, bp), lambda i, j, l: (i, l)),   # v
            pl.BlockSpec((bn, bp), lambda i, j, l: (j, l)),   # B
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), v.dtype),
        interpret=interpret,
    )(v, B)


# ---------------------------------------------------------------------------
# Sparse fused pair (compressed SparseBlocks operands)
# ---------------------------------------------------------------------------
#
# A SparseBlocks worker block is already a dense (p, w) tile on its column
# support, so the sparse kernels ARE the dense contractions with the lane
# axis n replaced by the padded support width w — same VMEM residency, same
# accumulation schedule, ~w/n of the HBM bytes.  The support gather
# Xₛ = X[:, cols] and the scatter-add back to the n axis happen in XLA
# around these calls (``ops.sparse_proj_update`` / ``sparse_cimmino_update``)
# because the TPU has no lane-axis hardware gather; padded support slots
# carry exact-zero vals/Bvals, so their contributions are exactly zero.

sparse_gather = apc_gather            # U = (X̄ₛ − Xₛ)·valsᵀ   (p, w) tile
sparse_cimmino_gather = cimmino_gather  # U = X̄ₛ·valsᵀ
sparse_scatter = cimmino_scatter      # C = U·Bvalsᵀ; scatter-add via cols
