"""Public jit'd wrappers around the Pallas projection-family kernels.

Handles what the raw kernels do not: shape padding to hardware-aligned
tiles, the BN tile-size choice (measured autotune, cached per (p, n,
dtype)), multi-RHS row-batch layout, and vmapping over the worker axis.

The ops here are the fused iteration engine for the whole projection
family (``use_kernel=True`` on apc / consensus / cimmino, both backends):

  * ``block_projection(A, B, x, xbar, gamma)`` — the fused APC/consensus
    worker update y = x + γ·P(x̄ − x); x/x̄ may carry a leading (k,) RHS
    batch, which streams through ONE VMEM residency of each A/B tile.
  * ``proj_gather`` / ``proj_scatter`` — the same two passes split so the
    mesh backend can psum the (k, p) gather result over column shards
    between them (B_loc u needs the FULL u = A d).
  * ``cimmino_update(A, B, b, xbar)`` — the fused block-Cimmino row
    projection r = B(b − A x̄), split the same way into
    ``cimmino_gather`` / ``cimmino_scatter``.

Every op accepts 1-D row vectors (plain solve) or (k, n) batches
(``solve_many`` / ``LinsysServer``) and pads k / p / n to the (8, 8, 128)
MXU-aligned tile internally — zero rows/cols are exact (zero-padded A rows
produce zero U entries; zero-padded B columns ignore them).

Tile autotune: ``pick_tiles`` measures candidate (BN, BP, BK) tiles on the
actual gather+scatter pair — BN lane tiles via ``pick_bn`` (cached per
(p, n_pad, dtype), the original search), then p-/k-sublane tiles staged at
the winning BN (cached per (k, p, n, dtype)).  The measurement runs where
the kernels actually compile (skipped in interpret mode — interpret
timings say nothing about HBM traffic); force it with
``REPRO_KERNEL_AUTOTUNE=1``, disable with ``=0``, or pin tiles outright
with ``REPRO_KERNEL_BN=256`` / ``REPRO_KERNEL_BP=64`` /
``REPRO_KERNEL_BK=8``.

Sparse systems get the same fused engine over the compressed support:
``sparse_proj_update`` / ``sparse_cimmino_update`` run the (p, w) vals /
(w, p) Bvals tiles through the identical Pallas contractions (lane axis =
padded support width) with the support gather/scatter-add in XLA around
them, and return the gather result ``u`` alongside the update — the
fused-residual source (no second read of A per iteration).
"""
from __future__ import annotations

import functools
import logging
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import block_projection as bp
from . import ref

log = logging.getLogger("repro.kernels")

BN_ENV = "REPRO_KERNEL_BN"
BP_ENV = "REPRO_KERNEL_BP"
BK_ENV = "REPRO_KERNEL_BK"
AUTOTUNE_ENV = "REPRO_KERNEL_AUTOTUNE"

# (p_pad, n_pad, dtype-name) -> measured (or heuristic) BN tile
_BN_CACHE: dict = {}
# (k_pad, p_pad, n_pad, dtype-name) -> measured (bp, bk) sublane tiles
_TILE_CACHE: dict = {}
# candidate lane tiles, measured in this order; the heuristic fallback is
# the FIRST candidate dividing n_pad (preserving the old _pick_bn choice)
BN_CANDIDATES = (bp.DEFAULT_BN, 1024, 256, 128)
# candidate p-/k-sublane tiles (whole-axis — the original single-residency
# schedule — is always the first candidate and the no-autotune fallback)
BP_CANDIDATES = (256, 128, 64, 32, 16, 8)
BK_CANDIDATES = (32, 16, 8)


def _pad_axis(a, axis: int, mult: int):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a, size
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, rem)
    return jnp.pad(a, pads), size


def _rows(x):
    """Lift (n,) to the (1, n) kernel row layout; remember to squeeze."""
    x = jnp.asarray(x)
    if x.ndim == 1:
        return x[None, :], True
    return x, False


def _pad_rows(x):
    """Pad the RHS-batch axis to the 8-sublane tile (k == 1 stays 1 — the
    single-RHS layout the kernels always supported)."""
    if x.shape[0] == 1:
        return x
    return _pad_axis(x, 0, 8)[0]


def bn_cache_clear() -> None:
    """Drop every cached BN choice (tests / re-tuning)."""
    _BN_CACHE.clear()


def bn_cache() -> dict:
    """The live {(p_pad, n_pad, dtype): bn} autotune cache (read-only use)."""
    return dict(_BN_CACHE)


def _autotune_enabled(interpret: bool) -> bool:
    env = os.environ.get(AUTOTUNE_ENV)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    # interpret-mode timings measure the python interpreter, not HBM
    # traffic — default to the heuristic there
    return not interpret


def _measure_bn(p_pad: int, n_pad: int, dtype, cands, interpret: bool) -> int:
    """Time the gather+scatter pair per candidate tile; smallest wins.

    Dummy operands, x == x̄ (d = 0 — timing is traffic-bound, not
    value-dependent); best-of-3 after a compile warmup.
    """
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((p_pad, n_pad)), dtype)
    B = jnp.asarray(rng.standard_normal((n_pad, p_pad)), dtype)
    x = jnp.asarray(rng.standard_normal((8, n_pad)), dtype)
    u = jnp.asarray(rng.standard_normal((8, p_pad)), dtype)
    g = jnp.ones((1, 1), dtype)
    best, best_t = cands[0], float("inf")
    for bn in cands:
        def run(bn=bn):
            uu = bp.apc_gather(A, x, x, bn=bn, interpret=interpret)
            return bp.apc_scatter(B, x, x, u, g, bn=bn, interpret=interpret)
        jax.block_until_ready(run())            # compile + warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = run()
        jax.block_until_ready(out)
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = bn, t
    log.debug("autotuned BN=%d for (p=%d, n=%d, %s) in %d candidates",
              best, p_pad, n_pad, np.dtype(dtype).name, len(cands))
    return best


def pick_bn(n_pad: int, p_pad: int = 8, dtype=jnp.float32, *,
            interpret: bool = True) -> int:
    """The lane-axis tile for a (p, n) block: env pin > cache > measure.

    Called at trace time (shapes are static), so the measured choice is
    resolved once per (p, n, dtype) and the kernel grid is fixed from it.
    """
    env = os.environ.get(BN_ENV)
    if env:
        bn = int(env)
        if n_pad % bn:
            raise ValueError(
                f"{BN_ENV}={bn} does not divide the padded n={n_pad} "
                f"(n pads to a multiple of 128; pick a 128-multiple tile "
                f"that divides it)")
        return bn
    key = (int(p_pad), int(n_pad), np.dtype(dtype).name)
    hit = _BN_CACHE.get(key)
    if hit is not None:
        return hit
    cands = [c for c in BN_CANDIDATES if n_pad % c == 0] or [128]
    if len(cands) == 1 or not _autotune_enabled(interpret):
        bn = cands[0]
    else:
        bn = _measure_bn(key[0], key[1], np.dtype(dtype), cands, interpret)
    _BN_CACHE[key] = bn
    return bn


def tile_cache_clear() -> None:
    """Drop every cached (bp, bk) sublane-tile choice (tests / re-tuning)."""
    _TILE_CACHE.clear()


def tile_cache() -> dict:
    """The live {(k_pad, p_pad, n_pad, dtype): (bp, bk)} cache (read-only)."""
    return dict(_TILE_CACHE)


def _env_tile(env_name: str, axis_pad: int, axis: str):
    """An env-pinned sublane tile, validated against the padded axis."""
    env = os.environ.get(env_name)
    if not env:
        return None
    t = int(env)
    if axis_pad % t:
        raise ValueError(
            f"{env_name}={t} does not divide the padded {axis}={axis_pad} "
            f"({axis} pads to a multiple of 8; pick an 8-multiple tile "
            f"that divides it)")
    return t


def _measure_pair(p_pad, n_pad, k_pad, dtype, bn, bpp, bk, interpret):
    """Time the gather+scatter pair once at a (bn, bp, bk) tiling."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((p_pad, n_pad)), dtype)
    B = jnp.asarray(rng.standard_normal((n_pad, p_pad)), dtype)
    x = jnp.asarray(rng.standard_normal((k_pad, n_pad)), dtype)
    g = jnp.ones((1, 1), dtype)

    def run():
        u = bp.apc_gather(A, x, x, bn=bn, bp=bpp, bk=bk,
                          interpret=interpret)
        return bp.apc_scatter(B, x, x, u, g, bn=bn, bp=bpp, bk=bk,
                              interpret=interpret)
    jax.block_until_ready(run())            # compile + warm
    t0 = time.perf_counter()
    for _ in range(3):
        out = run()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _measure_tiles(k_pad, p_pad, n_pad, dtype, bn, interpret):
    """Staged (bp, bk) search at the already-chosen BN: measure the p-tile
    candidates at whole-k, then the k-tile candidates at the winning
    p-tile — O(|BP| + |BK|) timings instead of the full cross product."""
    best_bp, best_t = p_pad, _measure_pair(
        p_pad, n_pad, k_pad, dtype, bn, p_pad, k_pad, interpret)
    for c in (c for c in BP_CANDIDATES if c < p_pad and p_pad % c == 0):
        t = _measure_pair(p_pad, n_pad, k_pad, dtype, bn, c, k_pad,
                          interpret)
        if t < best_t:
            best_bp, best_t = c, t
    best_bk = k_pad
    for c in (c for c in BK_CANDIDATES if c < k_pad and k_pad % c == 0):
        t = _measure_pair(p_pad, n_pad, k_pad, dtype, bn, best_bp, c,
                          interpret)
        if t < best_t:
            best_bk, best_t = c, t
    log.debug("autotuned (bp=%d, bk=%d) at bn=%d for (k=%d, p=%d, n=%d, %s)",
              best_bp, best_bk, bn, k_pad, p_pad, n_pad,
              np.dtype(dtype).name)
    return best_bp, best_bk


def pick_tiles(n_pad: int, p_pad: int = 8, k_pad: int = 1,
               dtype=jnp.float32, *, interpret: bool = True):
    """The (bn, bp, bk) tiling for a (k, p, n) kernel call.

    BN comes from ``pick_bn`` (env pin > cache > measurement — the
    original lane-tile search, cache format unchanged); the p-/k-sublane
    tiles resolve env pin (``REPRO_KERNEL_BP`` / ``REPRO_KERNEL_BK``) >
    cache > staged measurement at the winning BN > whole-axis default
    (the original single-residency schedule).  Called at trace time, so
    the choice is baked into each compiled executor.
    """
    bn = pick_bn(n_pad, p_pad, dtype, interpret=interpret)
    bpp = _env_tile(BP_ENV, p_pad, "p")
    bk = _env_tile(BK_ENV, k_pad, "k")
    if bpp is not None and bk is not None:
        return bn, bpp, bk
    key = (int(k_pad), int(p_pad), int(n_pad), np.dtype(dtype).name)
    hit = _TILE_CACHE.get(key)
    if hit is None:
        if _autotune_enabled(interpret) and (p_pad > 8 or k_pad > 8):
            hit = _measure_tiles(key[0], key[1], key[2], np.dtype(dtype),
                                 bn, interpret)
        else:
            hit = (int(p_pad), int(k_pad))
        _TILE_CACHE[key] = hit
    return bn, (bpp if bpp is not None else hit[0]), \
        (bk if bk is not None else hit[1])


# ---------------------------------------------------------------------------
# Engine autotune: "unfused" is a candidate too
# ---------------------------------------------------------------------------
#
# The tile autotune above assumes the fused kernel is the right engine and
# only picks its lane tile.  That is false in one measured corner: the
# Cimmino kernel LOSES to the plain XLA step at batch 1 (0.88x in
# BENCH_PR5.json — the single-RHS row projection has no A/B-tile reuse to
# amortize, so the kernel's padding + two-pass overhead is pure cost).
# ``use_fused`` extends the measured autotune with the unfused step as a
# candidate per (family, p, n, k, dtype): the projection-family dispatch
# consults it at TRACE time (shapes are static) and falls back to the
# unfused step when fused loses, so ``use_kernel=True`` always means "the
# faster engine", never "the fused engine even where it regresses".
#
# ``REPRO_KERNEL_ENGINE=fused|unfused`` pins the choice (benchmarks use it
# to measure the raw fused path); where measurement is off (interpret mode
# without REPRO_KERNEL_AUTOTUNE=1) the decision comes from the measured
# BENCH trend itself: fused everywhere EXCEPT cimmino below a full
# 8-sublane RHS batch.

ENGINE_ENV = "REPRO_KERNEL_ENGINE"
# the *_sparse families measure the compressed-support kernels against the
# unfused SparseBlocks step; their cache keys carry the padded support
# width w (the contraction axis) alongside the global n
ENGINE_FAMILIES = ("apc", "cimmino", "apc_sparse", "cimmino_sparse")
# (family, p_pad, n_pad, k_pad, dtype-name) -> bool (True = fused wins);
# sparse families key as (family, p_pad, n_pad, k_pad, w_pad, dtype-name)
_ENGINE_CACHE: dict = {}


def engine_cache_clear() -> None:
    """Drop every cached engine choice (tests / re-tuning)."""
    _ENGINE_CACHE.clear()


def engine_cache() -> dict:
    """The live engine-choice cache (read-only use)."""
    return dict(_ENGINE_CACHE)


def _pad_to(size: int, mult: int) -> int:
    return size + (-size) % mult


_MEAS_WORKERS = 2   # dummy worker axis the engine measurement vmaps over
# the probe times the bare kernel pair, but the dispatched step wraps it
# in glue (fused residual harvest, state bookkeeping, consensus psum)
# that burdens the fused path more than the unfused one — so a fused
# "win" inside this margin is measurement noise, not a real win
_ENGINE_MARGIN = 0.85


def _measure_engine(family: str, p_pad: int, n_pad: int, k_pad: int,
                    dtype, interpret: bool, w: Optional[int] = None) -> bool:
    """Time the fused kernel pair against the unfused XLA step for the
    SAME (p, n, k) shape, run the way the solvers actually dispatch
    them: jitted and ``vmap``-ed over a small dummy worker axis
    (``_MEAS_WORKERS``).  The per-step dispatch IS ``vmap(worker)`` over
    the m blocks, and batching a pallas_call — above all through the
    interpreter — costs far more than batching the equivalent XLA step,
    so a lone un-vmapped call flatters the fused engine and mis-routes
    the verdict.  Faster engine wins.  Dummy operands, best-of-3 after
    a compile warmup (same protocol as ``_measure_bn``).  Sparse
    families measure the compressed-support fused op against the
    unfused SparseBlocks-style step on a random w-column support."""
    rng = np.random.default_rng(0)
    mw = _MEAS_WORKERS
    if family.endswith("_sparse"):
        w = int(w)
        cols = jnp.asarray(np.stack(
            [np.sort(rng.choice(n_pad, size=w, replace=False))
             for _ in range(mw)]), jnp.int32)                  # (mw, w)
        vals = jnp.asarray(rng.standard_normal((mw, p_pad, w)), dtype)
        G = (jnp.einsum("mpw,mqw->mpq", vals, vals)
             + 1e-3 * jnp.eye(p_pad, dtype=dtype))
        L = jnp.linalg.cholesky(G)
        bvals = jax.vmap(
            lambda vi, Li: jax.scipy.linalg.cho_solve((Li, True), vi).T)(
                vals, L)                                       # (mw, w, p)
        x = jnp.asarray(rng.standard_normal((k_pad, n_pad)), dtype)
        xbar = jnp.asarray(rng.standard_normal((k_pad, n_pad)), dtype)
        b = jnp.asarray(rng.standard_normal((mw, k_pad, p_pad)), dtype)

        if family == "cimmino_sparse":
            fused_v = jax.jit(jax.vmap(
                lambda vi, ci, bvi, bi: sparse_cimmino_update(
                    vi, ci, bvi, bi, xbar, interpret=interpret)))

            def fused():
                return fused_v(vals, cols, bvals, b)

            def _unf(vi, ci, bvi, bi):
                u = xbar[:, ci] @ vi.T
                c = (bi - u) @ bvi.T
                return jnp.zeros_like(xbar).at[:, ci].add(c)
            unfused_v = jax.jit(jax.vmap(_unf))

            def unfused():
                return unfused_v(vals, cols, bvals, b)
        else:
            fused_v = jax.jit(jax.vmap(
                lambda vi, ci, bvi: sparse_proj_update(
                    vi, ci, bvi, x, xbar, 1.0, interpret=interpret)))

            def fused():
                return fused_v(vals, cols, bvals)

            def _unf(vi, ci, Li):
                d = xbar - x
                u = d[:, ci] @ vi.T
                wsol = jax.scipy.linalg.cho_solve((Li, True), u.T).T
                return (x + d).at[:, ci].add(-(wsol @ vi))
            unfused_v = jax.jit(jax.vmap(_unf))

            def unfused():
                return unfused_v(vals, cols, L)
    else:
        A = jnp.asarray(rng.standard_normal((mw, p_pad, n_pad)), dtype)
        G = (jnp.einsum("mpn,mqn->mpq", A, A)
             + 1e-3 * jnp.eye(p_pad, dtype=dtype))
        L = jnp.linalg.cholesky(G)
        Bm = jax.vmap(
            lambda Ai, Li: jax.scipy.linalg.cho_solve((Li, True), Ai).T)(
                A, L)                                          # (mw, n, p)
        x = jnp.asarray(rng.standard_normal((k_pad, n_pad)), dtype)
        xbar = jnp.asarray(rng.standard_normal((k_pad, n_pad)), dtype)
        b = jnp.asarray(rng.standard_normal((mw, k_pad, p_pad)), dtype)

        if family == "cimmino":
            fused_v = jax.jit(jax.vmap(
                lambda Ai, Bi, bi: cimmino_update(Ai, Bi, bi, xbar,
                                                  interpret=interpret)))

            def fused():
                return fused_v(A, Bm, b)

            def _unf(Ai, Li, bi):
                w_ = jax.scipy.linalg.cho_solve((Li, True),
                                                (bi - xbar @ Ai.T).T).T
                return w_ @ Ai
            unfused_v = jax.jit(jax.vmap(_unf))

            def unfused():
                return unfused_v(A, L, b)
        else:
            fused_v = jax.jit(jax.vmap(
                lambda Ai, Bi: block_projection(Ai, Bi, x, xbar, 1.0,
                                                interpret=interpret)))

            def fused():
                return fused_v(A, Bm)

            def _unf(Ai, Li):
                d = xbar - x
                w_ = jax.scipy.linalg.cho_solve((Li, True), (d @ Ai.T).T).T
                return x + (d - w_ @ Ai)
            unfused_v = jax.jit(jax.vmap(_unf))

            def unfused():
                return unfused_v(A, L)

    # true best-of-5: min over separately timed runs, so one scheduler
    # hiccup inside a candidate's window cannot flip the verdict (a
    # summed window did exactly that on loaded single-core CI hosts)
    times = {}
    for name, run in (("fused", fused), ("unfused", unfused)):
        jax.block_until_ready(run())             # compile + warm
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(run())
            best = min(best, time.perf_counter() - t0)
        times[name] = best
    fused_wins = times["fused"] <= _ENGINE_MARGIN * times["unfused"]
    log.debug("engine autotune %s (p=%d, n=%d, k=%d, %s): fused %.1fus "
              "unfused %.1fus -> %s", family, p_pad, n_pad, k_pad,
              np.dtype(dtype).name, times["fused"] * 1e6,
              times["unfused"] * 1e6,
              "fused" if fused_wins else "unfused")
    return fused_wins


def use_fused(family: str, p: int, n: int, k: int = 1,
              dtype=jnp.float32, *, w: Optional[int] = None,
              interpret: Optional[bool] = None) -> bool:
    """Should this (family, p, n, k, dtype) shape run the fused kernels?

    Resolution order: ``REPRO_KERNEL_ENGINE`` pin > cache > measured
    fused-vs-unfused comparison (where the autotune measures — see
    ``_autotune_enabled``) > the BENCH-trend heuristic (fused everywhere
    except cimmino below a full 8-row RHS batch).  Called at trace time by
    the projection-family ``step``/``step_many`` dispatch, so the choice
    is baked into each compiled executor — zero steady-state retraces.

    The ``*_sparse`` families require ``w`` (the support width — the
    contraction axis the compressed kernels actually stream) and key the
    cache on it alongside the global n.
    """
    if family not in ENGINE_FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"expected one of {ENGINE_FAMILIES}")
    sparse = family.endswith("_sparse")
    if sparse and w is None:
        raise ValueError(f"family {family!r} requires the support width w")
    env = os.environ.get(ENGINE_ENV)
    if env:
        choice = env.strip().lower()
        if choice not in ("fused", "unfused"):
            raise ValueError(f"{ENGINE_ENV}={env!r}: expected 'fused' or "
                             "'unfused'")
        return choice == "fused"
    if interpret is None:
        interpret = bp.default_interpret()
    p_pad = _pad_to(int(p), 8)
    n_pad = _pad_to(int(n), 128)
    k_pad = 1 if int(k) == 1 else _pad_to(int(k), 8)
    if sparse:
        key = (family, p_pad, n_pad, k_pad, int(w), np.dtype(dtype).name)
    else:
        key = (family, p_pad, n_pad, k_pad, np.dtype(dtype).name)
    hit = _ENGINE_CACHE.get(key)
    if hit is not None:
        return hit
    if _autotune_enabled(interpret):
        fused = _measure_engine(family, p_pad, n_pad, k_pad,
                                np.dtype(dtype), interpret,
                                w=(int(w) if sparse else None))
    else:
        # the measured trend (BENCH_PR5/PR6): the fused engine wins
        # wherever the RHS batch fills the 8-sublane tile or the APC
        # pinv step removes per-iteration Gram solves; the lone loser is
        # the sub-batch cimmino row projection (dense or sparse)
        fused = not (family.startswith("cimmino") and k_pad < 8)
    _ENGINE_CACHE[key] = fused
    return fused


# ---------------------------------------------------------------------------
# APC / consensus: the two projection passes, split and fused
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def proj_gather(A, x, xbar, *, interpret: Optional[bool] = None):
    """u = A (x̄ − x) for one worker.   A (p, n); x/x̄ (n,) or (k, n).

    Returns (p,) / (k, p).  The mesh backend psums this over column
    shards before handing it to ``proj_scatter``.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, n = A.shape
    A2, _ = _pad_axis(A, 0, 8)
    A2, _ = _pad_axis(A2, 1, 128)
    x2, squeeze = _rows(x)
    xb2, _ = _rows(xbar)
    k = x2.shape[0]
    x2 = _pad_rows(_pad_axis(x2, 1, 128)[0])
    xb2 = _pad_rows(_pad_axis(xb2, 1, 128)[0])
    n_pad = A2.shape[1]
    bn, bpp, bk = pick_tiles(n_pad, A2.shape[0], x2.shape[0], A.dtype,
                             interpret=interpret)
    u = bp.apc_gather(A2, x2, xb2, bn=bn, bp=bpp, bk=bk,
                      interpret=interpret)
    u = u[:k, :p]
    return u[0] if squeeze else u


@functools.partial(jax.jit, static_argnames=("interpret",))
def proj_scatter(B, x, xbar, u, gamma, *, interpret: Optional[bool] = None):
    """y = x + γ(d − B u) for one worker.   B (n, p); u (p,) or (k, p)."""
    if interpret is None:
        interpret = bp.default_interpret()
    n, p = B.shape
    B2, _ = _pad_axis(B, 1, 8)
    B2, _ = _pad_axis(B2, 0, 128)
    x2, squeeze = _rows(x)
    xb2, _ = _rows(xbar)
    u2, _ = _rows(u)
    k = x2.shape[0]
    x2 = _pad_rows(_pad_axis(x2, 1, 128)[0])
    xb2 = _pad_rows(_pad_axis(xb2, 1, 128)[0])
    u2 = _pad_rows(_pad_axis(u2, 1, 8)[0])
    n_pad = B2.shape[0]
    bn, bpp, bk = pick_tiles(n_pad, B2.shape[1], x2.shape[0], B.dtype,
                             interpret=interpret)
    g = jnp.asarray(gamma, x2.dtype).reshape(1, 1)
    y = bp.apc_scatter(B2, x2, xb2, u2, g, bn=bn, bp=bpp, bk=bk,
                       interpret=interpret)
    y = y[:k, :n]
    return y[0] if squeeze else y


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_projection(A, B, x, xbar, gamma, *,
                     interpret: Optional[bool] = None):
    """y = x + γ (d − B (A d)), d = x̄ − x, via the two fused Pallas passes.

    A (p, n), B (n, p); x/x̄ either (n,) — a plain solve — or (k, n), the
    multi-RHS batch whose k rows share ONE read of every A/B tile.  Pads
    k to a multiple of 8 (batched), p to a multiple of 8 and n to a
    multiple of 128 (zero rows/cols are exact: zero-padded A rows produce
    zero u entries; zero-padded B columns ignore them).

    ``interpret=None`` defers to ``block_projection.default_interpret()``:
    compiled on a real TPU, interpret mode elsewhere, env-overridable via
    ``REPRO_PALLAS_INTERPRET``.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, n = A.shape
    A2, _ = _pad_axis(A, 0, 8)
    A2, _ = _pad_axis(A2, 1, 128)
    B2, _ = _pad_axis(B, 1, 8)
    B2, _ = _pad_axis(B2, 0, 128)
    x2, squeeze = _rows(x)
    xb2, _ = _rows(xbar)
    k = x2.shape[0]
    x2 = _pad_rows(_pad_axis(x2, 1, 128)[0])
    xb2 = _pad_rows(_pad_axis(xb2, 1, 128)[0])
    n_pad = A2.shape[1]
    bn, bpp, bk = pick_tiles(n_pad, A2.shape[0], x2.shape[0], A.dtype,
                             interpret=interpret)

    u = bp.apc_gather(A2, x2, xb2, bn=bn, bp=bpp, bk=bk,
                      interpret=interpret)                      # (k8, p8)
    g = jnp.asarray(gamma, x2.dtype).reshape(1, 1)
    y = bp.apc_scatter(B2, x2, xb2, u, g, bn=bn, bp=bpp, bk=bk,
                       interpret=interpret)
    y = y[:k, :n]
    return y[0] if squeeze else y


def block_projection_batched(A, B, x, xbar, gamma, *,
                             interpret: Optional[bool] = None):
    """vmap over the leading worker axis: A (m,p,n), B (m,n,p), x (m,n)."""
    fn = functools.partial(block_projection, interpret=interpret)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(A, B, x, xbar, gamma)


# ---------------------------------------------------------------------------
# Block Cimmino: the row-projection passes
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def cimmino_gather(A, xbar, *, interpret: Optional[bool] = None):
    """u = A x̄ for one worker.   A (p, n); x̄ (n,) or (k, n) -> (p,)/(k, p).

    The mesh backend psums this over column shards before forming
    v = b − u for ``cimmino_scatter``.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, n = A.shape
    A2, _ = _pad_axis(A, 0, 8)
    A2, _ = _pad_axis(A2, 1, 128)
    xb2, squeeze = _rows(xbar)
    k = xb2.shape[0]
    xb2 = _pad_rows(_pad_axis(xb2, 1, 128)[0])
    n_pad = A2.shape[1]
    bn, bpp, bk = pick_tiles(n_pad, A2.shape[0], xb2.shape[0], A.dtype,
                             interpret=interpret)
    u = bp.cimmino_gather(A2, xb2, bn=bn, bp=bpp, bk=bk,
                          interpret=interpret)
    u = u[:k, :p]
    return u[0] if squeeze else u


@functools.partial(jax.jit, static_argnames=("interpret",))
def cimmino_scatter(B, v, *, interpret: Optional[bool] = None):
    """r = B v for one worker.   B (n, p); v (p,) or (k, p) -> (n,)/(k, n)."""
    if interpret is None:
        interpret = bp.default_interpret()
    n, p = B.shape
    B2, _ = _pad_axis(B, 1, 8)
    B2, _ = _pad_axis(B2, 0, 128)
    v2, squeeze = _rows(v)
    k = v2.shape[0]
    v2 = _pad_rows(_pad_axis(v2, 1, 8)[0])
    n_pad = B2.shape[0]
    bn, bpp, bk = pick_tiles(n_pad, B2.shape[1], v2.shape[0], B.dtype,
                             interpret=interpret)
    r = bp.cimmino_scatter(B2, v2, bn=bn, bp=bpp, bk=bk,
                           interpret=interpret)
    r = r[:k, :n]
    return r[0] if squeeze else r


@functools.partial(jax.jit, static_argnames=("interpret",))
def cimmino_update(A, B, b, xbar, *, interpret: Optional[bool] = None):
    """Fused block-Cimmino row projection r = B (b − A x̄) for one worker.

    A (p, n), B = Aᵀ G⁻¹ (n, p); b (p,) or (k, p); x̄ (n,) or (k, n).
    Returns (n,) / (k, n).  The master update x̄ += ν Σᵢ rᵢ stays outside
    (it is the worker-axis reduction, a psum on the mesh backend).
    """
    u = cimmino_gather(A, xbar, interpret=interpret)
    return cimmino_scatter(B, jnp.asarray(b) - u, interpret=interpret)


# ---------------------------------------------------------------------------
# Sparse fused updates (compressed SparseBlocks support)
# ---------------------------------------------------------------------------
#
# One worker's SparseBlocks slice is a dense (p, w) vals tile on w global
# columns ``cols`` plus the matching (w, p) pseudoinverse factor Bvals
# (B_i = A_iᵀ G_i⁻¹ has rows only on the support).  The fused ops gather
# the support columns of the iterate in XLA (TPU has no lane-axis hardware
# gather), run the SAME Pallas contractions as the dense engine over the
# padded support width, and scatter-add the rank-p correction back.
# Padded support slots carry exact-zero vals — and therefore exact-zero
# Bvals rows — so every padded contribution is exactly zero (duplicate
# padded indices add zeros).  Both ops return the gather result ``u``
# alongside the update: it is the per-iteration residual source (APC
# invariant A_i x_i = b_i makes u = A_i x̄ − b_i; Cimmino's is u − b), so
# recording the history costs no second pass over A.


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_proj_update(vals, cols, bvals, x, xbar, gamma, *,
                       interpret: Optional[bool] = None):
    """Fused sparse APC/consensus worker update y = x + γ(d − B u).

    vals (p, w); cols (w,) int32 global indices; bvals (w, p); x/x̄ (n,)
    or (k, n).  Returns ``(y, u)`` with y (n,)/(k, n) and u (p,)/(k, p)
    = A_i(x̄ − x) — the fused-residual source.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, w = vals.shape
    x2, squeeze = _rows(x)
    xb2, _ = _rows(xbar)
    k = x2.shape[0]
    xs = x2[:, cols]
    xbs = xb2[:, cols]
    V2, _ = _pad_axis(vals, 0, 8)
    V2, _ = _pad_axis(V2, 1, 128)              # (p8, w128)
    Bv2, _ = _pad_axis(bvals, 1, 8)
    Bv2, _ = _pad_axis(Bv2, 0, 128)            # (w128, p8)
    xs2 = _pad_rows(_pad_axis(xs, 1, 128)[0])
    xbs2 = _pad_rows(_pad_axis(xbs, 1, 128)[0])
    w_pad = V2.shape[1]
    bw, bpp, bk = pick_tiles(w_pad, V2.shape[0], xs2.shape[0], vals.dtype,
                             interpret=interpret)
    u = bp.sparse_gather(V2, xs2, xbs2, bn=bw, bp=bpp, bk=bk,
                         interpret=interpret)              # (k8, p8)
    c = bp.sparse_scatter(Bv2, u, bn=bw, bp=bpp, bk=bk,
                          interpret=interpret)             # (k8, w128)
    g = jnp.asarray(gamma, x2.dtype)
    y = x2 + g * (xb2 - x2)
    y = y.at[:, cols].add(-g * c[:k, :w].astype(y.dtype))
    u = u[:k, :p].astype(x2.dtype)
    return (y[0], u[0]) if squeeze else (y, u)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_cimmino_update(vals, cols, bvals, b, xbar, *,
                          interpret: Optional[bool] = None):
    """Fused sparse block-Cimmino row projection r = B(b − A x̄).

    vals (p, w); cols (w,); bvals (w, p); b (p,) or (k, p); x̄ (n,) or
    (k, n).  Returns ``(r, u)`` with r (n,)/(k, n) — supported on cols —
    and u = A_i x̄ (p,)/(k, p), whose residual block is u − b.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, w = vals.shape
    xb2, squeeze = _rows(xbar)
    b2, _ = _rows(b)
    k = xb2.shape[0]
    n = xb2.shape[1]
    xbs = xb2[:, cols]
    V2, _ = _pad_axis(vals, 0, 8)
    V2, _ = _pad_axis(V2, 1, 128)              # (p8, w128)
    Bv2, _ = _pad_axis(bvals, 1, 8)
    Bv2, _ = _pad_axis(Bv2, 0, 128)            # (w128, p8)
    xbs2 = _pad_rows(_pad_axis(xbs, 1, 128)[0])
    w_pad = V2.shape[1]
    bw, bpp, bk = pick_tiles(w_pad, V2.shape[0], xbs2.shape[0], vals.dtype,
                             interpret=interpret)
    u = bp.sparse_cimmino_gather(V2, xbs2, bn=bw, bp=bpp, bk=bk,
                                 interpret=interpret)      # (k8, p8)
    u = u[:k, :p].astype(xb2.dtype)
    v = b2.astype(xb2.dtype) - u
    v2 = _pad_rows(_pad_axis(v, 1, 8)[0])
    c = bp.sparse_scatter(Bv2, v2, bn=bw, bp=bpp, bk=bk,
                          interpret=interpret)             # (k8, w128)
    r = jnp.zeros((k, n), xb2.dtype).at[:, cols].add(
        c[:k, :w].astype(xb2.dtype))
    return (r[0], u[0]) if squeeze else (r, u)


# Re-exported oracle (tests import both from one place).
block_projection_ref = ref.block_projection_ref
