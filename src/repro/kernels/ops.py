"""Public jit'd wrappers around the Pallas APC kernels.

Handles what the raw kernels do not: shape padding to hardware-aligned
tiles, the (tiny, p × p) Gram solve between the two passes, vector-layout
bookkeeping, and vmapping over the worker axis.

``block_projection(A, B, x, xbar, gamma)`` is the drop-in replacement for
``x + gamma * P(xbar - x)`` used by ``core/apc.py`` (``use_kernel=True``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import block_projection as bp
from . import ref


def _pad_axis(a, axis: int, mult: int):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a, size
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, rem)
    return jnp.pad(a, pads), size


def _pick_bn(n: int) -> int:
    """Largest lane-aligned tile that divides the padded n."""
    for bn in (bp.DEFAULT_BN, 256, 128):
        if n % bn == 0:
            return bn
    return 128


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_projection(A, B, x, xbar, gamma, *,
                     interpret: Optional[bool] = None):
    """y = x + gamma * (d - B (A d)), d = xbar - x, via the two Pallas passes.

    A (p, n), B (n, p), x/xbar (n,). Pads p to a multiple of 8 and n to a
    multiple of 128 (zero rows/cols are exact: zero-padded A rows produce
    zero u entries; zero-padded B columns ignore them).

    ``interpret=None`` defers to ``block_projection.default_interpret()``:
    compiled on a real TPU, interpret mode elsewhere, env-overridable via
    ``REPRO_PALLAS_INTERPRET``.
    """
    if interpret is None:
        interpret = bp.default_interpret()
    p, n = A.shape
    A2, _ = _pad_axis(A, 0, 8)
    A2, _ = _pad_axis(A2, 1, 128)
    B2, _ = _pad_axis(B, 1, 8)
    B2, _ = _pad_axis(B2, 0, 128)
    x2, _ = _pad_axis(x[None, :], 1, 128)
    xb2, _ = _pad_axis(xbar[None, :], 1, 128)
    n_pad = A2.shape[1]
    bn = _pick_bn(n_pad)

    u = bp.apc_gather(A2, x2, xb2, bn=bn, interpret=interpret)      # (1, p8)
    g = jnp.asarray(gamma, x.dtype).reshape(1, 1)
    y = bp.apc_scatter(B2, x2, xb2, u, g, bn=bn, interpret=interpret)
    return y[0, :n]


def block_projection_batched(A, B, x, xbar, gamma, *,
                             interpret: Optional[bool] = None):
    """vmap over the leading worker axis: A (m,p,n), B (m,n,p), x (m,n)."""
    fn = functools.partial(block_projection, interpret=interpret)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(A, B, x, xbar, gamma)


# Re-exported oracle (tests import both from one place).
block_projection_ref = ref.block_projection_ref
