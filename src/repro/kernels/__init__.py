"""Pallas TPU kernels for the paper's per-iteration hot spot.

The fused iteration engine for the projection family (apc / consensus /
cimmino), multi-RHS and mesh-composable:

block_projection.py — pl.pallas_call kernels with explicit BlockSpec VMEM
  tiling: the APC gather/scatter passes and the Cimmino row-projection
  pair, all batch-polymorphic over a leading (k,) RHS axis so one read of
  every A/B tile serves the whole serving batch.
ops.py  — jit'd public wrappers (padding, BN autotune cached per
  (p, n, dtype) and env-overridable, worker vmap, the split gather/psum/
  scatter entry points the mesh backend composes with shard_map).
ref.py  — pure-jnp oracles; every kernel is allclose-validated against
  them across shapes, dtypes and batch sizes in tests/test_kernels.py.

Interpret vs compiled is decided at trace time from the runtime backend
(compiled on real TPU, interpret everywhere else); override with the
``REPRO_PALLAS_INTERPRET=0/1`` env var or an explicit ``interpret=`` kwarg
(see ``block_projection.default_interpret``).  The CI kernel smoke runs
every path under ``=1`` each push and force-compiles with ``=0`` on lanes
where lowering is available.
"""
from . import ops, ref  # noqa: F401
