"""Pallas TPU kernels for the paper's per-iteration hot spot.

block_projection.py — pl.pallas_call kernels (gather + scatter passes of
  the APC worker update) with explicit BlockSpec VMEM tiling.
ops.py  — jit'd public wrappers (padding, Gram solve, worker vmap).
ref.py  — pure-jnp oracles; every kernel is allclose-validated against
  them across shapes and dtypes in tests/test_kernels.py (interpret mode
  on CPU; flip block_projection._INTERPRET on real TPUs).
"""
from . import ops, ref  # noqa: F401
