"""Pallas TPU kernels for the paper's per-iteration hot spot.

block_projection.py — pl.pallas_call kernels (gather + scatter passes of
  the APC worker update) with explicit BlockSpec VMEM tiling.
ops.py  — jit'd public wrappers (padding, Gram solve, worker vmap).
ref.py  — pure-jnp oracles; every kernel is allclose-validated against
  them across shapes and dtypes in tests/test_kernels.py.

Interpret vs compiled is decided at trace time from the runtime backend
(compiled on real TPU, interpret everywhere else); override with the
``REPRO_PALLAS_INTERPRET=0/1`` env var or an explicit ``interpret=`` kwarg
(see ``block_projection.default_interpret``).
"""
from . import ops, ref  # noqa: F401
