"""AdamW with fully sharded optimizer state.

Plain-pytree implementation (no optax dependency): the (m, v) moments mirror
the parameter pytree, so the same PartitionSpecs used for parameters shard
the optimizer state — ZeRO-style, for free.  Moments are stored in float32
regardless of the parameter dtype (bf16-safe).

``clip_norm`` applies global-norm clipping; the norm reduction is a plain
jnp reduction, which under pjit lowers to the appropriate all-reduce over
the sharded pytree.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def abstract_state(params_abstract):
    """ShapeDtypeStruct pytree of the state for a params SDS pytree (dry-run:
    no allocation)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(f32, params_abstract),
                      v=jax.tree.map(f32, params_abstract))


def state_pspecs(param_pspecs):
    """PartitionSpecs for the state, mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(),
                      m=jax.tree.map(lambda s: s, param_pspecs),
                      v=jax.tree.map(lambda s: s, param_pspecs))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params, *,
           lr_scale=1.0):
    step = state.step + 1
    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * g32 * g32
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
