from . import adamw, schedule  # noqa: F401
