"""LR schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, warmup: int, total: int,
                         min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step):
    return 1.0
