"""APC as a distributed least-squares engine inside the LM framework.

This is the integration point between the paper's solver and the model zoo
(DESIGN.md §4): closed-form fits of linear maps on top of frozen hidden
states — linear probes, LM-head calibration, value heads — are ridge
problems ``min_W ||H W - Y||^2 + lam ||W||^2`` whose normal equations
``(H^T H + lam I) W = H^T Y`` are exactly the paper's setting: rows of
(H, Y) are sharded across data-parallel workers, and APC solves the system
without ever gathering the features on one host.

``fit_probe`` builds the (n x n) normal system with one pass over the
sharded activations (a psum-reduction), then runs APC on its row-blocks.
For n in the low thousands (d_model-sized), the APC iteration cost n^2/m
per worker amortizes the one-time O(n^2 p) setup after a few hundred
iterations — and, unlike a direct Cholesky of H^T H, tolerates worker
dropout via core/coding.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import apc, partition


def normal_system(H: jnp.ndarray, y: jnp.ndarray, lam: float = 1e-3):
    """Form (A, b) = (H^T H + lam I, H^T y) for the ridge normal equations.

    H (T, n) hidden states, y (T,) regression target (one column of Y).
    """
    n = H.shape[1]
    A = H.T @ H + lam * jnp.eye(n, dtype=H.dtype)
    b = H.T @ y
    return A, b


def fit_probe(H, y, *, m: int = 8, lam: float = 1e-3, iters: int = 500,
              dtype=jnp.float64):
    """Fit w = argmin ||H w - y||^2 + lam||w||^2 via APC on the normal
    equations, distributed over m row-blocks.  Returns (w, residual_history).
    """
    A, b = normal_system(H.astype(dtype), y.astype(dtype), lam)
    n = A.shape[0]
    mm = m
    while n % mm != 0:           # keep the paper's even-split assumption
        mm -= 1
    sys_ = partition.partition(A, b, mm)
    res = apc.solve(sys_, iters=iters)
    return res.x, res.residuals


def probe_loss(H, y, w):
    r = H @ w - y
    return float(jnp.mean(r * r))
