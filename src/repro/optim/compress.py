"""Gradient compression for the data-parallel all-reduce (int8 + error
feedback).

At 512+ chips the gradient all-reduce crosses the DCN (pod axis) where
bandwidth is ~10x scarcer than ICI.  This module provides block-wise int8
quantization with per-block scales (32x compression of f32 master grads,
8x of bf16 wire traffic) and *error feedback* (Seide et al. / EF-SGD): the
quantization residual is carried to the next step, which keeps SGD/Adam
convergence unbiased to first order.

Usage (launch/train.py --compress-grads):

    state = compress.init_error(params)
    grads, state = compress.compress_decompress(grads, state)   # per step
    # all-reduce the int8 payload in practice; here the roundtrip is
    # simulated locally so optimizer semantics are exactly what a
    # compressed all-reduce would produce.

The roundtrip is also exposed factored (``quantize`` / ``dequantize``) so
the launcher can psum the int32-accumulated payload across the pod axis.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class QGrad(NamedTuple):
    q: jnp.ndarray        # int8 payload, shape (n_blocks, BLOCK)
    scale: jnp.ndarray    # f32 per-block scale, (n_blocks, 1)
    n: int                # original element count


def quantize(g: jnp.ndarray) -> QGrad:
    """Symmetric per-block int8 quantization of a flat gradient."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return QGrad(q=q, scale=scale, n=n)


def dequantize(qg: QGrad, shape, dtype) -> jnp.ndarray:
    flat = (qg.q.astype(jnp.float32) * qg.scale).reshape(-1)[:qg.n]
    return flat.reshape(shape).astype(dtype)


def init_error(params):
    """Error-feedback buffers (f32, mirrors the parameter pytree)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, error) -> Tuple[dict, dict]:
    """Per-leaf quantize->dequantize roundtrip with error feedback.

    Returns (decompressed grads, new error buffers).  Wire bytes saved:
    4 bytes/elem -> 1 byte + 4/BLOCK bytes/elem (~3.9x vs f32, ~1.97x vs
    bf16), at zero asymptotic accuracy cost thanks to error feedback.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        qg = quantize(corrected)
        deq = dequantize(qg, g.shape, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def wire_bytes(params) -> Tuple[int, int]:
    """(uncompressed f32, compressed) all-reduce payload bytes."""
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    comp = n + (n + BLOCK - 1) // BLOCK * 4
    return 4 * n, comp
