"""Checkpointing: atomic, versioned, resumable save/restore of pytrees.

Design (fault-tolerance contract, runtime/fault.py relies on it):
  * Atomic: writes go to ``<dir>/tmp.<step>`` then ``os.replace`` into
    ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
  * Versioned: every save is a new ``step_<n>`` directory; ``latest()``
    resolves the newest complete one (a COMMIT marker file seals it).
  * Self-describing: the pytree structure is stored alongside a manifest
    (leaf shapes/dtypes), so restore validates BOTH against the running
    program and fails loudly on config drift — including dtype drift from
    a flipped ``jax_enable_x64`` (``allow_cast=True`` is the explicit
    escape hatch).
  * Data pipeline: only the step counter needs saving — data/synthetic.py
    batches are a pure function of step.

On a real multi-host pod each host writes only its addressable shards
(`jax.experimental.multihost_utils`); in this single-host container the
full array is written.  The layout (one .npy per leaf) is already the
per-shard-file layout that approach needs.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

COMMIT = "COMMITTED"


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically save `tree` as checkpoint `step`.  Returns the path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": [{"shape": list(np.shape(l)),
                            "dtype": str(jnp.asarray(l).dtype)}
                           for l in leaves]}
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, COMMIT)):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like: Any, *, step: Optional[int] = None,
            allow_cast: bool = False) -> Any:
    """Restore into the structure of `like`, failing loudly on drift.

    Both the leaf SHAPES and the manifest DTYPES must match the running
    program — a dtype mismatch (the classic case: a run checkpointed under
    ``jax_enable_x64`` restored without it, or vice versa) raises instead
    of silently casting, because a silent f64 -> f32 cast makes a resumed
    solve diverge from the uninterrupted one.  Pass ``allow_cast=True`` to
    explicitly accept the cast to ``like``'s dtypes.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves)} — config drift?")
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        want = tuple(np.shape(ref))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {want}")
        want_dtype = jnp.asarray(ref).dtype
        saved_dtype = manifest["leaves"][i].get("dtype")
        if (saved_dtype is not None and saved_dtype != str(want_dtype)
                and not allow_cast):
            raise ValueError(
                f"leaf {i}: checkpoint dtype {saved_dtype} != running "
                f"{want_dtype} — dtype drift (was the x64 flag changed "
                f"between save and resume?); pass allow_cast=True to cast")
        out.append(jnp.asarray(arr, dtype=want_dtype))
    return jax.tree.unflatten(treedef, out)
