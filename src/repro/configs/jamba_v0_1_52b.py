"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Period-8 pattern
with one attention layer per period (slot 4); MoE replaces the MLP on every
second layer (odd slots).  Jamba's SSM layers are Mamba-1 in the release;
we use our Mamba2/SSD block with Jamba's d_state=16 (DESIGN.md §5 notes the
adaptation — SSD is the TPU-native chunked formulation).
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, n_shared=0,
                  every_k=2, first_dense=0),
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_pattern=("ssm", "attn"),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=32),
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, every_k=2),
    dtype="float32",
)
