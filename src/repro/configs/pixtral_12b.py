"""Pixtral 12B — pixtral-ViT frontend + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  The ViT frontend
is a STUB: ``input_specs()`` provides precomputed patch embeddings
(B, 256, d_model) that are prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1000000.0,
    frontend="vision", num_patches=256,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, frontend="vision", num_patches=8,
    dtype="float32",
)
