"""Mamba2 130M — SSD (state-space duality) [arXiv:2405.21060; unverified].

24L d_model=768, attention-free, ssm_state=128, vocab=50280, tied embeddings.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=0, vocab_size=50280, attn_type="none",
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=256, attn_type="none",
    layer_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk=32),
    tie_embeddings=True, dtype="float32",
)
