"""Whisper tiny — enc-dec, conv frontend stubbed [arXiv:2212.04356;
unverified].

4+4L d_model=384 6H d_ff=1536 vocab=51865.  The mel/conv frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 384).
GELU MLPs (family="audio"); every decoder layer cross-attends the encoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_seq=1500, frontend="audio",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    encoder_layers=2, encoder_seq=64, frontend="audio",
    dtype="float32",
)
