"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full-size ModelConfig; ``get_smoke(name)`` returns
the reduced same-family config used by CPU smoke tests.  ``ARCHS`` lists all
assigned ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "tinyllama-1.1b",
    "deepseek-7b",
    "deepseek-coder-33b",
    "qwen3-4b",
    "deepseek-v2-236b",
    "qwen3-moe-30b-a3b",
    "jamba-v0.1-52b",
    "pixtral-12b",
    "mamba2-130m",
    "whisper-tiny",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str):
    return _mod(name).CONFIG


def get_smoke(name: str):
    return _mod(name).SMOKE
