"""DeepSeek-Coder 33B — llama-arch [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke", family="dense",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=160, vocab_size=256, dtype="float32",
)
