"""Qwen3-30B-A3B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) d_expert=768 vocab=151936, qk_norm.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768, n_shared=0,
                  every_k=1, first_dense=0),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256, qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=64),
    dtype="float32",
)
