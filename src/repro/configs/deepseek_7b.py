"""DeepSeek-LLM 7B — llama-arch [arXiv:2401.02954; hf].

30L d_model=4096 32H (GQA kv=32 == MHA) d_ff=11008 vocab=102400.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=160, vocab_size=256, dtype="float32",
)
