"""DeepSeek-V2 236B — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H d_expert=1536 vocab=102400; first layer dense
(d_ff=12288); q_lora=1536, rope/nope head dims 64/128, v_head_dim 128.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=12288, vocab_size=102400,
    attn_type="mla", kv_lora_rank=512, q_lora_rank=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  every_k=1, first_dense=1),
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=48,
    d_ff=128, vocab_size=256,
    attn_type="mla", kv_lora_rank=32, q_lora_rank=48,
    rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, n_shared=1,
                  every_k=1, first_dense=1),
    dtype="float32",
)
