"""Static lock-discipline checker for ``pipeline.py``-style classes.

The async server's contract is a single assembly thread plus a device
pool, with every shared mutation under ``self._lock`` and every
blocking call OUTSIDE it.  This checker re-derives that contract from
the source, per class that starts threads:

* **thread contexts** — ``threading.Thread(target=self._m)`` and
  ``self._pool.submit(self._m, ...)`` mark ``_m`` as a worker entry;
  methods reachable from an entry through ``self.x()`` calls inherit
  its context; public / externally-called methods run on the caller
  ("main") thread.
* **shared fields** — a ``self.f`` attribute written from >= 2 distinct
  contexts (assignment, augmented assignment, subscript store, or a
  mutator call such as ``.append``/``.add``/``.discard``).
* **L001** shared field mutated outside ``with self._lock:`` (a method
  whose every intra-class call site holds the lock counts as held —
  that is how ``_next_group`` is proven safe).
* **L002** ``Condition.wait`` without the lock held.
* **L003** device-blocking call (``block_until_ready``, ``.join``,
  ``.shutdown``, ``.result``, ``.acquire``, executor ``.run``) inside a
  ``with self._lock:`` body — holding the lock across a device call
  serializes the pipeline it exists to overlap.

Findings respect ``# repro: allow[L00x]`` suppressions and the central
allow-list, like every other rule.
"""
from __future__ import annotations

import ast
import fnmatch

from repro.analysis.lint import Finding, SourceFile, dotted

_LOCK_CTORS = {"threading.Lock", "threading.RLock"}
_COND_CTORS = {"threading.Condition"}
_POOL_CTORS = {"concurrent.futures.ThreadPoolExecutor",
               "futures.ThreadPoolExecutor", "ThreadPoolExecutor"}
_THREAD_CTORS = {"threading.Thread", "Thread"}

_MUTATORS = {"append", "appendleft", "add", "extend", "update", "remove",
             "discard", "pop", "popleft", "clear", "insert", "setdefault",
             "put"}
_BLOCKING = {"block_until_ready", "join", "shutdown", "result", "acquire",
             "run"}


def _self_attr(node) -> str | None:
    """'f' when node is ``self.f``."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _ClassModel:
    def __init__(self, cls: ast.ClassDef, src: SourceFile):
        self.cls = cls
        self.src = src
        self.methods: dict[str, ast.AST] = {
            s.name: s for s in cls.body
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.lock_fields: set[str] = set()
        self.cond_fields: set[str] = set()
        self.pool_fields: set[str] = set()
        self.entries: dict[str, str] = {}  # method -> context label
        self._scan_fields()
        self.threaded = bool(self.entries)

    def _scan_fields(self):
        for m in self.methods.values():
            for node in ast.walk(m):
                if isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Call):
                    ctor = self.src.resolve(dotted(node.value.func))
                    for tgt in node.targets:
                        f = _self_attr(tgt)
                        if f is None:
                            continue
                        if ctor in _LOCK_CTORS:
                            self.lock_fields.add(f)
                        elif ctor in _COND_CTORS:
                            self.cond_fields.add(f)
                        elif ctor in _POOL_CTORS or ctor.endswith(
                                "ThreadPoolExecutor"):
                            self.pool_fields.add(f)
                if isinstance(node, ast.Call):
                    ctor = self.src.resolve(dotted(node.func))
                    if ctor in _THREAD_CTORS or ctor.endswith(
                            "threading.Thread"):
                        for kw in node.keywords:
                            if kw.arg == "target":
                                t = _self_attr(kw.value)
                                if t:
                                    self.entries[t] = f"thread:{t}"
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr == "submit"
                          and _self_attr(node.func.value)
                          in self.pool_fields and node.args):
                        t = _self_attr(node.args[0])
                        if t:
                            self.entries[t] = f"pool:{t}"

    @property
    def guard_fields(self) -> set[str]:
        return self.lock_fields | self.cond_fields


def _callees(method: ast.AST) -> set[str]:
    out = set()
    for node in ast.walk(method):
        if isinstance(node, ast.Call):
            t = _self_attr(node.func)
            if t:
                out.add(t)
    return out


def check_source(src: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            model = _ClassModel(node, src)
            if model.threaded and model.guard_fields:
                findings.extend(_check_class(model))
    return findings


def _check_class(model: _ClassModel) -> list[Finding]:
    src, methods = model.src, model.methods
    callgraph = {name: _callees(m) & set(methods) for name, m in
                 methods.items()}
    callers: dict[str, set[str]] = {name: set() for name in methods}
    for caller, callees in callgraph.items():
        for c in callees:
            callers[c].add(caller)

    # ---- thread contexts (fixpoint over the intra-class call graph) --
    ctx: dict[str, set[str]] = {name: set() for name in methods}
    for name in methods:
        if name in model.entries:
            ctx[name].add(model.entries[name])
        elif not callers[name] or not name.startswith("_"):
            # externally callable (public or uncalled) => caller thread
            ctx[name].add("main")
    for _ in range(len(methods)):
        changed = False
        for name in methods:
            if name in model.entries:
                continue
            inherited = set()
            for c in callers[name]:
                inherited |= ctx[c]
            if not inherited <= ctx[name]:
                ctx[name] |= inherited
                changed = True
        if not changed:
            break

    # ---- per-statement lock-held positions ---------------------------
    def _with_holds(w: ast.With) -> bool:
        return any(_self_attr(item.context_expr) in model.guard_fields
                   for item in w.items)

    held_nodes: dict[str, set[ast.AST]] = {}
    for name, m in methods.items():
        held: set[ast.AST] = set()
        # every descendant of a lock-holding With's body is lock-held
        for sub in ast.walk(m):
            if isinstance(sub, ast.With) and _with_holds(sub):
                for stmt in sub.body:
                    for n in ast.walk(stmt):
                        held.add(n)
                    held.add(stmt)
        held_nodes[name] = held

    # ---- held-context propagation: a private method whose every call
    # site is under the lock runs lock-held itself ---------------------
    held_methods: set[str] = set()
    for _ in range(2):
        for name, m in methods.items():
            if name in held_methods or name in model.entries:
                continue
            if not name.startswith("_") or name == "__init__":
                continue
            sites = []
            for caller in callers[name]:
                cm = methods[caller]
                for sub in ast.walk(cm):
                    if isinstance(sub, ast.Call) and (
                            _self_attr(sub.func) == name):
                        sites.append(sub in held_nodes[caller]
                                     or caller in held_methods)
            if sites and all(sites):
                held_methods.add(name)

    def _is_held(name: str, node: ast.AST) -> bool:
        return name in held_methods or node in held_nodes[name]

    # ---- shared fields ----------------------------------------------
    writes: dict[str, list[tuple[str, ast.AST]]] = {}

    def _note_write(field, name, node):
        if field and field not in model.guard_fields and name != "__init__":
            writes.setdefault(field, []).append((name, node))

    for name, m in methods.items():
        for sub in ast.walk(m):
            if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                tgts = sub.targets if isinstance(sub, ast.Assign) else (
                    [sub.target])
                for tgt in tgts:
                    base = tgt
                    while isinstance(base, (ast.Subscript, ast.Attribute)):
                        f = _self_attr(base)
                        if f:
                            _note_write(f, name, sub)
                            break
                        base = base.value
            elif isinstance(sub, ast.Call) and isinstance(
                    sub.func, ast.Attribute) and sub.func.attr in _MUTATORS:
                recv = sub.func.value
                while isinstance(recv, (ast.Subscript, ast.Attribute)):
                    f = _self_attr(recv)
                    if f:
                        _note_write(f, name, sub)
                        break
                    recv = recv.value

    shared = {f for f, ws in writes.items()
              if len({c for (n, _) in ws for c in ctx[n]}) >= 2}

    findings: list[Finding] = []

    def _report(rule, node, qualname, message):
        from repro.analysis.allowlist import ALLOW
        line = getattr(node, "lineno", 1)
        if rule in src.suppressed.get(line, set()):
            return
        for path_glob, qual_glob, _why in ALLOW.get(rule, ()):
            ok = (fnmatch.fnmatchcase(src.relpath, path_glob)
                  or src.relpath.endswith(path_glob))
            if ok and fnmatch.fnmatchcase(qualname, qual_glob):
                return
        findings.append(Finding(rule, src.relpath, line,
                                getattr(node, "col_offset", 0) + 1, message))

    cname = model.cls.name

    # L001: shared field mutated without the lock
    for field in sorted(shared):
        for name, node in writes[field]:
            if not _is_held(name, node):
                _report("L001", node, f"{cname}.{name}",
                        f"shared field self.{field} (written from contexts "
                        f"{sorted(set(c for n, _ in writes[field] for c in ctx[n]))}) "
                        f"mutated in {name}() without holding the lock.")

    # L002/L003
    for name, m in methods.items():
        for sub in ast.walk(m):
            if not isinstance(sub, ast.Call):
                continue
            f = sub.func
            if not isinstance(f, ast.Attribute):
                continue
            recv_field = _self_attr(f.value)
            if (f.attr == "wait" and recv_field in model.cond_fields
                    and not _is_held(name, sub)):
                _report("L002", sub, f"{cname}.{name}",
                        f"self.{recv_field}.wait() without the lock held: "
                        "Condition.wait requires the associated lock.")
            if (f.attr in _BLOCKING and recv_field not in model.guard_fields
                    and _is_held(name, sub)):
                _report("L003", sub, f"{cname}.{name}",
                        f".{f.attr}() (blocking) inside a with-lock body in "
                        f"{name}(): holding the lock across a blocking call "
                        "serializes the pipeline. Capture refs under the "
                        "lock, call outside it.")
    return findings
