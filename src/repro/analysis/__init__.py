"""repro.analysis — contract lints + dynamic checkers for the repo.

* ``lint`` / ``rules`` — "reprolint": AST rules R001-R007 over the
  architecture contracts (jit scope, host entropy, factor-store
  ownership, registry completeness, core/ layering, interpret
  threading, future-safe excepts).
* ``locks`` — static lock-discipline checker (L001-L003) for the async
  pipeline classes.
* ``tracecheck`` — attributed zero-retrace assertions for serving
  paths.

CLI: ``python -m repro.analysis [paths...]`` (exit 1 on findings);
``scripts/lint.sh`` runs it after ruff in tier-1 CI.
"""
from __future__ import annotations

from repro.analysis.lint import (DEFAULT_PATHS, Finding, SourceFile,
                                 lint_file, lint_paths)
from repro.analysis.locks import check_source as check_locks
from repro.analysis.tracecheck import (TraceError, TraceEvent, TraceReport,
                                       tracecheck)

__all__ = [
    "DEFAULT_PATHS", "Finding", "SourceFile", "lint_file", "lint_paths",
    "check_locks", "TraceError", "TraceEvent", "TraceReport", "tracecheck",
]
