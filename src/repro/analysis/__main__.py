"""CLI: ``python -m repro.analysis [paths...]``.

Runs every reprolint rule (R001-R007), the lock-discipline checker
(L001-L003), and prints findings as ``path:line:col: RULE message``.
Exit status 1 when anything fires — this is the tier-1 CI lint gate.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint import DEFAULT_PATHS, lint_paths
from repro.analysis.rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: repo contract lints + lock checker")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--no-locks", action="store_true",
                    help="skip the lock-discipline checker")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for cls in ALL_RULES:
            print(f"{cls.id}  {cls.title}")
        print("L001  shared field mutated without the lock")
        print("L002  Condition.wait without the lock held")
        print("L003  blocking call inside a with-lock body")
        return 0

    rules = None
    if args.rules:
        want = {r.strip() for r in args.rules.split(",")}
        rules = [cls for cls in ALL_RULES if cls.id in want]

    findings = lint_paths(args.paths or None, rules=rules,
                          include_locks=not args.no_locks)
    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f)
        n = len(findings)
        print(f"reprolint: {n} finding(s)" if n else "reprolint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
