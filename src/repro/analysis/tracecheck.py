"""tracecheck — attributed retrace detection for jitted serving paths.

The serving layer's zero-steady-state-retrace contract used to be
enforced by counting (``LinsysServer.jit_cache_size()`` must stay
flat), which tells you THAT something retraced but not WHAT or WHERE.
This module upgrades the assertion to attribution.

Mechanism: ``jax_log_compiles`` makes jax emit a
``"Finished tracing + transforming <fun> for pjit"`` log record for
every trace — synchronously, inside the triggering call's stack, on the
triggering thread.  A logging handler on the ``jax`` logger therefore
sees every trace event AND can ``traceback.extract_stack()`` to find
the call site: the innermost frame that is not jax/logging internals is
the line of user code that caused the trace.  The subsequent
``"Compiling <fun> with global shapes and types [...]"`` record carries
the abstract signature, which is attached to the matching event.

Usage::

    with tracecheck() as tc:          # record + attribute
        ...
    print(tc.summary())

    with tracecheck(steady_state=True):   # assert zero traces
        srv.submit(...); srv.drain()      # raises TraceError naming the
                                          # call site if anything traced

``steady_state=True`` is the serving contract: after warmup, no call
may trace.  The raised :class:`TraceError` message names every traced
function and its attributed ``file:line`` call site, so a CI failure
points at the offending line instead of a cache-size delta.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import logging
import re
import threading
import traceback

_TRACE_RE = re.compile(r"Finished tracing \+ transforming (?P<fun>.+?) "
                       r"(?:for pjit )?in \S+ sec")
_COMPILE_RE = re.compile(r"Compiling (?P<fun>\S+) .*types\s+(?P<sig>\[.*\])")

# frames from these paths are machinery, not the call site
_INTERNAL_PARTS = ("/jax/", "/jaxlib/", "/jax/_src/", "/logging/",
                   "contextlib.py", "/repro/analysis/tracecheck",
                   "/threading.py", "/concurrent/futures/")


@dataclasses.dataclass
class TraceEvent:
    """One jit trace, attributed to the user-code line that caused it."""

    fun: str
    path: str
    line: int
    code: str
    thread: str
    signature: str | None = None

    @property
    def where(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        sig = f" {self.signature}" if self.signature else ""
        return (f"traced {self.fun!r}{sig} at {self.where} "
                f"({self.code}) [thread {self.thread}]")


class TraceError(AssertionError):
    """A steady-state region retraced; the message names the call site."""


class TraceReport:
    """Accumulates :class:`TraceEvent`s for one tracecheck window."""

    def __init__(self, allow: tuple[str, ...] = ()):
        self.allow = tuple(allow)
        self.events: list[TraceEvent] = []
        self._lock = threading.Lock()

    def _add(self, ev: TraceEvent):
        with self._lock:
            self.events.append(ev)

    def _attach_signature(self, fun: str, sig: str):
        with self._lock:
            for ev in reversed(self.events):
                if ev.fun == fun and ev.signature is None:
                    ev.signature = sig
                    return

    def traces(self, fun: str | None = None) -> list[TraceEvent]:
        with self._lock:
            evs = list(self.events)
        if fun is None:
            return evs
        return [e for e in evs if fnmatch.fnmatchcase(e.fun, fun)]

    def unexpected(self) -> list[TraceEvent]:
        return [e for e in self.traces()
                if not any(fnmatch.fnmatchcase(e.fun, pat)
                           for pat in self.allow)]

    def summary(self) -> str:
        evs = self.traces()
        if not evs:
            return "tracecheck: 0 trace events"
        lines = [f"tracecheck: {len(evs)} trace event(s):"]
        lines += [f"  - {e}" for e in evs]
        return "\n".join(lines)

    def assert_zero(self, context: str = "steady state"):
        bad = self.unexpected()
        if bad:
            lines = [f"{len(bad)} retrace(s) in a zero-retrace region "
                     f"({context}):"]
            lines += [f"  - {e}" for e in bad]
            raise TraceError("\n".join(lines))


class _Recorder(logging.Handler):
    def __init__(self, report: TraceReport):
        super().__init__(level=logging.DEBUG)
        self.report = report

    def emit(self, record: logging.LogRecord):  # runs in the tracing stack
        try:
            msg = record.getMessage()
        except (TypeError, ValueError):
            return
        m = _COMPILE_RE.search(msg)
        if m:
            self.report._attach_signature(m.group("fun"), m.group("sig"))
            return
        m = _TRACE_RE.search(msg)
        if not m:
            return
        site = None
        for frame in traceback.extract_stack():
            fn = frame.filename.replace("\\", "/")
            if any(part in fn for part in _INTERNAL_PARTS):
                continue
            site = frame  # keep the DEEPEST non-internal frame
        if site is None:
            path, line, code = "<unknown>", 0, ""
        else:
            path, line, code = site.filename, site.lineno, (site.line or "")
        self.report._add(TraceEvent(
            fun=m.group("fun"), path=path, line=line, code=code.strip(),
            thread=threading.current_thread().name))


@contextlib.contextmanager
def tracecheck(steady_state: bool = False, allow: tuple[str, ...] = ()):
    """Record every jit trace in the body, attributed to its call site.

    ``steady_state=True`` raises :class:`TraceError` on exit if ANY
    trace happened (minus ``allow`` fnmatch patterns on the traced
    function name) — the message names each offending call site.
    """
    import jax

    report = TraceReport(allow=allow)
    handler = _Recorder(report)
    # single attachment point: the "jax" ancestor sees every child
    # logger's records exactly once via propagation
    logger = logging.getLogger("jax")
    prev_compiles = bool(jax.config.jax_log_compiles)
    prev_level = logger.level
    jax.config.update("jax_log_compiles", True)
    # pin the subtree's effective level so an app-level logging config
    # (e.g. basicConfig(level=ERROR)) cannot starve the recorder
    logger.setLevel(logging.WARNING)
    logger.addHandler(handler)
    try:
        yield report
    finally:
        logger.removeHandler(handler)
        logger.setLevel(prev_level)
        jax.config.update("jax_log_compiles", prev_compiles)
    if steady_state:
        report.assert_zero()
