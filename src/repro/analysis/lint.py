"""reprolint — repo-specific AST contract lints.

PRs 1-6 accumulated architecture contracts that used to live only in
ROADMAP prose: factor acquisition goes through ``FactorStore``, steady
state serving never retraces, ``core/`` never imports ``solvers/`` or
``kernels/``, Pallas entry points thread ``default_interpret()``, the
async pipeline resolves every future it admits.  This module is the
framework that turns each contract into a checkable rule:

* :class:`SourceFile` — a parsed file plus the per-line suppression map
  (``# repro: allow[R001]`` / ``# repro: allow[R001,R007]`` on the
  statement's first line suppresses that rule there).
* :class:`Rule` — shared visitor base.  The framework owns traversal
  and context (function stack, class stack, loop depth); rules override
  the ``on_*`` hooks and call :meth:`Rule.report`, which applies both
  suppressions and the central allow-list (``allowlist.ALLOW``), so
  every sanctioned exception is auditable in one place.
* :class:`ProgramRule` — whole-program rules that need to see every
  file at once (registry completeness resolves inheritance across
  modules).
* :func:`lint_paths` — the entry point the CLI and CI use.

Adding a rule: subclass :class:`Rule` (or :class:`ProgramRule`) in
``analysis/rules/``, set ``id``/``title``, and append it to
``rules.ALL_RULES``.  Corpus-test it in ``tests/lint_corpus/`` — CI
asserts every rule fires on its violating snippet and stays quiet on
the conforming one.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib
import re

# src/repro/analysis/lint.py -> repo root is three levels up from src/
REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

#: Directories lint_paths scans by default (repo-relative).  Tests are
#: deliberately excluded: the corpus under tests/lint_corpus/ exists to
#: VIOLATE the rules, and test-local jit construction is idiomatic.
DEFAULT_PATHS = ("src", "scripts", "benchmarks", "examples")

_EXCLUDE_PARTS = {"__pycache__", "lint_corpus", ".git"}

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_\s,]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def dotted(node: ast.AST) -> str | None:
    """``'jax.jit'`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def call_name(node: ast.Call) -> str | None:
    return dotted(node.func)


class SourceFile:
    """A parsed python file: AST + alias map + suppression map."""

    def __init__(self, path: str | pathlib.Path, text: str | None = None,
                 repo_root: pathlib.Path | None = None):
        p = pathlib.Path(path).resolve()
        root = pathlib.Path(repo_root) if repo_root else REPO_ROOT
        try:
            self.relpath = p.relative_to(root).as_posix()
        except ValueError:
            self.relpath = p.as_posix()
        self.text = p.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=self.relpath)
        # line -> rule ids suppressed on that line
        self.suppressed: dict[int, set[str]] = {}
        for i, ln in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(ln)
            if m:
                self.suppressed[i] = {s.strip() for s in m.group(1).split(",")
                                      if s.strip()}
        # import alias map: local name -> fully dotted origin, so rules
        # can resolve `np.random.rand` vs `jax.random.uniform` even when
        # both are bound to short names.
        self.aliases: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                mod = ("." * node.level) + (node.module or "")
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = f"{mod}.{a.name}"

    def resolve(self, name: str | None) -> str:
        """Expand the leading component of ``name`` via the alias map."""
        if not name:
            return ""
        head, _, rest = name.partition(".")
        full = self.aliases.get(head, head)
        return f"{full}.{rest}" if rest else full


class Rule(ast.NodeVisitor):
    """Visitor base.  Subclasses override the ``on_*`` hooks only —
    traversal and context bookkeeping are framework-owned so every rule
    sees the same function/class/loop context for free."""

    id = "R000"
    title = ""

    def __init__(self, src: SourceFile, allowlist=None):
        self.src = src
        self.findings: list[Finding] = []
        self.func_stack: list[ast.AST] = []
        self.class_stack: list[ast.ClassDef] = []
        self.loop_depth = 0
        if allowlist is None:
            from repro.analysis.allowlist import ALLOW
            allowlist = ALLOW
        self._allow = allowlist.get(self.id, ())

    # ---- hooks (override in rules) ---------------------------------
    def on_module(self, node: ast.Module):
        pass

    def on_class(self, node: ast.ClassDef):
        pass

    def on_function(self, node):
        pass

    def on_call(self, node: ast.Call):
        pass

    def on_import(self, node: ast.Import):
        pass

    def on_import_from(self, node: ast.ImportFrom):
        pass

    def on_except(self, node: ast.ExceptHandler):
        pass

    # ---- framework-owned traversal ----------------------------------
    def run(self) -> list[Finding]:
        self.on_module(self.src.tree)
        self.visit(self.src.tree)
        return self.findings

    def visit_ClassDef(self, node: ast.ClassDef):
        for dec in node.decorator_list:
            self.visit(dec)
        self.on_class(node)
        self.class_stack.append(node)
        for child in node.body:
            self.visit(child)
        self.class_stack.pop()

    def _visit_function(self, node):
        # decorators evaluate in the ENCLOSING scope: visit them before
        # pushing, so a module-scope `@jax.jit` is not "inside" anything
        for dec in node.decorator_list:
            self.visit(dec)
        self.on_function(node)
        self.func_stack.append(node)
        for child in node.body:
            self.visit(child)
        self.func_stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def _visit_loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _visit_loop
    visit_AsyncFor = _visit_loop
    visit_While = _visit_loop

    def visit_Call(self, node: ast.Call):
        self.on_call(node)
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        self.on_import(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        self.on_import_from(node)

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        self.on_except(node)
        self.generic_visit(node)

    # ---- reporting ---------------------------------------------------
    def qualname(self) -> str:
        parts = [c.name for c in self.class_stack]
        parts += [getattr(f, "name", "<lambda>") for f in self.func_stack]
        return ".".join(parts)

    def report(self, node: ast.AST, message: str, qualname: str | None = None):
        line = getattr(node, "lineno", 1)
        if self.id in self.src.suppressed.get(line, set()):
            return
        qn = self.qualname() if qualname is None else qualname
        for path_glob, qual_glob, _why in self._allow:
            if _path_match(self.src.relpath, path_glob) and (
                    fnmatch.fnmatchcase(qn, qual_glob)):
                return
        self.findings.append(Finding(
            self.id, self.src.relpath, line,
            getattr(node, "col_offset", 0) + 1, message))


class ProgramRule:
    """Whole-program rule: sees every SourceFile at once.  Used when a
    contract spans modules (e.g. registry completeness resolves solver
    inheritance across files)."""

    id = "R000"
    title = ""

    def run_program(self, sources: list[SourceFile]) -> list[Finding]:
        raise NotImplementedError

    def report_at(self, src: SourceFile, node: ast.AST, message: str,
                  qualname: str = "", out: list[Finding] | None = None):
        from repro.analysis.allowlist import ALLOW
        line = getattr(node, "lineno", 1)
        if self.id in src.suppressed.get(line, set()):
            return
        for path_glob, qual_glob, _why in ALLOW.get(self.id, ()):
            if _path_match(src.relpath, path_glob) and (
                    fnmatch.fnmatchcase(qualname, qual_glob)):
                return
        out.append(Finding(self.id, src.relpath, line,
                           getattr(node, "col_offset", 0) + 1, message))


def _path_match(relpath: str, glob: str) -> bool:
    return fnmatch.fnmatchcase(relpath, glob) or relpath.endswith(glob)


def iter_py_files(paths=None, repo_root: pathlib.Path | None = None):
    root = pathlib.Path(repo_root) if repo_root else REPO_ROOT
    for p in (paths or DEFAULT_PATHS):
        pp = pathlib.Path(p)
        if not pp.is_absolute():
            pp = root / pp
        if pp.is_file():
            yield pp
            continue
        for f in sorted(pp.rglob("*.py")):
            if _EXCLUDE_PARTS.isdisjoint(f.parts):
                yield f


def lint_paths(paths=None, rules=None, repo_root=None,
               include_locks: bool = True) -> list[Finding]:
    """Run every rule (AST rules, program rules, and the lock checker)
    over ``paths`` and return the combined findings."""
    from repro.analysis import locks
    from repro.analysis.rules import ALL_RULES

    rule_classes = list(ALL_RULES if rules is None else rules)
    sources = []
    findings: list[Finding] = []
    for f in iter_py_files(paths, repo_root):
        try:
            src = SourceFile(f, repo_root=repo_root)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding("PARSE", str(f), getattr(e, "lineno", 1)
                                    or 1, 1, f"unparseable: {e}"))
            continue
        sources.append(src)
        for cls in rule_classes:
            if issubclass(cls, Rule):
                findings.extend(cls(src).run())
        if include_locks:
            findings.extend(locks.check_source(src))
    for cls in rule_classes:
        if issubclass(cls, ProgramRule):
            findings.extend(cls().run_program(sources))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_file(path, rules=None, repo_root=None,
              include_locks: bool = True) -> list[Finding]:
    """Lint a single file (program rules see only that file)."""
    return lint_paths([path], rules=rules, repo_root=repo_root,
                      include_locks=include_locks)
