"""Central allow-list for reprolint.

Every sanctioned rule exception lives HERE, with its justification, so
an audit of "what is exempt and why" is one file.  Entries are
``(path_glob, qualname_glob, why)``; a finding is suppressed when its
repo-relative path matches ``path_glob`` (fnmatch, or suffix match) AND
its qualified name (``Class.method`` nesting, ``""`` at module scope)
matches ``qualname_glob``.

Point-in-code exceptions should prefer the inline
``# repro: allow[RULE]`` comment next to the line; this file is for
STRUCTURAL exemptions — whole files or methods whose job is the thing
the rule exists to contain.
"""
from __future__ import annotations

ALLOW: dict[str, tuple[tuple[str, str, str], ...]] = {
    # R001: jax.jit inside a function body. The rule exists to catch
    # per-call jit construction; these sites construct ONCE and cache.
    "R001": (
        ("src/repro/solvers/mesh.py", "*",
         "compile-once builders: each jit(shard_map) is built once per "
         "CompiledSolve/placement and cached by the caller"),
        ("src/repro/solvers/redundant.py", "*",
         "compile-once redundant-placement builders, same pattern as "
         "mesh.py"),
        ("src/repro/solvers/serve.py", "_LocalExecutor.*",
         "the keyed executor cache itself: one jit per (solver, shape, "
         "param) key, constructed once in __init__ and cached by "
         "LinsysServer._executor — this IS the sanctioned home R001 "
         "points at"),
        ("src/repro/kernels/ops.py", "_measure_engine",
         "engine autotune measurement: candidate jits are constructed "
         "once per (family, p, n, k, dtype) probe, timed, then "
         "discarded; the winning engine is served by the module-scope "
         "jitted ops"),
        ("src/repro/core/distributed.py", "*",
         "deprecated shim layer: builds its compiled step once per "
         "DistributedSolve construction (kept for API compat)"),
        ("src/repro/launch/cells.py", "*",
         "dry-run cells lower one jit per (solver, shape) cell to cost "
         "it; each cell is built exactly once per plan"),
        ("src/repro/launch/train.py", "main",
         "training entry point: train_step is jitted once per process "
         "before the epoch loop"),
        ("src/repro/launch/serve.py", "make_decode",
         "the compile-once decode factory: built once per model OUTSIDE "
         "the batch loop, exactly the hoisting R001 demands"),
        ("benchmarks/periter.py", "*",
         "measurement harness: one jit per timed variant, constructed "
         "once before the timing loop"),
        ("benchmarks/straggler.py", "*",
         "measurement harness: one jit per timed variant, constructed "
         "once before the timing loop"),
    ),
    # R003: raw prepare/mesh_prepare callers that ARE the sanctioned
    # factor-acquisition machinery.
    "R003": (
        ("src/repro/solvers/store.py", "*",
         "FactorStore.factors IS the content-addressed owner of the "
         "raw solver.prepare call"),
        ("src/repro/solvers/api.py", "*",
         "Solver.solve/solve_many drivers: the non-served convenience "
         "path computes factors inline by design"),
        ("src/repro/solvers/mesh.py", "*",
         "mesh placement calls solver.mesh_prepare under shard_map; "
         "factors are then cached by the CompiledSolve"),
        ("src/repro/solvers/redundant.py", "*",
         "redundant placement, same ownership as mesh.py"),
        ("src/repro/solvers/elastic.py", "*",
         "elastic repartitioning goes through the FactorStore block "
         "tier when the solver supports it and falls back to a direct "
         "prepare for solvers without per-block factor independence"),
        ("src/repro/core/distributed.py", "*",
         "deprecated shim forwards to the solvers layer (kept for API "
         "compat; new code goes through FactorStore)"),
    ),
}
