"""Rule registry for reprolint.  One module per rule; adding a rule =
new module + an entry here + a corpus pair in tests/lint_corpus/."""
from __future__ import annotations

from repro.analysis.rules.r001_jit_scope import R001JitInFunction
from repro.analysis.rules.r002_host_entropy import R002HostEntropy
from repro.analysis.rules.r003_store_bypass import R003StoreBypass
from repro.analysis.rules.r004_registry import R004RegistryComplete
from repro.analysis.rules.r005_layering import R005CoreLayering
from repro.analysis.rules.r006_interpret import R006InterpretThreading
from repro.analysis.rules.r007_broad_except import R007BroadExcept
from repro.analysis.rules.r008_modes import R008ModeHooks
from repro.analysis.rules.r009_plan_kwargs import R009PlanKwargs

ALL_RULES = (
    R001JitInFunction,
    R002HostEntropy,
    R003StoreBypass,
    R004RegistryComplete,
    R005CoreLayering,
    R006InterpretThreading,
    R007BroadExcept,
    R008ModeHooks,
    R009PlanKwargs,
)

__all__ = ["ALL_RULES"] + [c.__name__ for c in ALL_RULES]
