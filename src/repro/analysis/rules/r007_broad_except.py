"""R007 — broad ``except Exception`` that can swallow a pending future.

The serving pipeline's availability contract is "every admitted request
gets an explicit answer": a broad except that neither re-raises nor
resolves a future can eat the failure and leave a caller blocked on
``future.result()`` forever.  A broad handler is conforming when its
body re-raises, or resolves the pending work via ``set_exception`` /
``set_result`` / ``_complete_error``.  Everything else must either
narrow the exception types or carry an explicit
``# repro: allow[R007]`` with a reason.

ruff's BLE001 is deliberately disabled in pyproject.toml: this rule
owns broad-except judgment because "is the future resolved" is a
repo-specific question a generic linter cannot answer.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Rule

_RESOLVERS = {"set_exception", "set_result", "_complete_error"}


class R007BroadExcept(Rule):
    id = "R007"
    title = "broad except without re-raise or future resolution"

    def on_except(self, node: ast.ExceptHandler):
        t = node.type
        broad = t is None or (isinstance(t, ast.Name)
                              and t.id in ("Exception", "BaseException"))
        if not broad:
            return
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Raise):
                    return
                if isinstance(sub, ast.Call):
                    f = sub.func
                    name = f.attr if isinstance(f, ast.Attribute) else (
                        f.id if isinstance(f, ast.Name) else "")
                    if name in _RESOLVERS:
                        return
        label = "bare except" if t is None else f"except {t.id}"
        self.report(node, f"{label} neither re-raises nor resolves a "
                          "future (set_exception/set_result/"
                          "_complete_error): it can swallow a pending "
                          "request forever. Narrow the types or justify "
                          "with # repro: allow[R007].")
