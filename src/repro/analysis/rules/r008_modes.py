"""R008 — declared system modes must be backed by mode hooks.

A solver class that declares a ``supports`` capability set is making a
dispatch-time promise (``solvers/capability.py`` routes on it).  The
promise is only honest if the claimed mode's machinery exists:

* ``"least_squares"`` requires non-stub ``ls_moment`` (the normal-map
  optimality moment the drivers turn into a residual) and
  ``ls_reference`` (the lstsq ground truth used when ``x_true`` is
  absent) somewhere in the class's inheritance chain.
* ``"sparse"`` requires the chain's defining modules to import
  ``repro.core.blockops`` — the structure-dispatched contraction layer
  is the only legal way to consume a ``SparseBlocks`` operand, so a
  sparse claim without the import means the solver would crash (or
  silently densify) on its first sparse system.

Inheritance is resolved across every scanned file, mirroring R004: the
gradient family declares ``supports`` and the ls hooks once on a shared
base.  A hook whose body is just ``raise NotImplementedError`` is the
``Solver`` interface stub and does not count.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding, ProgramRule, SourceFile, dotted

LS_HOOKS = ("ls_moment", "ls_reference")
BLOCKOPS = "repro.core.blockops"


def _is_stub(fn: ast.AST) -> bool:
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant)
                    and isinstance(s.value.value, str))]
    return len(body) == 1 and isinstance(body[0], ast.Raise) and (
        "NotImplementedError" in ast.dump(body[0]))


def _declared_supports(cls: ast.ClassDef) -> set[str] | None:
    """The string literals of a class-body ``supports = ...`` assignment,
    or None when the class does not declare one (inheriting is fine —
    the base that declares carries the obligation)."""
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        if not any(isinstance(t, ast.Name) and t.id == "supports"
                   for t in targets):
            continue
        if isinstance(value, ast.Call) and value.args:
            value = value.args[0]          # frozenset({...}) / set([...])
        if isinstance(value, (ast.Set, ast.Tuple, ast.List)):
            elts = value.elts
        else:
            return set()                   # dynamic: nothing checkable
        return {e.value for e in elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return None


def _imports_blockops(src: SourceFile) -> bool:
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Import):
            if any(a.name.startswith(BLOCKOPS) for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.startswith(BLOCKOPS):
                return True
            if mod == "repro.core" and any(a.name == "blockops"
                                           for a in node.names):
                return True
    return False


class R008ModeHooks(ProgramRule):
    id = "R008"
    title = "declared capability mode without its mode hooks"

    def run_program(self, sources: list[SourceFile]) -> list[Finding]:
        table: dict[str, tuple[ast.ClassDef, SourceFile]] = {}
        for src in sources:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.ClassDef):
                    table.setdefault(node.name, (node, src))

        findings: list[Finding] = []
        for src in sources:
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                supports = _declared_supports(node)
                if not supports:
                    continue
                defined: set[str] = set()
                chain_srcs: list[SourceFile] = []
                seen: set[str] = set()
                queue = [node.name]
                while queue:
                    cname = queue.pop()
                    if cname in seen or cname not in table:
                        continue
                    seen.add(cname)
                    cls, csrc = table[cname]
                    chain_srcs.append(csrc)
                    for stmt in cls.body:
                        if isinstance(stmt, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                            if not _is_stub(stmt):
                                defined.add(stmt.name)
                    for base in cls.bases:
                        bname = dotted(base)
                        if bname:
                            queue.append(bname.split(".")[-1])

                if "least_squares" in supports:
                    missing = [h for h in LS_HOOKS if h not in defined]
                    if missing:
                        self.report_at(
                            src, node,
                            f"class {node.name!r} declares "
                            f"supports={{'least_squares', ...}} but its "
                            f"inheritance chain lacks non-stub {missing}: "
                            "the LS drivers need ls_moment for the "
                            "optimality residual and ls_reference for the "
                            "lstsq ground truth.",
                            qualname=node.name, out=findings)
                if "sparse" in supports:
                    if not any(_imports_blockops(s) for s in chain_srcs):
                        self.report_at(
                            src, node,
                            f"class {node.name!r} declares "
                            f"supports={{'sparse', ...}} but no module in "
                            "its inheritance chain imports "
                            f"{BLOCKOPS}: sparse operands must go through "
                            "the structure-dispatched contractions, not "
                            "raw einsums on a SparseBlocks NamedTuple.",
                            qualname=node.name, out=findings)
        return findings
