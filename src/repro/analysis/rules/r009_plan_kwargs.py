"""R009 — internal solve call sites pass ``plan=``, not loose kwargs.

PR 10 consolidated the execution surface (``backend=``, ``mesh=``,
``use_kernel=``, ``redundancy=``, ``alive_schedule=``, ``store=``,
``precision=``, ``warm_state=``, ``factors=``, ``worker_axes=``,
``model_axis=``) into ONE validated ``ExecutionPlan`` resolved at
dispatch (solvers/capability.py).  The loose kwargs survive only as a
deprecation shim for EXTERNAL callers — one ``DeprecationWarning`` per
call.  Internal code (anything under ``repro``) must not lean on its
own deprecation path: every ``.solve(...)`` / ``.solve_many(...)`` call
site passes ``plan=`` or nothing.  The shim itself (solvers/api.py)
forwards plan fields, so it has no such call to flag; tests exercising
the legacy surface live under ``tests/`` and are out of scope.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.lint import Rule

_DEPRECATED = frozenset({
    "use_kernel", "precision", "warm_state", "factors", "store",
    "backend", "mesh", "worker_axes", "model_axis", "redundancy",
    "alive_schedule",
})
_METHODS = ("solve", "solve_many")


class R009PlanKwargs(Rule):
    id = "R009"
    title = "internal solve() call passes deprecated loose kwargs"

    def _internal(self) -> bool:
        return "repro" in pathlib.PurePosixPath(self.src.relpath).parts

    def on_call(self, node: ast.Call):
        if not self._internal():
            return
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in _METHODS):
            return
        bad = sorted(kw.arg for kw in node.keywords
                     if kw.arg is not None and kw.arg in _DEPRECATED)
        if bad:
            self.report(
                node,
                f"{fn.attr}() called with deprecated loose kwargs "
                f"{bad}: internal code must put the execution surface "
                f"on the plan — pass plan=ExecutionPlan("
                f"{', '.join(k + '=...' for k in bad)}) instead "
                f"(the kwarg shim is for external callers and emits a "
                f"DeprecationWarning at runtime)")
