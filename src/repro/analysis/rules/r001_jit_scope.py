"""R001 — ``jax.jit`` constructed inside a function or loop body.

A jit transform built per call is a retrace hazard: every construction
gets a fresh cache, so the compile cost is paid on every invocation and
``jit_cache_size()``-style steady-state accounting is silently wrong.
Jits must live at module scope (decorator or module-level assignment)
or inside a KEYED executor cache (``LinsysServer._executor``) — those
caches are the allow-listed exceptions in ``allowlist.ALLOW``.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Rule, call_name, dotted

_JIT = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit",
        "jax.experimental.pjit.pjit.pjit"}


class R001JitInFunction(Rule):
    id = "R001"
    title = "jax.jit constructed inside a function/loop body"

    def _is_jit_ctor(self, node: ast.Call) -> bool:
        name = self.src.resolve(call_name(node))
        if name in _JIT:
            return True
        # functools.partial(jax.jit, ...) builds a jit factory too
        if name.endswith("partial") and node.args:
            return self.src.resolve(dotted(node.args[0])) in _JIT
        return False

    def on_call(self, node: ast.Call):
        if not self._is_jit_ctor(node):
            return
        if self.func_stack:
            where = f"function {self.qualname()!r}"
        elif self.loop_depth:
            where = "a module-level loop"
        else:
            return
        self.report(node, f"jax.jit constructed inside {where}: each "
                          "construction starts a fresh trace cache (per-call "
                          "retrace hazard). Move it to module scope or a "
                          "keyed executor cache.")
