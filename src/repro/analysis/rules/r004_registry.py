"""R004 — registry completeness.

Every ``@register``-ed solver must provide the four lifecycle hooks
(``prepare``/``init``/``step``/``extract``) the drivers, the factor
store, and the servers rely on; and a solver that opts into the mesh
backend by defining ANY of the four mesh hooks must define the full set
(``mesh_factor_specs``/``mesh_state_specs``/``mesh_prepare``/
``mesh_step``) — a partial mesh surface fails at placement time deep
inside ``shard_map`` with an unhelpful NotImplementedError.

Inheritance is resolved across every scanned file (the gradient family
defines prepare/step on a shared base and only init on the registered
subclasses).  A method whose body is just ``raise NotImplementedError``
is an abstract stub and does not count as a definition — that is how
``Solver``'s own interface stubs are excluded.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding, ProgramRule, SourceFile, dotted

LIFECYCLE = ("prepare", "init", "step", "extract")
MESH_FULL = ("mesh_factor_specs", "mesh_state_specs", "mesh_prepare",
             "mesh_step")


def _is_stub(fn: ast.AST) -> bool:
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant)
                    and isinstance(s.value.value, str))]
    return len(body) == 1 and isinstance(body[0], ast.Raise) and (
        "NotImplementedError" in ast.dump(body[0]))


def _registered_name(cls: ast.ClassDef, src: SourceFile) -> str | None:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            name = dotted(dec.func) or ""
            if name.split(".")[-1] == "register":
                if dec.args and isinstance(dec.args[0], ast.Constant):
                    return str(dec.args[0].value)
                return cls.name
    return None


class R004RegistryComplete(ProgramRule):
    id = "R004"
    title = "@register-ed solver missing lifecycle/mesh hooks"

    def run_program(self, sources: list[SourceFile]) -> list[Finding]:
        table: dict[str, tuple[ast.ClassDef, SourceFile]] = {}
        for src in sources:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.ClassDef):
                    table.setdefault(node.name, (node, src))

        findings: list[Finding] = []
        for src in sources:
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                reg = _registered_name(node, src)
                if reg is None:
                    continue
                defined: set[str] = set()
                seen: set[str] = set()
                queue = [node.name]
                while queue:
                    cname = queue.pop()
                    if cname in seen or cname not in table:
                        continue
                    seen.add(cname)
                    cls, _ = table[cname]
                    for stmt in cls.body:
                        if isinstance(stmt, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                            if not _is_stub(stmt):
                                defined.add(stmt.name)
                    for base in cls.bases:
                        bname = dotted(base)
                        if bname:
                            queue.append(bname.split(".")[-1])

                missing = [h for h in LIFECYCLE if h not in defined]
                if missing:
                    self.report_at(
                        src, node,
                        f"registered solver {reg!r} missing lifecycle "
                        f"hook(s) {missing}: the drivers/store/servers "
                        "require prepare/init/step/extract.",
                        qualname=node.name, out=findings)
                mesh_defined = [h for h in MESH_FULL if h in defined]
                mesh_missing = [h for h in MESH_FULL if h not in defined]
                if mesh_defined and mesh_missing:
                    self.report_at(
                        src, node,
                        f"registered solver {reg!r} defines "
                        f"{mesh_defined} but not {mesh_missing}: any mesh_* "
                        "hook implies the full mesh set, else placement "
                        "fails inside shard_map.",
                        qualname=node.name, out=findings)
        return findings
