"""R002 — host time/RNG inside jitted or ``lax.scan``-carried code.

``time.time()``, ``time.perf_counter()``, ``random.*`` and unseeded
``np.random.*`` execute at TRACE time inside a jitted function: the
value is baked into the jaxpr as a constant, so every retrace changes
the program and steady-state results silently depend on when tracing
happened.  Host-side timing/RNG around autotune measurement (outside
the jitted callee) is fine; ``jax.random`` with threaded keys and
seeded ``np.random.default_rng(seed)`` construction are fine.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Rule, call_name, dotted

_JIT = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}
_SCAN = {"jax.lax.scan", "jax.lax.fori_loop", "jax.lax.while_loop"}

_HOST_TIME = {"time.time", "time.perf_counter", "time.monotonic",
              "time.process_time", "time.time_ns", "time.perf_counter_ns",
              "datetime.datetime.now", "datetime.datetime.utcnow"}
# seeded-Generator construction is allowed even near jitted code; the
# generator itself is host-side and the seed makes it reproducible
_NP_RANDOM_OK = {"numpy.random.default_rng", "numpy.random.Generator",
                 "numpy.random.SeedSequence", "numpy.random.PCG64"}


class R002HostEntropy(Rule):
    id = "R002"
    title = "host time/RNG inside jitted or lax.scan-carried code"

    def on_module(self, tree: ast.Module):
        parents: dict[ast.AST, ast.AST] = {}
        defs: dict[str, list[ast.AST]] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        jitted: set[ast.AST] = set()

        def _is_jit_expr(expr) -> bool:
            name = self.src.resolve(dotted(expr))
            if name in _JIT:
                return True
            if isinstance(expr, ast.Call):
                cname = self.src.resolve(call_name(expr))
                if cname in _JIT:
                    return True
                if cname.endswith("partial") and expr.args:
                    return self.src.resolve(dotted(expr.args[0])) in _JIT
            return False

        # (a) decorated defs; (b) defs passed to jit(f) / lax.scan(f, ...)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_is_jit_expr(d) for d in node.decorator_list):
                    jitted.add(node)
            elif isinstance(node, ast.Call):
                cname = self.src.resolve(call_name(node))
                carried = []
                if cname in _JIT and node.args:
                    carried = [node.args[0]]
                elif cname in _SCAN:
                    # scan(f, ...) / fori_loop(lo, hi, f, ...) /
                    # while_loop(cond, body, ...): every function-valued
                    # positional arg is traced
                    carried = list(node.args)
                for arg in carried:
                    if isinstance(arg, ast.Name) and arg.id in defs:
                        jitted.update(defs[arg.id])

        # (c) closure: defs nested inside a jitted def trace with it
        def _under_jitted(node) -> bool:
            cur = parents.get(node)
            while cur is not None:
                if cur in jitted:
                    return True
                cur = parents.get(cur)
            return False

        for fn in list(defs.values()):
            for node in fn:
                if _under_jitted(node):
                    jitted.add(node)

        for fn in jitted:
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = self.src.resolve(call_name(node))
                bad = (name in _HOST_TIME
                       or name.startswith("random.")
                       or (name.startswith("numpy.random.")
                           and name not in _NP_RANDOM_OK))
                if bad:
                    self.report(
                        node,
                        f"host time/RNG call {name}() inside jitted/scanned "
                        f"function {fn.name!r}: the value is baked in at "
                        "trace time. Use jax.random with a threaded key or "
                        "hoist the host call out of the traced region.",
                        qualname=fn.name)
