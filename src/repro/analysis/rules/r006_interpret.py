"""R006 — Pallas entry points must thread ``default_interpret()``.

``kernels.block_projection.default_interpret()`` is the single
authority on interpret-vs-compile (TPU detection + the
``REPRO_PALLAS_INTERPRET`` override CI's force-compile lane relies on).
A ``pl.pallas_call`` with a hard-coded ``interpret=True``/``False`` —
or with no ``interpret`` argument at all, which silently means
``False`` — pins one mode and breaks either the CPU test environment or
the TPU deployment.  Entry points must accept an ``interpret`` argument
defaulting to ``default_interpret()`` and thread it through.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Rule, call_name


class R006InterpretThreading(Rule):
    id = "R006"
    title = "pallas_call hard-codes (or omits) interpret="

    def on_call(self, node: ast.Call):
        name = call_name(node) or ""
        is_pallas = name.split(".")[-1] == "pallas_call"
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        if is_pallas and "interpret" not in kw:
            self.report(node, "pallas_call without interpret=: this "
                              "hard-codes compiled mode. Thread "
                              "interpret=default_interpret() through the "
                              "entry point.")
            return
        val = kw.get("interpret")
        if (val is not None and isinstance(val, ast.Constant)
                and isinstance(val.value, bool)):
            self.report(node, f"interpret={val.value} is hard-coded: mode "
                              "selection belongs to default_interpret() "
                              "(TPU detection + REPRO_PALLAS_INTERPRET "
                              "override).")
