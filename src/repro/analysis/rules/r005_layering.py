"""R005 — layering: ``core/`` may not import ``solvers/`` or ``kernels/``.

``repro.core`` is the deprecated numerics layer kept alive as thin
shims over ``repro.solvers``; the sanctioned shim pattern is a LAZY
import inside the function body (cycle guard — solvers imports core
types at module scope).  A module-level import in either direction
creates an import cycle that only detonates for some import orders, so
only module-scope imports are flagged; function-scope imports are the
documented escape hatch.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.lint import Rule

_FORBIDDEN_HEADS = ("solvers", "kernels")


class R005CoreLayering(Rule):
    id = "R005"
    title = "core/ imports solvers/ or kernels/ at module scope"

    def _in_core(self) -> bool:
        return "core" in pathlib.PurePosixPath(self.src.relpath).parts

    def _flag(self, node, modname: str):
        self.report(node, f"core/ module imports {modname!r} at module "
                          "scope: layering violation (cycle hazard). Shims "
                          "must import lazily inside the function body.")

    def on_import(self, node: ast.Import):
        if not self._in_core() or self.func_stack:
            return
        for a in node.names:
            parts = a.name.split(".")
            if len(parts) >= 2 and parts[0] == "repro" and (
                    parts[1] in _FORBIDDEN_HEADS):
                self._flag(node, a.name)

    def on_import_from(self, node: ast.ImportFrom):
        if not self._in_core() or self.func_stack:
            return
        mod = node.module or ""
        parts = mod.split(".") if mod else []
        if node.level >= 2 and parts and parts[0] in _FORBIDDEN_HEADS:
            self._flag(node, "." * node.level + mod)
        elif len(parts) >= 2 and parts[0] == "repro" and (
                parts[1] in _FORBIDDEN_HEADS):
            self._flag(node, mod)
