"""R003 — factor-store bypass.

Every factorization in the serving stack is acquired through
``FactorStore`` (content-addressed by ``fingerprint(A_blocks, solver,
params)``) so cost is paid once per (system, solver, param) key and the
disk tier stays coherent.  A direct ``solver.prepare(...)`` /
``solver.mesh_prepare(...)`` call anywhere else silently duplicates the
factorization work and bypasses cache accounting.  The store itself,
the ``Solver.solve`` drivers, and the mesh/redundant compile paths are
the allow-listed owners of the raw call.

A solver calling ``self.prepare(...)`` internally is NOT a bypass —
that IS the factorization being implemented — so self/cls/super
receivers are exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Rule


class R003StoreBypass(Rule):
    id = "R003"
    title = "Solver.prepare/mesh_prepare called outside FactorStore"

    def on_call(self, node: ast.Call):
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and f.attr in ("prepare", "mesh_prepare")):
            return
        recv = f.value
        if isinstance(recv, ast.Name) and recv.id in ("self", "cls"):
            return
        if (isinstance(recv, ast.Call) and isinstance(recv.func, ast.Name)
                and recv.func.id == "super"):
            return
        self.report(node, f"direct .{f.attr}() call bypasses FactorStore: "
                          "factorizations must be acquired via "
                          "store.factors(...) so they are content-addressed "
                          "and paid once per key.")
