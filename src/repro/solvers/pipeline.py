"""Async pipelined linear-system serving: overlapped admission → batch
assembly → execution → streaming result return.

``LinsysServer`` is a synchronous ``step()``/``drain()`` loop — admission,
batch assembly, device execution, and result return all serialize, so the
taskmaster throughput is one batch at a time.  ``AsyncLinsysServer``
decomposes the same serving contract into pipeline stages connected by
bounded queues:

  1. **Admission with backpressure** — ``submit(fp, rhs)`` returns a
     ``Ticket`` whose future streams the result back.  Admission is
     bounded by ``admit_capacity`` requests in the system (queued or in
     flight): a full pipeline REJECTS the request with an explicit
     ``Shed`` result instead of queueing unboundedly — overload degrades
     availability (shed rate), never correctness or latency of admitted
     work.
  2. **Batch assembly on a host thread** — the identical FIFO
     oldest-pending-system rule and ``take_group`` coalescing/padding
     semantics as the sync server (reused, not reimplemented), plus
     factor acquisition through the shared ``FactorStore`` and the
     host→device transfer (``jax.device_put`` via ``Executor.place_B``)
     so the copy of batch B+1 overlaps the execution of batch B.
  3. **A pool of in-flight executors** — up to ``pipeline_depth`` batches
     execute concurrently on the compile-once executor cache inherited
     from ``LinsysServer`` (same keys, same zero-steady-state-retrace
     invariant, ``jit_cache_size()`` constant under load); system A's
     solve overlaps system B's assembly and readback.
  4. **Streaming result return** — each request's future resolves to a
     ``Served`` (or ``Shed``) the moment its batch completes; per-request
     latency (submit → result) is recorded for the SLO report.

Everything the synchronous lifecycle guarantees composes unchanged:
``use_kernel=True`` (fused multi-RHS Pallas kernels), ``warm_start=True``
gated by ``Solver.warm_rhs_ok`` (warm chaining serializes same-system
batches so state hand-off is exact), and ``backend="mesh"`` through
``mesh.batched_runner``.

    srv = AsyncLinsysServer(store, solver="apc", batch=4,
                            pipeline_depth=2, admit_capacity=64)
    fp = srv.register(sys)
    with srv:                                   # start()/close()
        tickets = [srv.submit(fp, b) for b in stream]
        for t in tickets:
            r = t.result()                      # Served or Shed
    srv.latency_report()                        # p50/p95/p99 ms, count
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, List, NamedTuple, Optional, Union

import numpy as np

from .api import iters_to_tolerance
from .serve import LinsysServer, Served, take_group
from .store import FactorStore


class Shed(NamedTuple):
    """Explicit overload result: the request was REJECTED at admission
    because ``admit_capacity`` requests were already in the pipeline."""
    rid: int
    fp: str


Result = Union[Served, Shed]


class Ticket(NamedTuple):
    """Admission receipt: the future resolves to ``Served`` (success) or
    ``Shed`` (rejected at admission — resolved immediately)."""
    rid: int
    fp: str
    future: Future
    t_submit: float

    def result(self, timeout: Optional[float] = None) -> Result:
        return self.future.result(timeout)


class _AsyncRequest(NamedTuple):
    rid: int
    fp: str
    rhs: np.ndarray
    future: Future
    t_submit: float


class _Work(NamedTuple):
    """One assembled batch handed from the assembly stage to the executor
    pool (arrays already placed on device by the assembly thread)."""
    fp: str
    ent: Any
    ex: Any
    group: List[_AsyncRequest]
    n_real: int
    Bb: np.ndarray          # host copy (warm-start repeat detection)
    Bb_dev: Any             # device copy (place_B on the assembly thread)
    warm: bool


class AsyncLinsysServer(LinsysServer):
    """Pipelined twin of ``LinsysServer``: same registration, coalescing,
    store, executor-cache, and warm-start semantics — decomposed into
    admission / assembly / execution stages so they overlap.

    ``pipeline_depth`` bounds concurrently-executing batches (the
    executor pool size AND the assembly→execution queue bound);
    ``admit_capacity`` bounds requests in the system — queued plus in
    flight — beyond which ``submit`` sheds.  ``step()`` is not part of
    this server's surface (serving happens on the pipeline threads);
    ``drain()`` blocks until every ticket since the last drain resolved
    and returns the results in submission (rid) order.
    """

    def __init__(self, store: Optional[FactorStore] = None, *,
                 pipeline_depth: int = 2,
                 admit_capacity: Optional[int] = None, **kw):
        super().__init__(store, **kw)
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        if admit_capacity is None:
            # enough for every executor slot plus a full assembly backlog
            admit_capacity = 8 * self.batch * pipeline_depth
        if admit_capacity < 1:
            raise ValueError(
                f"admit_capacity must be >= 1, got {admit_capacity}")
        self.pipeline_depth = pipeline_depth
        self.admit_capacity = admit_capacity
        self._admit_base = admit_capacity   # full-fleet capacity; see
                                            # on_membership()
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)   # assembly wakeups
        self._idle = threading.Condition(self._lock)   # drain/close wakeups
        self._in_system = 0       # admitted and not yet completed
        self._inflight = 0        # batches dispatched and not yet completed
        self._busy = set()        # fps serialized for warm-state chaining
        self._tickets: List[Ticket] = []
        self._lat: List[float] = []
        self._stopping = False
        self._assembler: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        # bounded assembly->execution hand-off: acquiring a slot blocks the
        # assembly thread once pipeline_depth batches are in flight
        self._slots = threading.Semaphore(pipeline_depth)

    # ----- lifecycle --------------------------------------------------------
    def start(self) -> "AsyncLinsysServer":
        """Start the assembly thread and the executor pool (idempotent)."""
        with self._lock:
            if self._assembler is not None:
                return self
            self._stopping = False
            self._pool = ThreadPoolExecutor(
                max_workers=self.pipeline_depth,
                thread_name_prefix="linsys-exec")
            self._assembler = threading.Thread(
                target=self._assemble_loop, name="linsys-assembly",
                daemon=True)
            self._assembler.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Drain the pipeline (default) and stop the stage threads."""
        with self._lock:
            started = self._assembler is not None
            has_work = self._in_system > 0
        if not started:
            if has_work and drain:
                self.start()
            elif not has_work:
                return
        if drain:
            with self._idle:
                while self._in_system or self._inflight:
                    self._idle.wait(0.05)
        with self._lock:
            self._stopping = True
            self._work.notify_all()
            assembler, pool = self._assembler, self._pool
            self._assembler, self._pool = None, None
        if assembler is not None:
            assembler.join()
        if pool is not None:
            pool.shutdown(wait=True)

    def __enter__(self) -> "AsyncLinsysServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ----- stage 1: admission with backpressure -----------------------------
    def submit(self, fp: str, rhs) -> Ticket:        # type: ignore[override]
        """Admit one request, or shed it with an explicit overload result.

        Validation (unknown fingerprint -> KeyError naming it, shape
        mismatch -> ValueError) is the sync server's, shared.  A full
        pipeline (``admit_capacity`` requests queued or in flight)
        resolves the ticket's future IMMEDIATELY with ``Shed`` — callers
        always get an answer, and admitted requests keep their latency.
        """
        _, rhs = self._validated(fp, rhs)
        fut: Future = Future()
        t = time.perf_counter()
        with self._lock:
            rid = self._rid
            self._rid += 1
            tk = Ticket(rid=rid, fp=fp, future=fut, t_submit=t)
            self._tickets.append(tk)
            if self._in_system >= self.admit_capacity:
                self.stats.shed += 1
                shed = True
            else:
                self.stats.admitted += 1
                self._in_system += 1
                self._queues[fp].append(_AsyncRequest(
                    rid=rid, fp=fp, rhs=rhs, future=fut, t_submit=t))
                self._work.notify()
                shed = False
        if shed:
            fut.set_result(Shed(rid=rid, fp=fp))
        return tk

    def in_system(self) -> int:
        """Requests admitted and not yet completed (queued + in flight)."""
        with self._lock:
            return self._in_system

    def on_membership(self, alive: int, total: int) -> int:
        """Scale admission to the live fraction of the worker fleet.

        The elastic integration point: when the fleet shrinks (deaths
        reported by a ``HeartbeatMonitor`` / ``ElasticRuntime`` event
        stream), per-batch latency rises — so admission must shrink with
        it or queueing delay grows unboundedly.  Overload under a
        shrunken fleet therefore degrades AVAILABILITY (explicit ``Shed``
        at admission), never correctness or the latency of admitted work.
        Capacity recovers automatically when the fleet does (call again
        with the new alive count); it never drops below 1, so the server
        keeps serving as long as any worker lives.  Returns the new
        ``admit_capacity``.
        """
        if total < 1:
            raise ValueError(f"total workers must be >= 1, got {total}")
        if not 0 <= alive <= total:
            raise ValueError(
                f"alive={alive} must be within [0, total={total}]")
        with self._lock:
            self.admit_capacity = max(
                1, int(self._admit_base * alive / total))
            return self.admit_capacity

    # ----- stage 2: batch assembly (host thread) ----------------------------
    def _next_group(self):
        """Under the lock: oldest-pending eligible system -> FIFO group.

        The selection rule and the ``take_group`` coalescing/padding are
        the sync server's.  With ``warm_start`` on, a system whose batch
        is still in flight is skipped (its next batch needs that batch's
        final states) — other systems keep the pipeline full meanwhile.
        """
        pending = [(q[0].rid, fp) for fp, q in self._queues.items()
                   if q and fp not in self._busy]
        if not pending:
            return None
        fp = min(pending)[1]
        group, n_real = take_group(self._queues[fp], self.batch)
        if self.warm_start:
            self._busy.add(fp)
        return fp, group, n_real

    def _assemble_loop(self):
        while True:
            with self._work:
                item = self._next_group()
                while item is None:
                    if self._stopping:
                        return
                    self._work.wait(0.05)
                    item = self._next_group()
            fp, group, n_real = item
            try:
                work = self._assemble(fp, group, n_real)
            except Exception as e:               # noqa: BLE001 — stage must
                self._complete_error(fp, group[:n_real], e)   # not die
                continue
            # bounded hand-off: blocks while pipeline_depth batches are in
            # flight — THE backpressure between assembly and execution
            self._slots.acquire()
            with self._lock:
                self._inflight += 1
            self._pool.submit(self._execute, work)

    def _assemble(self, fp: str, group, n_real: int) -> _Work:
        """Store lookup, executor acquisition, placement — all identical
        to the sync ``step()`` (single assembly thread, so the per-system
        placement cache and the executor cache need no extra locking)."""
        ent = self._systems[fp]
        factors = self.store.factors(self.solver, ent.sys, key=fp,
                                     use_kernel=ent.use_kernel, **ent.prm)
        ex = self._executor(ent)
        if ent.placed_src is not factors:        # first batch/post-eviction
            ent.A_placed, ent.factors_placed = ex.place_system(ent.sys,
                                                               factors)
            ent.placed_src = factors
        Bb = np.stack([r.rhs for r in group]).reshape(
            len(group), ent.sys.m, ent.sys.p)
        warm = self._warm_ok(ent, Bb)
        # host->device on THIS thread: the transfer of the next batch
        # double-buffers behind the executing one
        Bb_dev = ex.place_B(Bb)
        return _Work(fp=fp, ent=ent, ex=ex, group=list(group),
                     n_real=n_real, Bb=Bb, Bb_dev=Bb_dev, warm=warm)

    # ----- stage 3+4: execution pool, streaming completion ------------------
    def _execute(self, w: _Work) -> None:
        try:
            states, X, res = w.ex.run(
                w.ent.A_placed, w.ent.factors_placed, w.Bb_dev,
                w.ent.last_states if w.warm else None)
            X = np.asarray(X)                    # blocks until device done
            res = np.asarray(res)
            to_tol = np.atleast_1d(iters_to_tolerance(res, self.tol))
            t_done = time.perf_counter()
            out = [Served(rid=r.rid, fp=w.fp, x=X[i],
                          residual=float(res[i, -1]),
                          iters_to_tol=int(to_tol[i]), warm=w.warm)
                   for i, r in enumerate(w.group[:w.n_real])]
            with self._lock:
                if self.warm_start:
                    w.ent.last_states, w.ent.last_Bb = states, w.Bb
                    self._busy.discard(w.fp)     # unblocks warm chaining
                self.stats.batches += 1
                self.stats.served += w.n_real
                self.stats.padded += len(w.group) - w.n_real
                self.stats.warm_batches += int(w.warm)
                for r in w.group[:w.n_real]:
                    self._lat.append(t_done - r.t_submit)
                self._in_system -= w.n_real
                self._inflight -= 1
                self._work.notify_all()
                self._idle.notify_all()
            for r, s in zip(w.group[:w.n_real], out):
                r.future.set_result(s)
        except Exception as e:                   # noqa: BLE001
            with self._lock:
                self._busy.discard(w.fp)
                self._in_system -= w.n_real
                self._inflight -= 1
                self._work.notify_all()
                self._idle.notify_all()
            for r in w.group[:w.n_real]:
                if not r.future.done():
                    r.future.set_exception(e)
        finally:
            self._slots.release()

    def _complete_error(self, fp, requests, exc) -> None:
        with self._lock:
            self._busy.discard(fp)
            self._in_system -= len(requests)
            self._work.notify_all()
            self._idle.notify_all()
        for r in requests:
            if not r.future.done():
                r.future.set_exception(exc)

    # ----- draining / reporting ---------------------------------------------
    def step(self):
        raise RuntimeError(
            "AsyncLinsysServer serves on its pipeline threads: submit() "
            "returns a Ticket whose future streams the result; use "
            "drain() (or ticket.result()) instead of step()")

    def drain(self) -> List[Result]:
        """Block until every ticket since the last drain resolved; return
        the results in submission (rid) order — ``Served`` for admitted
        requests, ``Shed`` for rejected ones.  With zero outstanding
        tickets this is a true no-op ([] — no threads started, no
        executor compile, jit cache unchanged)."""
        with self._lock:
            tickets, self._tickets = self._tickets, []
            has_work = self._in_system > 0
        if not tickets:
            return []
        if has_work:
            self.start()
        return [t.future.result() for t in tickets]

    def latencies(self) -> np.ndarray:
        """Per-request submit→result latencies (seconds) so far."""
        with self._lock:
            return np.asarray(self._lat, dtype=float)

    def reset_metrics(self) -> None:
        """Clear the latency record and traffic counters (keeps executors,
        placements, and warm states — benchmarks prime then measure)."""
        with self._lock:
            self._lat = []
            builds = self.stats.executor_builds
            self.stats = type(self.stats)(executor_builds=builds)

    def latency_report(self) -> dict:
        """The SLO view: count, p50/p95/p99/mean/max in milliseconds."""
        lat = self.latencies()
        if lat.size == 0:
            return {"count": 0, "p50_ms": float("nan"),
                    "p95_ms": float("nan"), "p99_ms": float("nan"),
                    "mean_ms": float("nan"), "max_ms": float("nan")}
        q = np.percentile(lat, [50, 95, 99]) * 1e3
        return {"count": int(lat.size), "p50_ms": float(q[0]),
                "p95_ms": float(q[1]), "p99_ms": float(q[2]),
                "mean_ms": float(lat.mean() * 1e3),
                "max_ms": float(lat.max() * 1e3)}
