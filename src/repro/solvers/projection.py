"""Projection-family solvers: APC, plain projection consensus, block Cimmino.

All three share the per-worker null-space projection machinery of
``core/apc.py`` (Gram Cholesky factors, P_i v = v - A^T G^{-1} A v), support
the Pallas kernel path uniformly (``use_kernel=True``), and auto-tune their
parameters from the Theorem-1 spectral analysis of X when none are given.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import blockops
from repro.core import spectral
from repro.core import apc as apc_core
from repro.core.apc import APCState, _gram_chol, _gram_solve
from repro.core.partition import BlockSystem

from .api import Solver
from .registry import register


class ProjFactors(NamedTuple):
    """b-independent per-worker factors (leading axis = worker)."""
    A: jnp.ndarray      # (m, p, n) row blocks, or a blockops.SparseBlocks
    chol: jnp.ndarray   # (m, p, p) Cholesky of Gram A_i A_i^T
    B: Optional[jnp.ndarray] = None  # pinv factors A^T G^{-1}: (m, n, p)
                                     # dense, (m, w, p) support-compressed
                                     # for SparseBlocks operands (kernel
                                     # path only, see kernel_factors)


def _proj_prepare(A, jitter: float) -> ProjFactors:
    if blockops.is_sparse(A):
        # support-compressed Gram — exact (padded columns carry zeros)
        G = blockops.bgram(A)
        if jitter:
            p = G.shape[-1]
            tr = jnp.trace(G, axis1=-2, axis2=-1)[:, None, None]
            G = G + jitter * tr / p * jnp.eye(p, dtype=G.dtype)
        return ProjFactors(A=A, chol=jnp.linalg.cholesky(G))
    chol = jax.vmap(lambda Ai: _gram_chol(Ai, jitter))(A)
    return ProjFactors(A=A, chol=chol)


def _with_pinv(factors: ProjFactors) -> ProjFactors:
    """Precompute B_i = A_i^T G_i^{-1} once (iteration-invariant).

    Sparse operands get the SUPPORT-COMPRESSED pinv: B_i has rows only on
    the block's column support, so Bvals_i = (G_i^{-1} vals_i)^T is the
    full factor stored as (w, p) on the same ``cols`` — padded support
    slots carry exact-zero vals columns and therefore exact-zero Bvals
    rows, keeping every kernel contraction exact.
    """
    if factors.B is not None:
        return factors
    if blockops.is_sparse(factors.A):
        B = jax.vmap(
            lambda Vi, Li: jax.scipy.linalg.cho_solve((Li, True), Vi).T)(
                factors.A.vals, factors.chol)          # (m, w, p)
        return factors._replace(B=B)
    B = jax.vmap(lambda Ai, Li: jax.scipy.linalg.cho_solve((Li, True), Ai).T)(
        factors.A, factors.chol)
    return factors._replace(B=B)


def _min_norm_solutions(factors: ProjFactors, b: jnp.ndarray) -> jnp.ndarray:
    """x0_i = A_i^T (A_i A_i^T)^{-1} b_i — the min-norm local solutions."""
    if blockops.is_sparse(factors.A):
        return blockops.brmatvec(factors.A,
                                 _cho_solve_workers(factors.chol, b))
    return jax.vmap(lambda Ai, Li, bi: Ai.T @ _gram_solve(Li, bi))(
        factors.A, factors.chol, b)


def _cho_solve_workers(chol, u):
    """Per-worker G_i^{-1} u_i with the stored Cholesky factors."""
    return jax.vmap(
        lambda Li, ui: jax.scipy.linalg.cho_solve((Li, True), ui))(chol, u)


def _cho_solve_replicas(chol, u):
    """Replicated form: leading (m, r) worker x slot axes."""
    return jax.vmap(_cho_solve_workers)(chol, u)


def _sparse_use_fused(family: str, Asp, k: int) -> bool:
    """Trace-time engine choice for the compressed-support kernel pair."""
    from repro.kernels import ops as kops
    return kops.use_fused(family, Asp.vals.shape[1], blockops.ncols(Asp),
                          k, Asp.vals.dtype, w=Asp.vals.shape[2])


def _cast_proj_factors(factors: ProjFactors, precision: str) -> ProjFactors:
    """``precision="mixed"``: bf16 storage for the streamed A/B tiles.

    Only the memory-bound tile streams are cast — the Cholesky factors
    (and every cho_solve against them) stay in the working precision, and
    the kernels accumulate every contraction in f32 (see
    ``kernels/block_projection``).  Residual histories then hold to the
    bf16 storage tolerance (~1e-2 relative) while halving the HBM bytes
    of the dominant per-iteration reads.
    """
    if precision == "default":
        return factors
    if blockops.is_sparse(factors.A):
        A = factors.A._replace(vals=factors.A.vals.astype(jnp.bfloat16))
    else:
        A = factors.A.astype(jnp.bfloat16)
    B = None if factors.B is None else factors.B.astype(jnp.bfloat16)
    return ProjFactors(A=A, chol=factors.chol, B=B)


def _mesh_gram_chol(A, jitter: float, ctx):
    """Cholesky of the full Gram A_i A_i^T from column-sharded blocks."""
    G = ctx.psum_model(blockops.bgram(A))
    if jitter:
        p = G.shape[-1]
        tr = jnp.trace(G, axis1=-2, axis2=-1)[:, None, None]
        G = G + jitter * tr / p * jnp.eye(p, dtype=G.dtype)
    return jnp.linalg.cholesky(G)


@register("apc")
class APCSolver(Solver):
    """Accelerated Projection-based Consensus (paper Algorithm 1)."""

    paper_name = "APC"
    supports_kernel = True
    param_names = ("gamma", "eta")
    # the paper's convergence theory (Theorem 1) assumes an exact solution
    # exists, so APC keeps its square-only contract; sparse blocks are fine
    supports = frozenset({"square", "sparse"})

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        prm = spectral.apc_optimal(*spectral.mu_extremes(X))
        return {"gamma": prm.gamma, "eta": prm.eta}, prm.rho

    def prepare(self, A, params):
        return _proj_prepare(A, params.get("jitter", 0.0))

    def kernel_factors(self, factors):
        return _with_pinv(factors)

    def init(self, factors, b, params):
        x0 = _min_norm_solutions(factors, b)
        return APCState(x=x0, xbar=jnp.mean(x0, axis=0),
                        t=jnp.zeros((), jnp.int32))

    def step(self, factors, b, state, params, *, use_kernel=False):
        gamma, eta = params["gamma"], params["eta"]
        if blockops.is_sparse(factors.A):
            Asp = factors.A
            if (use_kernel and factors.B is not None
                    and _sparse_use_fused("apc_sparse", Asp, 1)):
                from repro.kernels import ops as kops

                # fused compressed-support pair: one VMEM residency of the
                # (p, w) vals / (w, p) Bvals tiles per worker
                def worker(Vi, ci, Bvi, xi):
                    return kops.sparse_proj_update(Vi, ci, Bvi, xi,
                                                   state.xbar, gamma)[0]

                x_new = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B,
                                         state.x)
            else:
                # mask-aware products on the column support (same update
                # as the unfused mesh formulation below)
                d = state.xbar[None, :] - state.x
                u = blockops.bmatvec_each(factors.A, d)
                w = _cho_solve_workers(factors.chol, u)
                proj = d - blockops.brmatvec(factors.A, w)
                x_new = state.x + gamma * proj
            xbar_new = (eta * jnp.mean(x_new, axis=0)
                        + (1.0 - eta) * state.xbar)
            return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            # the engine autotune includes "unfused" as a candidate: when
            # the fused pair loses at this (p, n, k=1, dtype) the step
            # falls through to the plain XLA path below (trace-time
            # choice — baked into the compiled executor, never retraced)
            if kops.use_fused("apc", factors.A.shape[1], factors.A.shape[2],
                              1, factors.A.dtype):
                def worker(Ai, Bi, xi):
                    return kops.block_projection(Ai, Bi, xi, state.xbar,
                                                 gamma)

                x_new = jax.vmap(worker)(factors.A, factors.B, state.x)
                xbar_new = (eta * jnp.mean(x_new, axis=0)
                            + (1.0 - eta) * state.xbar)
                return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)
            use_kernel = False                   # measured fallback
        legacy = apc_core.APCFactors(A=factors.A, chol=factors.chol,
                                     x0=None, b=None)
        return apc_core.apc_step(legacy, state, gamma, eta,
                                 use_kernel=use_kernel)

    def step_many(self, factors, Bb, states, params, *, use_kernel=False):
        """Fused multi-RHS iteration: the k batch rows stream through ONE
        VMEM residency of every A/B tile (states.x (k, m, n))."""
        if not (use_kernel and factors.B is not None):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=use_kernel)
        from repro.kernels import ops as kops
        gamma, eta = params["gamma"], params["eta"]
        if blockops.is_sparse(factors.A):
            Asp = factors.A
            if not _sparse_use_fused("apc_sparse", Asp, Bb.shape[0]):
                return super().step_many(factors, Bb, states, params,
                                         use_kernel=False)  # measured fb
            X = jnp.swapaxes(states.x, 0, 1)              # (m, k, n)

            def worker(Vi, ci, Bvi, Xi):
                return kops.sparse_proj_update(Vi, ci, Bvi, Xi,
                                               states.xbar, gamma)[0]

            x_new = jnp.swapaxes(jax.vmap(worker)(
                Asp.vals, Asp.cols, factors.B, X), 0, 1)  # (k, m, n)
            xbar_new = (eta * jnp.mean(x_new, axis=1)
                        + (1.0 - eta) * states.xbar)
            return APCState(x=x_new, xbar=xbar_new, t=states.t + 1)
        if not kops.use_fused("apc", factors.A.shape[1], factors.A.shape[2],
                              Bb.shape[0], factors.A.dtype):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=False)   # measured fallback
        X = jnp.swapaxes(states.x, 0, 1)                  # (m, k, n)

        def worker(Ai, Bi, Xi):
            return kops.block_projection(Ai, Bi, Xi, states.xbar, gamma)

        x_new = jnp.swapaxes(
            jax.vmap(worker)(factors.A, factors.B, X), 0, 1)   # (k, m, n)
        xbar_new = (eta * jnp.mean(x_new, axis=1)
                    + (1.0 - eta) * states.xbar)
        return APCState(x=x_new, xbar=xbar_new, t=states.t + 1)

    # ----- fused residual --------------------------------------------------
    # The iterates satisfy A_i x_i = b_i exactly (min-norm init, preserved
    # by the projection since A_i B_i = I), so the gather pass's result
    # u_i = A_i(x̄ − x_i) IS the residual block A_i x̄ − b_i of the CONSUMED
    # state — the history costs no second read of A per iteration.  The
    # drivers in ``api._history_scan`` shift the lagged records by one and
    # close with a single true-A residual of the final state.
    supports_fused_residual = True

    def cast_factors(self, factors, precision):
        return _cast_proj_factors(factors, precision)

    def _step_u(self, factors, state, gamma):
        """One worker update plus the gather result u (the residual
        source); engine dispatch identical to ``step``."""
        kern = factors.B is not None
        sparse = blockops.is_sparse(factors.A)
        if kern:
            if sparse:
                kern = _sparse_use_fused("apc_sparse", factors.A, 1)
            else:
                from repro.kernels import ops as kops
                kern = kops.use_fused("apc", factors.A.shape[1],
                                      factors.A.shape[2], 1,
                                      factors.A.dtype)
        if kern and sparse:
            from repro.kernels import ops as kops
            Asp = factors.A

            def worker(Vi, ci, Bvi, xi):
                return kops.sparse_proj_update(Vi, ci, Bvi, xi,
                                               state.xbar, gamma)

            x_new, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B,
                                        state.x)
        elif kern:
            from repro.kernels import ops as kops
            u = jax.vmap(
                lambda Ai, xi: kops.proj_gather(Ai, xi, state.xbar))(
                    factors.A, state.x)                   # (m, p)
            x_new = jax.vmap(
                lambda Bi, xi, ui: kops.proj_scatter(Bi, xi, state.xbar,
                                                     ui, gamma))(
                    factors.B, state.x, u)
        else:
            d = state.xbar[None, :] - state.x
            u = blockops.bmatvec_each(factors.A, d)
            w = _cho_solve_workers(factors.chol, u)
            proj = d - blockops.brmatvec(factors.A, w)
            x_new = state.x + gamma * proj
        return x_new, u

    def step_residual(self, factors, b, state, params):
        gamma, eta = params["gamma"], params["eta"]
        x_new, u = self._step_u(factors, state, gamma)
        xbar_new = (eta * jnp.mean(x_new, axis=0)
                    + (1.0 - eta) * state.xbar)
        return (APCState(x=x_new, xbar=xbar_new, t=state.t + 1),
                jnp.sum(u * u))

    def step_many_residual(self, factors, Bb, states, params):
        gamma, eta = params["gamma"], params["eta"]
        kern = factors.B is not None
        sparse = blockops.is_sparse(factors.A)
        k = Bb.shape[0]
        if kern:
            if sparse:
                kern = _sparse_use_fused("apc_sparse", factors.A, k)
            else:
                from repro.kernels import ops as kops
                kern = kops.use_fused("apc", factors.A.shape[1],
                                      factors.A.shape[2], k,
                                      factors.A.dtype)
        if kern:
            from repro.kernels import ops as kops
            X = jnp.swapaxes(states.x, 0, 1)              # (m, k, n)
            if sparse:
                Asp = factors.A

                def worker(Vi, ci, Bvi, Xi):
                    return kops.sparse_proj_update(Vi, ci, Bvi, Xi,
                                                   states.xbar, gamma)

                x_new, u = jax.vmap(worker)(Asp.vals, Asp.cols,
                                            factors.B, X)
            else:
                u = jax.vmap(
                    lambda Ai, Xi: kops.proj_gather(Ai, Xi, states.xbar))(
                        factors.A, X)                     # (m, k, p)
                x_new = jax.vmap(
                    lambda Bi, Xi, ui: kops.proj_scatter(
                        Bi, Xi, states.xbar, ui, gamma))(
                            factors.B, X, u)              # (m, k, n)
            x_new = jnp.swapaxes(x_new, 0, 1)             # (k, m, n)
            rsq = jnp.sum(u * u, axis=(0, 2))             # (k,)
        else:
            def one(xk, xbark):
                d = xbark[None, :] - xk
                uk = blockops.bmatvec_each(factors.A, d)
                w = _cho_solve_workers(factors.chol, uk)
                proj = d - blockops.brmatvec(factors.A, w)
                return xk + gamma * proj, uk

            x_new, u = jax.vmap(one)(states.x, states.xbar)
            rsq = jnp.sum(u * u, axis=(1, 2))             # (k,)
        xbar_new = (eta * jnp.mean(x_new, axis=1)
                    + (1.0 - eta) * states.xbar)
        return (APCState(x=x_new, xbar=xbar_new, t=states.t + 1), rsq)

    def extract(self, state):
        return state.xbar

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx, use_kernel=False):
        return ProjFactors(A=P(ctx.w, None, ctx.n),
                           chol=P(ctx.w, None, None),
                           B=P(ctx.w, ctx.n, None) if use_kernel else None)

    def mesh_state_specs(self, ctx):
        return APCState(x=P(ctx.w, ctx.n), xbar=P(ctx.n), t=P())

    def mesh_factors(self, factors, use_kernel=False):
        if use_kernel:
            return _with_pinv(factors)      # idempotent host augmentation
        return factors._replace(B=None)     # pinv factors are kernel-only

    def mesh_prepare(self, A, params, ctx, use_kernel=False):
        chol = _mesh_gram_chol(A, params.get("jitter", 0.0), ctx)
        factors = ProjFactors(A=A, chol=chol)
        if use_kernel:
            # B_loc = A_locᵀ G⁻¹ is shard-local given the FULL Gram's
            # Cholesky (cho_solve acts on the p axis only), so the pinv
            # augmentation runs on-mesh without materializing A anywhere
            factors = _with_pinv(factors)
        return factors

    def mesh_init(self, factors, b, params, ctx):
        w = _cho_solve_workers(factors.chol, b)
        x0 = blockops.brmatvec(factors.A, w)          # min-norm local sols
        m = ctx.workers_total(x0.shape[0])
        xbar0 = ctx.psum_workers(jnp.sum(x0, axis=0)) / m
        return APCState(x=x0, xbar=xbar0, t=jnp.zeros((), jnp.int32))

    def _mesh_step_u(self, factors, state, gamma, ctx, use_kernel):
        """Shared Eq. 2a body on local shards: (x_new, full u)."""
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            if blockops.is_sparse(factors.A):
                # sparse systems shard over worker axes only (model_axis
                # is None — cols index the global n), so the per-worker
                # fused pair composes directly and u is already full
                Asp = factors.A

                def worker(Vi, ci, Bvi, xi):
                    return kops.sparse_proj_update(Vi, ci, Bvi, xi,
                                                   state.xbar, gamma)

                x_new, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B,
                                            state.x)
                return x_new, ctx.psum_model(u)
            u_loc = jax.vmap(
                lambda Ai, xi: kops.proj_gather(Ai, xi, state.xbar))(
                    factors.A, state.x)               # (m_loc, p)
            u = ctx.psum_model(u_loc)                 # full u = A_i d
            x_new = jax.vmap(
                lambda Bi, xi, ui: kops.proj_scatter(Bi, xi, state.xbar,
                                                     ui, gamma))(
                    factors.B, state.x, u)            # Eq. 2a, fused
            return x_new, u
        d = state.xbar[None, :] - state.x             # (m_loc, n_loc)
        u = ctx.psum_model(blockops.bmatvec_each(factors.A, d))
        w = _cho_solve_workers(factors.chol, u)       # G^{-1} A_i d
        proj = d - blockops.brmatvec(factors.A, w)
        return state.x + gamma * proj, u              # Eq. 2a

    def mesh_step(self, factors, b, state, params, ctx, *, use_kernel=False):
        gamma, eta = params["gamma"], params["eta"]
        x_new, _ = self._mesh_step_u(factors, state, gamma, ctx, use_kernel)
        m = ctx.workers_total(x_new.shape[0])
        s = ctx.psum_workers(jnp.sum(x_new, axis=0))      # Eq. 2b psum
        xbar_new = (eta / m) * s + (1.0 - eta) * state.xbar
        return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)

    def mesh_step_residual(self, factors, b, state, params, ctx):
        """Mesh step plus the consumed state's GLOBAL squared residual,
        psum'd from the gather results (see the local hook)."""
        gamma, eta = params["gamma"], params["eta"]
        x_new, u = self._mesh_step_u(factors, state, gamma, ctx, True)
        m = ctx.workers_total(x_new.shape[0])
        s = ctx.psum_workers(jnp.sum(x_new, axis=0))
        xbar_new = (eta / m) * s + (1.0 - eta) * state.xbar
        rsq = ctx.psum_workers(jnp.sum(u * u))
        return APCState(x=x_new, xbar=xbar_new, t=state.t + 1), rsq

    def _mesh_step_many_u(self, factors, states, gamma, ctx):
        """Batched Eq. 2a body: (x_new (k, m_loc, n_loc), full u)."""
        from repro.kernels import ops as kops
        X = jnp.swapaxes(states.x, 0, 1)                  # (m_loc, k, n_loc)
        if blockops.is_sparse(factors.A):
            Asp = factors.A

            def worker(Vi, ci, Bvi, Xi):
                return kops.sparse_proj_update(Vi, ci, Bvi, Xi,
                                               states.xbar, gamma)

            x_new, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, X)
            return jnp.swapaxes(x_new, 0, 1), ctx.psum_model(u)
        u_loc = jax.vmap(
            lambda Ai, Xi: kops.proj_gather(Ai, Xi, states.xbar))(
                factors.A, X)                             # (m_loc, k, p)
        u = ctx.psum_model(u_loc)
        x_new = jnp.swapaxes(jax.vmap(
            lambda Bi, Xi, ui: kops.proj_scatter(Bi, Xi, states.xbar,
                                                 ui, gamma))(
                factors.B, X, u), 0, 1)                   # (k, m_loc, n_loc)
        return x_new, u

    def mesh_step_many(self, factors, Bb, states, params, ctx, *,
                       use_kernel=False):
        if not (use_kernel and factors.B is not None):
            return super().mesh_step_many(factors, Bb, states, params, ctx)
        gamma, eta = params["gamma"], params["eta"]
        x_new, _ = self._mesh_step_many_u(factors, states, gamma, ctx)
        m = ctx.workers_total(x_new.shape[1])
        s = ctx.psum_workers(jnp.sum(x_new, axis=1))      # (k, n_loc)
        xbar_new = (eta / m) * s + (1.0 - eta) * states.xbar
        return APCState(x=x_new, xbar=xbar_new, t=states.t + 1)

    def mesh_step_many_residual(self, factors, Bb, states, params, ctx):
        gamma, eta = params["gamma"], params["eta"]
        if factors.B is not None:
            x_new, u = self._mesh_step_many_u(factors, states, gamma, ctx)
            rsq = ctx.psum_workers(jnp.sum(u * u, axis=(0, 2)))   # (k,)
        else:
            def one(xk, xbark):
                d = xbark[None, :] - xk
                uk = ctx.psum_model(blockops.bmatvec_each(factors.A, d))
                w = _cho_solve_workers(factors.chol, uk)
                proj = d - blockops.brmatvec(factors.A, w)
                return xk + gamma * proj, uk

            x_new, u = jax.vmap(one)(states.x, states.xbar)
            rsq = ctx.psum_workers(jnp.sum(u * u, axis=(1, 2)))
        m = ctx.workers_total(x_new.shape[1])
        s = ctx.psum_workers(jnp.sum(x_new, axis=1))
        xbar_new = (eta / m) * s + (1.0 - eta) * states.xbar
        return APCState(x=x_new, xbar=xbar_new, t=states.t + 1), rsq

    # ----- redundant execution (solvers/redundant.py) ---------------------
    # Internal state keeps the APCState structure with x grown to the
    # replicated (m, r, n) layout; xbar stays global.  Eq. 2b becomes the
    # W-masked block-unique mean — the same worker-axis psum as above.
    supports_redundancy = True

    def red_init(self, factors, b, params, W0, ctx):
        w = _cho_solve_replicas(factors.chol, b)
        x0 = jnp.einsum("mrpn,mrp->mrn", factors.A, w)    # min-norm per slot
        m = ctx.workers_total(x0.shape[0])
        xbar0 = ctx.psum_workers(jnp.einsum("mr,mrn->n", W0, x0)) / m
        return APCState(x=x0, xbar=xbar0, t=jnp.zeros((), jnp.int32))

    def red_step(self, factors, b, state, params, W, ctx):
        gamma, eta = params["gamma"], params["eta"]
        d = state.xbar[None, None, :] - state.x           # (m, r, n)
        u = ctx.psum_model(jnp.einsum("mrpn,mrn->mrp", factors.A, d))
        w = _cho_solve_replicas(factors.chol, u)
        proj = d - jnp.einsum("mrpn,mrp->mrn", factors.A, w)
        x_new = state.x + gamma * proj                    # every replica
        m = ctx.workers_total(x_new.shape[0])
        s = ctx.psum_workers(jnp.einsum("mr,mrn->n", W, x_new))
        xbar_new = (eta / m) * s + (1.0 - eta) * state.xbar
        return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)

    def red_expand(self, state, assign):
        x = jnp.asarray(state.x)
        return APCState(x=x[assign.holder], xbar=jnp.asarray(state.xbar),
                        t=state.t)

    def red_collapse(self, state, assign):
        # slot 0 of worker j holds block j, and replicas are identical
        return APCState(x=state.x[:, 0], xbar=state.xbar, t=state.t)

    def red_state_specs(self, ctx):
        return APCState(x=P(ctx.w, None, ctx.n), xbar=P(ctx.n), t=P())

    # ----- cross-partition warm start (solvers/elastic.py) ------------------
    # APC states are partition-specific: each x_i must satisfy A_i x_i =
    # b_i for THIS partition's blocks.  The lift projects the global
    # estimate onto every new block's feasible set — x_i = x + A_iᵀ
    # G_i⁻¹(b_i − A_i x) — so the invariant the step relies on holds from
    # the first post-repartition iteration, with x̄ carrying x verbatim.
    supports_lift = True
    supports_block_store = True    # per-block Gram Cholesky, leading m axis

    def lift_state(self, factors, b, params, x):
        x = jnp.asarray(x)
        v = b - blockops.bmatvec(factors.A, x)            # (m, p)
        w = _cho_solve_workers(factors.chol, v)
        xi = x[None, :] + blockops.brmatvec(factors.A, w)
        return APCState(x=xi, xbar=x, t=jnp.zeros((), jnp.int32))


@register("consensus")
class ConsensusSolver(APCSolver):
    """Plain projection consensus [11,14] == APC with gamma = eta = 1."""

    paper_name = "Consensus"

    def default_params(self, sys: BlockSystem):
        return {"gamma": 1.0, "eta": 1.0}

    def theoretical_rate(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        mu_min, _ = spectral.mu_extremes(X)
        return spectral.consensus_rate(mu_min)

    def analyze(self, sys: BlockSystem):
        return self.default_params(sys), self.theoretical_rate(sys)


class CimminoState(NamedTuple):
    xbar: jnp.ndarray   # (n,) master estimate
    t: jnp.ndarray      # ()   iteration counter


@register("cimmino")
class CimminoSolver(Solver):
    """Block Cimmino row projections (Sec 4.5; Proposition 2: APC gamma=1)."""

    paper_name = "B-Cimmino"
    supports_kernel = True
    param_names = ("nu",)
    # state is the master estimate alone and b enters every step, so a
    # prior state warm-starts perturbed right-hand sides too
    warm_rhs_ok = True
    # the fixed point Σ A_iᵀG_i⁻¹(b_i − A_i x̄) = 0 is the G⁻¹-weighted
    # least-squares optimum, well-defined for inconsistent systems too
    # (each block must stay row-independent: p ≤ n per block)
    supports = frozenset({"square", "least_squares", "sparse"})

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        nu_m, rho = spectral.cimmino_optimal(*spectral.mu_extremes(X))
        return {"nu": nu_m / sys.m}, rho

    def prepare(self, A, params):
        return _proj_prepare(A, params.get("jitter", 0.0))

    def kernel_factors(self, factors):
        return _with_pinv(factors)

    def init(self, factors, b, params):
        n = blockops.ncols(factors.A)
        # state dtype follows b, not the stored blocks: under
        # precision="mixed" the A/B tiles are bf16 storage but the
        # iterate (and every accumulation) stays in the working precision
        return CimminoState(xbar=jnp.zeros(n, b.dtype),
                            t=jnp.zeros((), jnp.int32))

    def step(self, factors, b, state, params, *, use_kernel=False):
        nu = params["nu"]
        if blockops.is_sparse(factors.A):
            Asp = factors.A
            if (use_kernel and factors.B is not None
                    and _sparse_use_fused("cimmino_sparse", Asp, 1)):
                from repro.kernels import ops as kops

                def worker(Vi, ci, Bvi, bi):
                    return kops.sparse_cimmino_update(Vi, ci, Bvi, bi,
                                                      state.xbar)[0]

                r = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, b)
            else:
                u = blockops.bmatvec(factors.A, state.xbar)
                w = _cho_solve_workers(factors.chol, b - u)
                r = blockops.brmatvec(factors.A, w)   # row projections
            return CimminoState(xbar=state.xbar + nu * jnp.sum(r, axis=0),
                                t=state.t + 1)
        kern = use_kernel and factors.B is not None
        if kern:
            # single-RHS cimmino is the measured corner where the fused
            # pair LOSES (no batch to amortize the A/B tile reads) — the
            # engine autotune includes "unfused" as a candidate and this
            # dispatch honors it at trace time
            from repro.kernels import ops as kops
            kern = kops.use_fused("cimmino", factors.A.shape[1],
                                  factors.A.shape[2], 1, factors.A.dtype)
        if kern:
            from repro.kernels import ops as kops

            # the dedicated Cimmino kernel pair: r_i = B_i (b_i − A_i x̄)
            # (B = A^T G^{-1} bakes the Gram inverse in, so no per-step
            # cho_solve and no rewrite onto the APC update shape)
            def worker(Ai, Bi, bi):
                return kops.cimmino_update(Ai, Bi, bi, state.xbar)

            r = jax.vmap(worker)(factors.A, factors.B, b)
        else:
            def worker(Ai, Li, bi):
                u = jax.scipy.linalg.cho_solve((Li, True), bi - Ai @ state.xbar)
                return Ai.T @ u

            r = jax.vmap(worker)(factors.A, factors.chol, b)
        return CimminoState(xbar=state.xbar + nu * jnp.sum(r, axis=0),
                            t=state.t + 1)

    def step_many(self, factors, Bb, states, params, *, use_kernel=False):
        """Fused multi-RHS row projections (Bb (k, m, p), x̄ (k, n))."""
        if not (use_kernel and factors.B is not None):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=use_kernel)
        from repro.kernels import ops as kops
        if blockops.is_sparse(factors.A):
            Asp = factors.A
            if not _sparse_use_fused("cimmino_sparse", Asp, Bb.shape[0]):
                return super().step_many(factors, Bb, states, params,
                                         use_kernel=False)  # measured fb
            bw = jnp.swapaxes(Bb, 0, 1)                   # (m, k, p)

            def worker(Vi, ci, Bvi, bi):
                return kops.sparse_cimmino_update(Vi, ci, Bvi, bi,
                                                  states.xbar)[0]

            r = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, bw)
            return CimminoState(
                xbar=states.xbar + params["nu"] * jnp.sum(r, 0),
                t=states.t + 1)
        if not kops.use_fused("cimmino", factors.A.shape[1],
                              factors.A.shape[2], Bb.shape[0],
                              factors.A.dtype):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=False)   # measured fallback
        bw = jnp.swapaxes(Bb, 0, 1)                       # (m, k, p)

        def worker(Ai, Bi, bi):
            return kops.cimmino_update(Ai, Bi, bi, states.xbar)   # (k, n)

        r = jax.vmap(worker)(factors.A, factors.B, bw)    # (m, k, n)
        return CimminoState(xbar=states.xbar + params["nu"] * jnp.sum(r, 0),
                            t=states.t + 1)

    # ----- fused residual --------------------------------------------------
    # The gather result u_i = A_i x̄ gives the consumed state's residual
    # blocks directly: A x̄ − b = u − b = −v where v = b − u is exactly the
    # operand the scatter consumes, so the history rides along for free.
    supports_fused_residual = True

    def cast_factors(self, factors, precision):
        return _cast_proj_factors(factors, precision)

    def _r_v(self, factors, b, xbar):
        """Row projections r plus v = b − A x̄ (the residual source);
        engine dispatch identical to ``step``.  Batch-polymorphic: b may
        be (m, p) or (m, k, p) with xbar (n,) / (k, n)."""
        k = b.shape[1] if b.ndim == 3 else 1
        sparse = blockops.is_sparse(factors.A)
        kern = factors.B is not None
        if kern:
            if sparse:
                kern = _sparse_use_fused("cimmino_sparse", factors.A, k)
            else:
                from repro.kernels import ops as kops
                kern = kops.use_fused("cimmino", factors.A.shape[1],
                                      factors.A.shape[2], k,
                                      factors.A.dtype)
        if kern and sparse:
            from repro.kernels import ops as kops
            Asp = factors.A

            def worker(Vi, ci, Bvi, bi):
                return kops.sparse_cimmino_update(Vi, ci, Bvi, bi, xbar)

            r, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, b)
        elif kern:
            from repro.kernels import ops as kops
            u = jax.vmap(lambda Ai: kops.cimmino_gather(Ai, xbar))(
                factors.A)                                # (m[, k], p)
            r = jax.vmap(kops.cimmino_scatter)(factors.B, b - u)
        else:
            def one(bk, xk):
                uk = blockops.bmatvec(factors.A, xk)      # (m, p)
                wk = _cho_solve_workers(factors.chol, bk - uk)
                return blockops.brmatvec(factors.A, wk), bk - uk

            if b.ndim == 2:
                return one(b, xbar)
            # batched: map the k axis (b (m, k, p) ax 1, xbar (k, n) ax 0)
            return jax.vmap(one, in_axes=(1, 0), out_axes=(1, 1))(b, xbar)
        return r, b - u

    def step_residual(self, factors, b, state, params):
        r, v = self._r_v(factors, b, state.xbar)
        return (CimminoState(xbar=state.xbar + params["nu"] * jnp.sum(r, 0),
                             t=state.t + 1),
                jnp.sum(v * v))

    def step_many_residual(self, factors, Bb, states, params):
        bw = jnp.swapaxes(Bb, 0, 1)                       # (m, k, p)
        r, v = self._r_v(factors, bw, states.xbar)        # (m, k, n/p)
        rsq = jnp.sum(v * v, axis=(0, 2))                 # (k,)
        return (CimminoState(
            xbar=states.xbar + params["nu"] * jnp.sum(r, 0),
            t=states.t + 1), rsq)

    def extract(self, state):
        return state.xbar

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx, use_kernel=False):
        return ProjFactors(A=P(ctx.w, None, ctx.n),
                           chol=P(ctx.w, None, None),
                           B=P(ctx.w, ctx.n, None) if use_kernel else None)

    def mesh_state_specs(self, ctx):
        return CimminoState(xbar=P(ctx.n), t=P())

    def mesh_factors(self, factors, use_kernel=False):
        if use_kernel:
            return _with_pinv(factors)
        return factors._replace(B=None)

    def mesh_prepare(self, A, params, ctx, use_kernel=False):
        factors = ProjFactors(
            A=A, chol=_mesh_gram_chol(A, params.get("jitter", 0.0), ctx))
        if use_kernel:
            factors = _with_pinv(factors)     # shard-local, see APCSolver
        return factors

    def _mesh_r_v(self, factors, b, xbar, ctx, use_kernel):
        """Local row projections r plus full v = b − A x̄ (the residual
        source) from local shards."""
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            if blockops.is_sparse(factors.A):
                # sparse systems shard over worker axes only (cols index
                # the global n), so the fused pair composes per worker
                Asp = factors.A

                def worker(Vi, ci, Bvi, bi):
                    return kops.sparse_cimmino_update(Vi, ci, Bvi, bi, xbar)

                r, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, b)
                return r, b - ctx.psum_model(u)
            u = ctx.psum_model(jax.vmap(
                lambda Ai: kops.cimmino_gather(Ai, xbar))(factors.A))
            return jax.vmap(kops.cimmino_scatter)(factors.B, b - u), b - u
        u = ctx.psum_model(blockops.bmatvec(factors.A, xbar))
        w = _cho_solve_workers(factors.chol, b - u)   # G^{-1}(b - A xbar)
        return blockops.brmatvec(factors.A, w), b - u  # row projections

    def mesh_step(self, factors, b, state, params, ctx, *, use_kernel=False):
        r, _ = self._mesh_r_v(factors, b, state.xbar, ctx, use_kernel)
        s = ctx.psum_workers(jnp.sum(r, axis=0))
        return CimminoState(xbar=state.xbar + params["nu"] * s,
                            t=state.t + 1)

    def mesh_step_residual(self, factors, b, state, params, ctx):
        """Mesh step plus ‖A x̄ − b‖² of the CONSUMED state, harvested
        from the gather pass (v = b − A x̄)."""
        r, v = self._mesh_r_v(factors, b, state.xbar, ctx, True)
        s = ctx.psum_workers(jnp.sum(r, axis=0))
        rsq = ctx.psum_workers(jnp.sum(v * v))
        return CimminoState(xbar=state.xbar + params["nu"] * s,
                            t=state.t + 1), rsq

    # ----- least-squares mode ---------------------------------------------
    # The Cimmino fixed point minimizes Σᵢ ‖L_i^{-1}(A_i x − b_i)‖² — the
    # Gram-whitened least-squares problem.  ``ls_moment`` is exactly the
    # update direction (zero at the optimum); ``ls_reference`` solves the
    # whitened system directly for error tracking.
    def ls_moment(self, factors, A, b, x, params, ctx):
        u = ctx.psum_model(blockops.bmatvec(A, x))
        w = _cho_solve_workers(factors.chol, b - u)
        r = blockops.brmatvec(A, w)
        return ctx.psum_workers(jnp.sum(r, axis=0))

    def ls_reference(self, sys: BlockSystem) -> jnp.ndarray:
        A = np.asarray(sys.A_blocks, dtype=np.float64)
        b = np.asarray(sys.b_blocks, dtype=np.float64)
        rows = []
        rhs = []
        for Ai, bi in zip(A, b):
            L = np.linalg.cholesky(Ai @ Ai.T)
            rows.append(np.linalg.solve(L, Ai))       # L_i^{-1} A_i
            rhs.append(np.linalg.solve(L, bi))        # L_i^{-1} b_i
        x, *_ = np.linalg.lstsq(np.concatenate(rows), np.concatenate(rhs),
                                rcond=None)
        return jnp.asarray(x, dtype=sys.b_blocks.dtype)

    def _mesh_r_v_many(self, factors, Bb, xbar, ctx):
        """Batched kernel-path row projections: r (m_loc, k, n_loc) and
        v = b − A x̄ (m_loc, k, p).  Bb (k, m_loc, p); x̄ (k, n_loc)."""
        from repro.kernels import ops as kops
        bw = jnp.swapaxes(Bb, 0, 1)                       # (m_loc, k, p)
        if blockops.is_sparse(factors.A):
            Asp = factors.A

            def worker(Vi, ci, Bvi, bi):
                return kops.sparse_cimmino_update(Vi, ci, Bvi, bi, xbar)

            r, u = jax.vmap(worker)(Asp.vals, Asp.cols, factors.B, bw)
            return r, bw - ctx.psum_model(u)
        # gather is RHS-batched per worker
        u = ctx.psum_model(jax.vmap(
            lambda Ai: kops.cimmino_gather(Ai, xbar))(factors.A))
        v = bw - u                                        # (m_loc, k, p)
        return jax.vmap(kops.cimmino_scatter)(factors.B, v), v

    def mesh_step_many(self, factors, Bb, states, params, ctx, *,
                       use_kernel=False):
        if not (use_kernel and factors.B is not None):
            return super().mesh_step_many(factors, Bb, states, params, ctx)
        r, _ = self._mesh_r_v_many(factors, Bb, states.xbar, ctx)
        s = ctx.psum_workers(jnp.sum(r, axis=0))          # (k, n_loc)
        return CimminoState(xbar=states.xbar + params["nu"] * s,
                            t=states.t + 1)

    def mesh_step_many_residual(self, factors, Bb, states, params, ctx):
        if factors.B is not None:
            r, v = self._mesh_r_v_many(factors, Bb, states.xbar, ctx)
            s = ctx.psum_workers(jnp.sum(r, axis=0))
            rsq = ctx.psum_workers(jnp.sum(v * v, axis=(0, 2)))   # (k,)
        else:
            def one(bk, xk):
                uk = ctx.psum_model(blockops.bmatvec(factors.A, xk))
                vk = bk - uk
                wk = _cho_solve_workers(factors.chol, vk)
                return blockops.brmatvec(factors.A, wk), vk

            r, v = jax.vmap(one)(Bb, states.xbar)         # (k, m_loc, ·)
            s = ctx.psum_workers(jnp.sum(r, axis=1))
            rsq = ctx.psum_workers(jnp.sum(v * v, axis=(1, 2)))
        return CimminoState(xbar=states.xbar + params["nu"] * s,
                            t=states.t + 1), rsq

    # ----- redundant execution (solvers/redundant.py) ---------------------
    # State is the master estimate alone (already global-shaped): the
    # masked sum of row projections replaces the plain worker-axis sum.
    supports_redundancy = True

    def red_init(self, factors, b, params, W0, ctx):
        return CimminoState(xbar=jnp.zeros(factors.A.shape[3],
                                           factors.A.dtype),
                            t=jnp.zeros((), jnp.int32))

    def red_step(self, factors, b, state, params, W, ctx):
        u = ctx.psum_model(jnp.einsum("mrpn,n->mrp", factors.A, state.xbar))
        w = _cho_solve_replicas(factors.chol, b - u)
        r = jnp.einsum("mrpn,mrp->mrn", factors.A, w)     # row projections
        s = ctx.psum_workers(jnp.einsum("mr,mrn->n", W, r))
        return CimminoState(xbar=state.xbar + params["nu"] * s,
                            t=state.t + 1)

    # ----- cross-partition warm start (solvers/elastic.py) ------------------
    # The state is the master estimate alone and carries no per-block
    # invariant, so it lifts across any repartition verbatim.
    supports_lift = True
    supports_block_store = True    # per-block Gram Cholesky, leading m axis

    def lift_state(self, factors, b, params, x):
        return CimminoState(xbar=jnp.asarray(x), t=jnp.zeros((), jnp.int32))
