"""Projection-family solvers: APC, plain projection consensus, block Cimmino.

All three share the per-worker null-space projection machinery of
``core/apc.py`` (Gram Cholesky factors, P_i v = v - A^T G^{-1} A v), support
the Pallas kernel path uniformly (``use_kernel=True``), and auto-tune their
parameters from the Theorem-1 spectral analysis of X when none are given.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import blockops
from repro.core import spectral
from repro.core import apc as apc_core
from repro.core.apc import APCState, _gram_chol, _gram_solve
from repro.core.partition import BlockSystem

from .api import Solver
from .registry import register


class ProjFactors(NamedTuple):
    """b-independent per-worker factors (leading axis = worker)."""
    A: jnp.ndarray      # (m, p, n) row blocks, or a blockops.SparseBlocks
    chol: jnp.ndarray   # (m, p, p) Cholesky of Gram A_i A_i^T
    B: Optional[jnp.ndarray] = None  # (m, n, p) pinv factors A^T G^{-1}
                                     # (kernel path only, see kernel_factors)


def _proj_prepare(A, jitter: float) -> ProjFactors:
    if blockops.is_sparse(A):
        # support-compressed Gram — exact (padded columns carry zeros)
        G = blockops.bgram(A)
        if jitter:
            p = G.shape[-1]
            tr = jnp.trace(G, axis1=-2, axis2=-1)[:, None, None]
            G = G + jitter * tr / p * jnp.eye(p, dtype=G.dtype)
        return ProjFactors(A=A, chol=jnp.linalg.cholesky(G))
    chol = jax.vmap(lambda Ai: _gram_chol(Ai, jitter))(A)
    return ProjFactors(A=A, chol=chol)


def _with_pinv(factors: ProjFactors) -> ProjFactors:
    """Precompute B_i = A_i^T G_i^{-1} once (iteration-invariant)."""
    if factors.B is not None or blockops.is_sparse(factors.A):
        # sparse operands never reach the kernel path (capability layer
        # downgrades use_kernel loudly), so no pinv augmentation either
        return factors
    B = jax.vmap(lambda Ai, Li: jax.scipy.linalg.cho_solve((Li, True), Ai).T)(
        factors.A, factors.chol)
    return factors._replace(B=B)


def _min_norm_solutions(factors: ProjFactors, b: jnp.ndarray) -> jnp.ndarray:
    """x0_i = A_i^T (A_i A_i^T)^{-1} b_i — the min-norm local solutions."""
    if blockops.is_sparse(factors.A):
        return blockops.brmatvec(factors.A,
                                 _cho_solve_workers(factors.chol, b))
    return jax.vmap(lambda Ai, Li, bi: Ai.T @ _gram_solve(Li, bi))(
        factors.A, factors.chol, b)


def _cho_solve_workers(chol, u):
    """Per-worker G_i^{-1} u_i with the stored Cholesky factors."""
    return jax.vmap(
        lambda Li, ui: jax.scipy.linalg.cho_solve((Li, True), ui))(chol, u)


def _cho_solve_replicas(chol, u):
    """Replicated form: leading (m, r) worker x slot axes."""
    return jax.vmap(_cho_solve_workers)(chol, u)


def _mesh_gram_chol(A, jitter: float, ctx):
    """Cholesky of the full Gram A_i A_i^T from column-sharded blocks."""
    G = ctx.psum_model(blockops.bgram(A))
    if jitter:
        p = G.shape[-1]
        tr = jnp.trace(G, axis1=-2, axis2=-1)[:, None, None]
        G = G + jitter * tr / p * jnp.eye(p, dtype=G.dtype)
    return jnp.linalg.cholesky(G)


@register("apc")
class APCSolver(Solver):
    """Accelerated Projection-based Consensus (paper Algorithm 1)."""

    paper_name = "APC"
    supports_kernel = True
    param_names = ("gamma", "eta")
    # the paper's convergence theory (Theorem 1) assumes an exact solution
    # exists, so APC keeps its square-only contract; sparse blocks are fine
    supports = frozenset({"square", "sparse"})

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        prm = spectral.apc_optimal(*spectral.mu_extremes(X))
        return {"gamma": prm.gamma, "eta": prm.eta}, prm.rho

    def prepare(self, A, params):
        return _proj_prepare(A, params.get("jitter", 0.0))

    def kernel_factors(self, factors):
        return _with_pinv(factors)

    def init(self, factors, b, params):
        x0 = _min_norm_solutions(factors, b)
        return APCState(x=x0, xbar=jnp.mean(x0, axis=0),
                        t=jnp.zeros((), jnp.int32))

    def step(self, factors, b, state, params, *, use_kernel=False):
        gamma, eta = params["gamma"], params["eta"]
        if blockops.is_sparse(factors.A):
            # mask-aware products on the column support (same update as the
            # unfused mesh formulation below)
            d = state.xbar[None, :] - state.x
            u = blockops.bmatvec_each(factors.A, d)
            w = _cho_solve_workers(factors.chol, u)
            proj = d - blockops.brmatvec(factors.A, w)
            x_new = state.x + gamma * proj
            xbar_new = (eta * jnp.mean(x_new, axis=0)
                        + (1.0 - eta) * state.xbar)
            return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            # the engine autotune includes "unfused" as a candidate: when
            # the fused pair loses at this (p, n, k=1, dtype) the step
            # falls through to the plain XLA path below (trace-time
            # choice — baked into the compiled executor, never retraced)
            if kops.use_fused("apc", factors.A.shape[1], factors.A.shape[2],
                              1, factors.A.dtype):
                def worker(Ai, Bi, xi):
                    return kops.block_projection(Ai, Bi, xi, state.xbar,
                                                 gamma)

                x_new = jax.vmap(worker)(factors.A, factors.B, state.x)
                xbar_new = (eta * jnp.mean(x_new, axis=0)
                            + (1.0 - eta) * state.xbar)
                return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)
            use_kernel = False                   # measured fallback
        legacy = apc_core.APCFactors(A=factors.A, chol=factors.chol,
                                     x0=None, b=None)
        return apc_core.apc_step(legacy, state, gamma, eta,
                                 use_kernel=use_kernel)

    def step_many(self, factors, Bb, states, params, *, use_kernel=False):
        """Fused multi-RHS iteration: the k batch rows stream through ONE
        VMEM residency of every A/B tile (states.x (k, m, n))."""
        if not (use_kernel and factors.B is not None):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=use_kernel)
        from repro.kernels import ops as kops
        if not kops.use_fused("apc", factors.A.shape[1], factors.A.shape[2],
                              Bb.shape[0], factors.A.dtype):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=False)   # measured fallback
        gamma, eta = params["gamma"], params["eta"]
        X = jnp.swapaxes(states.x, 0, 1)                  # (m, k, n)

        def worker(Ai, Bi, Xi):
            return kops.block_projection(Ai, Bi, Xi, states.xbar, gamma)

        x_new = jnp.swapaxes(
            jax.vmap(worker)(factors.A, factors.B, X), 0, 1)   # (k, m, n)
        xbar_new = (eta * jnp.mean(x_new, axis=1)
                    + (1.0 - eta) * states.xbar)
        return APCState(x=x_new, xbar=xbar_new, t=states.t + 1)

    def extract(self, state):
        return state.xbar

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx, use_kernel=False):
        return ProjFactors(A=P(ctx.w, None, ctx.n),
                           chol=P(ctx.w, None, None),
                           B=P(ctx.w, ctx.n, None) if use_kernel else None)

    def mesh_state_specs(self, ctx):
        return APCState(x=P(ctx.w, ctx.n), xbar=P(ctx.n), t=P())

    def mesh_factors(self, factors, use_kernel=False):
        if use_kernel:
            return _with_pinv(factors)      # idempotent host augmentation
        return factors._replace(B=None)     # pinv factors are kernel-only

    def mesh_prepare(self, A, params, ctx, use_kernel=False):
        chol = _mesh_gram_chol(A, params.get("jitter", 0.0), ctx)
        factors = ProjFactors(A=A, chol=chol)
        if use_kernel:
            # B_loc = A_locᵀ G⁻¹ is shard-local given the FULL Gram's
            # Cholesky (cho_solve acts on the p axis only), so the pinv
            # augmentation runs on-mesh without materializing A anywhere
            factors = _with_pinv(factors)
        return factors

    def mesh_init(self, factors, b, params, ctx):
        w = _cho_solve_workers(factors.chol, b)
        x0 = blockops.brmatvec(factors.A, w)          # min-norm local sols
        m = ctx.workers_total(x0.shape[0])
        xbar0 = ctx.psum_workers(jnp.sum(x0, axis=0)) / m
        return APCState(x=x0, xbar=xbar0, t=jnp.zeros((), jnp.int32))

    def mesh_step(self, factors, b, state, params, ctx, *, use_kernel=False):
        gamma, eta = params["gamma"], params["eta"]
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            u_loc = jax.vmap(
                lambda Ai, xi: kops.proj_gather(Ai, xi, state.xbar))(
                    factors.A, state.x)               # (m_loc, p)
            u = ctx.psum_model(u_loc)                 # full u = A_i d
            x_new = jax.vmap(
                lambda Bi, xi, ui: kops.proj_scatter(Bi, xi, state.xbar,
                                                     ui, gamma))(
                    factors.B, state.x, u)            # Eq. 2a, fused
        else:
            d = state.xbar[None, :] - state.x             # (m_loc, n_loc)
            u = ctx.psum_model(blockops.bmatvec_each(factors.A, d))
            w = _cho_solve_workers(factors.chol, u)       # G^{-1} A_i d
            proj = d - blockops.brmatvec(factors.A, w)
            x_new = state.x + gamma * proj                # Eq. 2a
        m = ctx.workers_total(x_new.shape[0])
        s = ctx.psum_workers(jnp.sum(x_new, axis=0))      # Eq. 2b psum
        xbar_new = (eta / m) * s + (1.0 - eta) * state.xbar
        return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)

    def mesh_step_many(self, factors, Bb, states, params, ctx, *,
                       use_kernel=False):
        if not (use_kernel and factors.B is not None):
            return super().mesh_step_many(factors, Bb, states, params, ctx)
        from repro.kernels import ops as kops
        gamma, eta = params["gamma"], params["eta"]
        X = jnp.swapaxes(states.x, 0, 1)                  # (m_loc, k, n_loc)
        u_loc = jax.vmap(
            lambda Ai, Xi: kops.proj_gather(Ai, Xi, states.xbar))(
                factors.A, X)                             # (m_loc, k, p)
        u = ctx.psum_model(u_loc)
        x_new = jnp.swapaxes(jax.vmap(
            lambda Bi, Xi, ui: kops.proj_scatter(Bi, Xi, states.xbar,
                                                 ui, gamma))(
                factors.B, X, u), 0, 1)                   # (k, m_loc, n_loc)
        m = ctx.workers_total(x_new.shape[1])
        s = ctx.psum_workers(jnp.sum(x_new, axis=1))      # (k, n_loc)
        xbar_new = (eta / m) * s + (1.0 - eta) * states.xbar
        return APCState(x=x_new, xbar=xbar_new, t=states.t + 1)

    # ----- redundant execution (solvers/redundant.py) ---------------------
    # Internal state keeps the APCState structure with x grown to the
    # replicated (m, r, n) layout; xbar stays global.  Eq. 2b becomes the
    # W-masked block-unique mean — the same worker-axis psum as above.
    supports_redundancy = True

    def red_init(self, factors, b, params, W0, ctx):
        w = _cho_solve_replicas(factors.chol, b)
        x0 = jnp.einsum("mrpn,mrp->mrn", factors.A, w)    # min-norm per slot
        m = ctx.workers_total(x0.shape[0])
        xbar0 = ctx.psum_workers(jnp.einsum("mr,mrn->n", W0, x0)) / m
        return APCState(x=x0, xbar=xbar0, t=jnp.zeros((), jnp.int32))

    def red_step(self, factors, b, state, params, W, ctx):
        gamma, eta = params["gamma"], params["eta"]
        d = state.xbar[None, None, :] - state.x           # (m, r, n)
        u = ctx.psum_model(jnp.einsum("mrpn,mrn->mrp", factors.A, d))
        w = _cho_solve_replicas(factors.chol, u)
        proj = d - jnp.einsum("mrpn,mrp->mrn", factors.A, w)
        x_new = state.x + gamma * proj                    # every replica
        m = ctx.workers_total(x_new.shape[0])
        s = ctx.psum_workers(jnp.einsum("mr,mrn->n", W, x_new))
        xbar_new = (eta / m) * s + (1.0 - eta) * state.xbar
        return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)

    def red_expand(self, state, assign):
        x = jnp.asarray(state.x)
        return APCState(x=x[assign.holder], xbar=jnp.asarray(state.xbar),
                        t=state.t)

    def red_collapse(self, state, assign):
        # slot 0 of worker j holds block j, and replicas are identical
        return APCState(x=state.x[:, 0], xbar=state.xbar, t=state.t)

    def red_state_specs(self, ctx):
        return APCState(x=P(ctx.w, None, ctx.n), xbar=P(ctx.n), t=P())


@register("consensus")
class ConsensusSolver(APCSolver):
    """Plain projection consensus [11,14] == APC with gamma = eta = 1."""

    paper_name = "Consensus"

    def default_params(self, sys: BlockSystem):
        return {"gamma": 1.0, "eta": 1.0}

    def theoretical_rate(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        mu_min, _ = spectral.mu_extremes(X)
        return spectral.consensus_rate(mu_min)

    def analyze(self, sys: BlockSystem):
        return self.default_params(sys), self.theoretical_rate(sys)


class CimminoState(NamedTuple):
    xbar: jnp.ndarray   # (n,) master estimate
    t: jnp.ndarray      # ()   iteration counter


@register("cimmino")
class CimminoSolver(Solver):
    """Block Cimmino row projections (Sec 4.5; Proposition 2: APC gamma=1)."""

    paper_name = "B-Cimmino"
    supports_kernel = True
    param_names = ("nu",)
    # state is the master estimate alone and b enters every step, so a
    # prior state warm-starts perturbed right-hand sides too
    warm_rhs_ok = True
    # the fixed point Σ A_iᵀG_i⁻¹(b_i − A_i x̄) = 0 is the G⁻¹-weighted
    # least-squares optimum, well-defined for inconsistent systems too
    # (each block must stay row-independent: p ≤ n per block)
    supports = frozenset({"square", "least_squares", "sparse"})

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        nu_m, rho = spectral.cimmino_optimal(*spectral.mu_extremes(X))
        return {"nu": nu_m / sys.m}, rho

    def prepare(self, A, params):
        return _proj_prepare(A, params.get("jitter", 0.0))

    def kernel_factors(self, factors):
        return _with_pinv(factors)

    def init(self, factors, b, params):
        n = blockops.ncols(factors.A)
        return CimminoState(xbar=jnp.zeros(n, blockops.block_dtype(factors.A)),
                            t=jnp.zeros((), jnp.int32))

    def step(self, factors, b, state, params, *, use_kernel=False):
        nu = params["nu"]
        if blockops.is_sparse(factors.A):
            u = blockops.bmatvec(factors.A, state.xbar)
            w = _cho_solve_workers(factors.chol, b - u)
            r = blockops.brmatvec(factors.A, w)       # row projections
            return CimminoState(xbar=state.xbar + nu * jnp.sum(r, axis=0),
                                t=state.t + 1)
        kern = use_kernel and factors.B is not None
        if kern:
            # single-RHS cimmino is the measured corner where the fused
            # pair LOSES (no batch to amortize the A/B tile reads) — the
            # engine autotune includes "unfused" as a candidate and this
            # dispatch honors it at trace time
            from repro.kernels import ops as kops
            kern = kops.use_fused("cimmino", factors.A.shape[1],
                                  factors.A.shape[2], 1, factors.A.dtype)
        if kern:
            from repro.kernels import ops as kops

            # the dedicated Cimmino kernel pair: r_i = B_i (b_i − A_i x̄)
            # (B = A^T G^{-1} bakes the Gram inverse in, so no per-step
            # cho_solve and no rewrite onto the APC update shape)
            def worker(Ai, Bi, bi):
                return kops.cimmino_update(Ai, Bi, bi, state.xbar)

            r = jax.vmap(worker)(factors.A, factors.B, b)
        else:
            def worker(Ai, Li, bi):
                u = jax.scipy.linalg.cho_solve((Li, True), bi - Ai @ state.xbar)
                return Ai.T @ u

            r = jax.vmap(worker)(factors.A, factors.chol, b)
        return CimminoState(xbar=state.xbar + nu * jnp.sum(r, axis=0),
                            t=state.t + 1)

    def step_many(self, factors, Bb, states, params, *, use_kernel=False):
        """Fused multi-RHS row projections (Bb (k, m, p), x̄ (k, n))."""
        if not (use_kernel and factors.B is not None):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=use_kernel)
        from repro.kernels import ops as kops
        if not kops.use_fused("cimmino", factors.A.shape[1],
                              factors.A.shape[2], Bb.shape[0],
                              factors.A.dtype):
            return super().step_many(factors, Bb, states, params,
                                     use_kernel=False)   # measured fallback
        bw = jnp.swapaxes(Bb, 0, 1)                       # (m, k, p)

        def worker(Ai, Bi, bi):
            return kops.cimmino_update(Ai, Bi, bi, states.xbar)   # (k, n)

        r = jax.vmap(worker)(factors.A, factors.B, bw)    # (m, k, n)
        return CimminoState(xbar=states.xbar + params["nu"] * jnp.sum(r, 0),
                            t=states.t + 1)

    def extract(self, state):
        return state.xbar

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx, use_kernel=False):
        return ProjFactors(A=P(ctx.w, None, ctx.n),
                           chol=P(ctx.w, None, None),
                           B=P(ctx.w, ctx.n, None) if use_kernel else None)

    def mesh_state_specs(self, ctx):
        return CimminoState(xbar=P(ctx.n), t=P())

    def mesh_factors(self, factors, use_kernel=False):
        if use_kernel:
            return _with_pinv(factors)
        return factors._replace(B=None)

    def mesh_prepare(self, A, params, ctx, use_kernel=False):
        factors = ProjFactors(
            A=A, chol=_mesh_gram_chol(A, params.get("jitter", 0.0), ctx))
        if use_kernel:
            factors = _with_pinv(factors)     # shard-local, see APCSolver
        return factors

    def mesh_step(self, factors, b, state, params, ctx, *, use_kernel=False):
        if use_kernel and factors.B is not None:
            from repro.kernels import ops as kops
            u = ctx.psum_model(jax.vmap(
                lambda Ai: kops.cimmino_gather(Ai, state.xbar))(factors.A))
            r = jax.vmap(kops.cimmino_scatter)(factors.B, b - u)
        else:
            u = ctx.psum_model(blockops.bmatvec(factors.A, state.xbar))
            w = _cho_solve_workers(factors.chol, b - u)   # G^{-1}(b - A xbar)
            r = blockops.brmatvec(factors.A, w)           # row projections
        s = ctx.psum_workers(jnp.sum(r, axis=0))
        return CimminoState(xbar=state.xbar + params["nu"] * s,
                            t=state.t + 1)

    # ----- least-squares mode ---------------------------------------------
    # The Cimmino fixed point minimizes Σᵢ ‖L_i^{-1}(A_i x − b_i)‖² — the
    # Gram-whitened least-squares problem.  ``ls_moment`` is exactly the
    # update direction (zero at the optimum); ``ls_reference`` solves the
    # whitened system directly for error tracking.
    def ls_moment(self, factors, A, b, x, params, ctx):
        u = ctx.psum_model(blockops.bmatvec(A, x))
        w = _cho_solve_workers(factors.chol, b - u)
        r = blockops.brmatvec(A, w)
        return ctx.psum_workers(jnp.sum(r, axis=0))

    def ls_reference(self, sys: BlockSystem) -> jnp.ndarray:
        A = np.asarray(sys.A_blocks, dtype=np.float64)
        b = np.asarray(sys.b_blocks, dtype=np.float64)
        rows = []
        rhs = []
        for Ai, bi in zip(A, b):
            L = np.linalg.cholesky(Ai @ Ai.T)
            rows.append(np.linalg.solve(L, Ai))       # L_i^{-1} A_i
            rhs.append(np.linalg.solve(L, bi))        # L_i^{-1} b_i
        x, *_ = np.linalg.lstsq(np.concatenate(rows), np.concatenate(rhs),
                                rcond=None)
        return jnp.asarray(x, dtype=sys.b_blocks.dtype)

    def mesh_step_many(self, factors, Bb, states, params, ctx, *,
                       use_kernel=False):
        if not (use_kernel and factors.B is not None):
            return super().mesh_step_many(factors, Bb, states, params, ctx)
        from repro.kernels import ops as kops
        # Bb (k, m_loc, p); x̄ (k, n_loc); gather is RHS-batched per worker
        u = ctx.psum_model(jax.vmap(
            lambda Ai: kops.cimmino_gather(Ai, states.xbar))(factors.A))
        v = jnp.swapaxes(Bb, 0, 1) - u                    # (m_loc, k, p)
        r = jax.vmap(kops.cimmino_scatter)(factors.B, v)  # (m_loc, k, n_loc)
        s = ctx.psum_workers(jnp.sum(r, axis=0))          # (k, n_loc)
        return CimminoState(xbar=states.xbar + params["nu"] * s,
                            t=states.t + 1)

    # ----- redundant execution (solvers/redundant.py) ---------------------
    # State is the master estimate alone (already global-shaped): the
    # masked sum of row projections replaces the plain worker-axis sum.
    supports_redundancy = True

    def red_init(self, factors, b, params, W0, ctx):
        return CimminoState(xbar=jnp.zeros(factors.A.shape[3],
                                           factors.A.dtype),
                            t=jnp.zeros((), jnp.int32))

    def red_step(self, factors, b, state, params, W, ctx):
        u = ctx.psum_model(jnp.einsum("mrpn,n->mrp", factors.A, state.xbar))
        w = _cho_solve_replicas(factors.chol, b - u)
        r = jnp.einsum("mrpn,mrp->mrn", factors.A, w)     # row projections
        s = ctx.psum_workers(jnp.einsum("mr,mrn->n", W, r))
        return CimminoState(xbar=state.xbar + params["nu"] * s,
                            t=state.t + 1)
