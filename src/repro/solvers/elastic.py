"""Elastic runtime: membership-aware driving of the unified solve lifecycle.

``ElasticRuntime`` wraps one solver + one global system and keeps a solve
making progress while the worker fleet CHANGES underneath it.  It owns the
loop the paper's synchronous taskmaster only sketches: solve in short
warm-started segments, poll the ``HeartbeatMonitor``'s membership-event
stream between segments, and react:

  * **permanent death** (``mark_dead`` / ``sweep`` timeout) — the row
    partition is KEPT and the redundant selection-weight schedule is
    re-lowered over the survivors (``RedundantEngine.lower``); replicas of
    the dead worker's blocks answer for it, so the iterate continues from
    the live global-shape state, bit-exactly (see solvers/redundant.py).
    If the survivors cannot cover every block (>= r cyclically-adjacent
    holders lost) the runtime fails LOUDLY with a ``RuntimeError`` — a
    silent wrong answer is never on the menu.

  * **join / rejoin that grows the fleet** — the global system is
    repartitioned over the alive workers (``pad_to_blocks`` +
    ``partition``), the new assignment is warm-started by LIFTING the
    current global iterate into the new block layout
    (``Solver.lift_state``), and per-block factorizations are reused
    through the ``FactorStore`` block tier wherever a block's (content,
    slice, dtype, solver, params) fingerprint is unchanged —
    ``reused_blocks`` / ``prepared_blocks`` report reuse vs
    refactorization.  A returnee to the CURRENT fleet size is just a
    reassignment: replicas resynced by the rejoin handshake, state and
    compiled scan untouched.

  * **taskmaster loss** — ``checkpoint()`` persists the in-flight global
    iterate after every segment (atomic, versioned: checkpoint/ckpt.py);
    ``ElasticRuntime.recover`` rebuilds a fresh runtime on a new process
    from the store's DISK tier (factors come back as block-tier hits,
    counted as reuse) plus the checkpointed iterate.

Retrace discipline: one ``RedundantEngine`` is cached per fleet size, and
every segment re-enters its compiled scan with a freshly lowered schedule
of identical shape — membership changes cost a host-side lowering (death)
or one engine build (first visit to a fleet size), never a steady-state
retrace.  ``engine_cache_sizes()`` exposes the jit caches so benchmarks
(benchmarks/chaos.py) can gate on exactly that.

    from repro import solvers
    from repro.runtime.fault import HeartbeatMonitor
    rt = solvers.ElasticRuntime(
        solvers.get("apc"), sys,
        plan=solvers.ExecutionPlan(redundancy=2),
        monitor=HeartbeatMonitor(n_workers=sys.m))
    rt.monitor.mark_dead(2)          # death -> re-lower, keep iterating
    rep = rt.run(iters=600)          # rep.reused_blocks / rep.events
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import partition as partition_lib
from repro.core.partition import BlockSystem
from repro.runtime.fault import HeartbeatMonitor, MembershipEvent, covering_ok

from .api import SolveResult, iters_to_tolerance
from .capability import CapabilityError, ExecutionPlan, resolve_plan
from .redundant import RedundantEngine
from .store import FactorStore


@dataclasses.dataclass(frozen=True)
class ElasticReport:
    """What one ``ElasticRuntime.run`` segment-loop did and produced.

    ``result`` is the ordinary ``SolveResult`` (final x, plain
    global-shape state, residual/error history of THIS call); the elastic
    bookkeeping rides alongside: the membership events absorbed, factor
    reuse vs refactorization counts, and how often the runtime re-lowered
    the schedule (deaths) or repartitioned (fleet growth).  ``iters`` is
    CUMULATIVE across run calls and recoveries — the chaos benchmark
    compares it against the oracle's uninterrupted count.
    """
    result: SolveResult
    events: Tuple[MembershipEvent, ...]
    iters: int
    segments: int
    reused_blocks: int
    prepared_blocks: int
    repartitions: int
    relowerings: int
    fleet: Tuple[int, ...]          # holder worker-ids after the run

    # convenience mirrors so ``rep.x`` / ``rep.residuals`` read naturally
    @property
    def x(self):
        return self.result.x

    @property
    def residuals(self):
        return self.result.residuals

    @property
    def errors(self):
        return self.result.errors

    @property
    def state(self):
        return self.result.state

    @property
    def iters_to_tol(self):
        return self.result.iters_to_tol


@dataclasses.dataclass
class _Partition:
    """One fleet size's compiled world: system, factors, params, engine."""
    sys: BlockSystem
    prm: Dict[str, Any]
    factors: Any
    engine: RedundantEngine


class ElasticRuntime:
    """Drive a solve across fleet membership changes (see module docstring).

    Parameters
    ----------
    solver:   a registry solver with redundant hooks (projection family).
    sys:      the ``BlockSystem`` — its initial ``m`` must equal the
              monitor's ``n_workers``.
    plan:     an ``ExecutionPlan``; ``redundancy`` sets the death budget,
              ``store`` supplies (or a fresh in-memory ``FactorStore``
              replaces) the per-block factor cache, ``backend``/``mesh``
              pick local vs shard_map execution, ``warm_state`` seeds the
              first segment.  ``kernel=True`` and ``alive_schedule=`` are
              rejected: the replicated layout has no fused kernel, and
              elastic masks come from the monitor, not a fixed schedule.
    monitor:  the ``HeartbeatMonitor`` whose event stream is polled
              between segments.  The runtime drives beats itself (it IS
              the driver loop), so membership truth is the explicit
              death/rejoin/join transitions.
    segment:  iterations per compiled segment — the reaction latency to a
              membership event, and the shape the engine caches compile
              against.
    checkpoint_dir: when set, ``checkpoint()`` runs after every segment so
              ``recover`` can rebuild after taskmaster loss.
    """

    def __init__(self, solver, sys: BlockSystem, *,
                 plan: Optional[ExecutionPlan] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 segment: int = 25, tol: float = 1e-6,
                 checkpoint_dir: Optional[str] = None, **params):
        if plan is None:
            plan = ExecutionPlan()
        if not isinstance(plan, ExecutionPlan):
            raise TypeError(f"plan must be an ExecutionPlan, got "
                            f"{type(plan).__name__}")
        if plan.alive_schedule is not None:
            raise ValueError(
                "ExecutionPlan.alive_schedule is for fixed-schedule "
                "solve(); the elastic runtime derives alive masks from "
                "its HeartbeatMonitor")
        plan = resolve_plan(solver, sys, plan, context="elastic")
        if plan.kernel:
            raise CapabilityError(
                f"solver {solver.name!r} cannot run the elastic runtime "
                f"with kernel=True: the replicated (m, r, p, n) layout "
                f"has no Pallas kernel (same limit as redundancy= + "
                f"use_kernel=True); drop kernel=True")
        self.solver, self.plan = solver, plan
        self.tol = float(tol)
        self.segment = int(segment)
        if self.segment < 1:
            raise ValueError(f"segment must be >= 1, got {segment}")
        self.checkpoint_dir = checkpoint_dir
        self.params = dict(params)
        self.monitor = (HeartbeatMonitor(n_workers=sys.m)
                        if monitor is None else monitor)
        if self.monitor.n_workers != sys.m:
            raise ValueError(
                f"HeartbeatMonitor tracks {self.monitor.n_workers} workers "
                f"but the system has m={sys.m} blocks — build the monitor "
                f"for the initial fleet")
        self.store = plan.store if plan.store is not None else FactorStore()
        self.base_sys = sys
        self._A_global, self._b_global = sys.dense()
        self._x_true = sys.x_true
        self._dtype = jnp.asarray(sys.A_blocks).dtype

        self._parts: Dict[int, _Partition] = {}
        self.reused_blocks = 0
        self.prepared_blocks = 0
        self.repartitions = 0
        self.relowerings = 0
        self.segments = 0
        self.events: List[MembershipEvent] = []
        self._iters_done = 0
        self._state = None              # replicated state of current engine
        self._warm_x = None             # recovered global iterate (if any)
        self._holders = np.arange(sys.m)
        self._current = self._partition_for(sys.m)
        self._beat_alive()

    # ------------------------------------------------------------------
    # partitions & engines
    # ------------------------------------------------------------------
    @property
    def sys(self) -> BlockSystem:
        """The CURRENT partition's system (m tracks the fleet size)."""
        return self._current.sys

    @property
    def engine(self) -> RedundantEngine:
        return self._current.engine

    def engine_cache_sizes(self) -> Dict[int, int]:
        """jit-cache entries per fleet size — flat across steady-state
        segments; the chaos benchmark gates on the post-change delta."""
        return {m: part.engine.cache_size()
                for m, part in sorted(self._parts.items())}

    def _partition_for(self, m_new: int) -> _Partition:
        """The compiled world for fleet size ``m_new`` (built once)."""
        part = self._parts.get(m_new)
        if part is not None:
            return part
        if m_new == self.base_sys.m:
            sys2 = self.base_sys
        else:
            A2, b2 = partition_lib.pad_to_blocks(
                self._A_global, self._b_global, m_new)
            sys2 = partition_lib.partition(
                A2, b2, m_new, x_true=self._x_true, mode=self.base_sys.mode)
        prm2 = self.solver.resolve_params(sys2, **self.params)
        if (getattr(self.solver, "supports_block_store", False)
                and not sys2.is_sparse):
            factors2, reuse = self.store.blockwise_factors(
                self.solver, sys2, precision=self.plan.precision,
                **self.params)
            self.reused_blocks += reuse.reused
            self.prepared_blocks += reuse.prepared
        else:
            factors2 = self.solver.prepare(sys2.A_blocks, prm2)
            self.prepared_blocks += sys2.m
        engine = RedundantEngine(
            self.solver, sys2, r=min(self.plan.redundancy, m_new),
            backend=self.plan.backend, mesh=self.plan.mesh,
            worker_axes=self.plan.worker_axes,
            model_axis=self.plan.model_axis, factors=factors2,
            **self.params)
        part = _Partition(sys=sys2, prm=prm2, factors=factors2,
                          engine=engine)
        self._parts[m_new] = part
        return part

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def _alive_holder_mask(self) -> np.ndarray:
        """(m,) bool: is the holder of block-slot i alive right now?"""
        dead = self.monitor.dead
        return np.array([w not in dead for w in self._holders], dtype=bool)

    def _beat_alive(self):
        dead = self.monitor.dead
        for w in range(self.monitor.n_workers):
            if w not in dead:
                self.monitor.beat(w)

    def _require_covered(self, alive: np.ndarray):
        r = self.engine.r
        if not covering_ok(alive, r):
            lost = [int(w) for w, a in zip(self._holders, alive) if not a]
            raise RuntimeError(
                f"elastic fleet uncoverable: dead workers {lost} include "
                f">= r={r} cyclically-adjacent holders over m={self.sys.m} "
                f"blocks — no survivor holds a replica of every block.  "
                f"Add workers (monitor.join / rejoin) or recover from the "
                f"last checkpoint onto a fresh fleet")

    def _absorb_events(self):
        """Drain the monitor stream and react (see module docstring)."""
        events = self.monitor.poll_events()
        if not events:
            return
        self.events.extend(events)
        deaths = [e for e in events if e.kind == "died"]
        growth = [e for e in events if e.kind in ("joined", "rejoined")]
        if growth:
            self._repartition()
        if deaths:
            # the partition is kept; the NEXT segment lowers the schedule
            # over the survivors — fail loudly now if they can't cover
            self._require_covered(self._alive_holder_mask())
            self.relowerings += 1

    def _repartition(self):
        dead = self.monitor.dead
        holders = np.array([w for w in range(self.monitor.n_workers)
                            if w not in dead], dtype=int)
        if holders.size == 0:
            raise RuntimeError("elastic fleet has no alive workers left")
        m_new = int(holders.size)
        if m_new == self.sys.m:
            # same fleet size: a returnee slots into the existing layout
            # (replicas resynced by the join/rejoin handshake); the state
            # and the compiled scan are untouched.
            self._holders = holders
            return
        x = self._global_x()
        part = self._partition_for(m_new)
        self._current = part
        self._holders = holders
        lifted = self.solver.lift_state(part.factors, part.sys.b_blocks,
                                        part.prm, x)
        self._state = part.engine.init_state(lifted)
        self.repartitions += 1

    # ------------------------------------------------------------------
    # state plumbing
    # ------------------------------------------------------------------
    def _global_x(self) -> jnp.ndarray:
        """The current global iterate (n,) — partition-independent."""
        if self._state is not None:
            return self.solver.extract(self.engine.collapse(self._state))
        if self._warm_x is not None:
            return jnp.asarray(self._warm_x)
        if self.plan.warm_state is not None:
            return jnp.asarray(self.solver.extract(self.plan.warm_state))
        return jnp.zeros((self.sys.n,), self._dtype)

    def _initial_state(self):
        part = self._current
        if self._warm_x is not None:        # taskmaster recovery
            lifted = self.solver.lift_state(
                part.factors, part.sys.b_blocks, part.prm,
                jnp.asarray(self._warm_x))
            self._warm_x = None
            return part.engine.init_state(lifted)
        return part.engine.init_state(self.plan.warm_state)

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def run(self, iters: int = 1000, *, tol: Optional[float] = None
            ) -> ElasticReport:
        """Run ``iters`` more iterations, absorbing membership events at
        segment boundaries.  Returns an ``ElasticReport``; call again to
        keep going — state, counters and engine caches persist."""
        tol = self.tol if tol is None else float(tol)
        remaining = int(iters)
        events_before = len(self.events)
        segments_before = self.segments
        self._absorb_events()
        if self._state is None:
            self._state = self._initial_state()
        res_parts, err_parts = [], []
        while remaining > 0:
            self._absorb_events()
            T = min(self.segment, remaining)
            alive = self._alive_holder_mask()
            self._require_covered(alive)
            W_seq = self.engine.lower(
                np.broadcast_to(alive, (T, self.sys.m)))
            self._state, res, err = self.engine.run(self._state, W_seq)
            res_parts.append(np.asarray(res))
            err_parts.append(np.asarray(err))
            remaining -= T
            self._iters_done += T
            self.segments += 1
            self._beat_alive()
            if self.checkpoint_dir is not None:
                self.checkpoint()
        residuals = (np.concatenate(res_parts) if res_parts
                     else np.zeros((0,)))
        errors = (np.concatenate(err_parts) if err_parts
                  else np.zeros((0,)))
        state = self.engine.collapse(self._state)
        result = SolveResult(
            name=self.solver.name, x=self.solver.extract(state),
            state=state, residuals=residuals,
            errors=errors if self._x_true is not None else None,
            params=self._current.prm,
            iters_to_tol=iters_to_tolerance(residuals, tol), tol=tol)
        return ElasticReport(
            result=result, events=tuple(self.events[events_before:]),
            iters=self._iters_done,
            segments=self.segments - segments_before,
            reused_blocks=self.reused_blocks,
            prepared_blocks=self.prepared_blocks,
            repartitions=self.repartitions,
            relowerings=self.relowerings,
            fleet=tuple(int(w) for w in self._holders))

    # ------------------------------------------------------------------
    # taskmaster loss
    # ------------------------------------------------------------------
    def checkpoint(self, directory: Optional[str] = None) -> str:
        """Atomically persist the in-flight global iterate (+ iteration
        count).  Together with the store's disk tier this is the full
        serving state a replacement taskmaster needs."""
        d = directory or self.checkpoint_dir
        if d is None:
            raise ValueError(
                "no checkpoint directory: pass checkpoint_dir= at "
                "construction or directory= here")
        tree = {"iters": jnp.asarray(self._iters_done, jnp.int32),
                "x": jnp.asarray(self._global_x(), self._dtype)}
        return ckpt.save(d, self._iters_done, tree)

    @classmethod
    def recover(cls, solver, sys: BlockSystem, directory: str, *,
                plan: Optional[ExecutionPlan] = None,
                monitor: Optional[HeartbeatMonitor] = None,
                segment: int = 25, tol: float = 1e-6,
                **params) -> "ElasticRuntime":
        """Rebuild a runtime after taskmaster loss.

        A FRESH process constructs the runtime (factors flow back through
        the store's disk tier — point ``plan.store`` at the same
        ``FactorStore`` directory and the rebuild counts as
        ``reused_blocks``), then restores the checkpointed iterate, which
        the first segment lifts into the current fleet's partition.
        """
        rt = cls(solver, sys, plan=plan, monitor=monitor, segment=segment,
                 tol=tol, checkpoint_dir=directory, **params)
        like = {"iters": jnp.zeros((), jnp.int32),
                "x": jnp.zeros((sys.n,), rt._dtype)}
        tree = ckpt.restore(directory, like)
        rt._warm_x = tree["x"]
        rt._iters_done = int(tree["iters"])
        return rt
