"""Modified consensus-ADMM (paper Sec 4.4).

Native consensus-ADMM with the y_i-update disabled (y_i == 0), which the
paper reports as a significant speedup for consistent systems.  Each worker
solves its p x p (not n x n!) system via the matrix inversion lemma:

    (A^T A + xi I)^{-1} v = (v - A^T (G + xi I)^{-1} A v) / xi.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import blockops
from repro.core.partition import BlockSystem

from .api import Solver
from .projection import _cho_solve_workers
from .registry import register


class ADMMFactors(NamedTuple):
    A: jnp.ndarray      # (m, p, n) row blocks, or a blockops.SparseBlocks
    chol: jnp.ndarray   # (m, p, p) Cholesky of G + xi I


class ADMMState(NamedTuple):
    xbar: jnp.ndarray   # (n,)   consensus estimate
    t: jnp.ndarray      # ()     iteration counter
    Atb: jnp.ndarray    # (m, n) cached A_i^T b_i (iteration-invariant)


@register("madmm")
class MADMMSolver(Solver):
    paper_name = "M-ADMM"
    param_names = ("xi",)
    # the y_i == 0 simplification is only exact for consistent systems
    # (paper Sec 4.4), so no least-squares mode; sparse blocks are fine
    supports = frozenset({"square", "sparse"})

    def default_params(self, sys: BlockSystem):
        return {"xi": 1.0}

    def prepare(self, A, params):
        xi = params["xi"]
        G = blockops.bgram(A)
        eye = jnp.eye(G.shape[1], dtype=G.dtype)
        return ADMMFactors(A=A, chol=jnp.linalg.cholesky(G + xi * eye))

    def init(self, factors, b, params):
        A = factors.A
        return ADMMState(xbar=jnp.zeros(blockops.ncols(A),
                                        blockops.block_dtype(A)),
                         t=jnp.zeros((), jnp.int32),
                         Atb=blockops.brmatvec(A, b))

    def step(self, factors, b, state, params, *, use_kernel=False):
        xi = params["xi"]
        if blockops.is_sparse(factors.A):
            v = state.Atb + xi * state.xbar[None, :]
            Av = blockops.bmatvec_each(factors.A, v)
            w = _cho_solve_workers(factors.chol, Av)
            x_new = (v - blockops.brmatvec(factors.A, w)) / xi
            return ADMMState(xbar=jnp.mean(x_new, axis=0), t=state.t + 1,
                             Atb=state.Atb)

        def worker(Ai, Li, Atbi):
            v = Atbi + xi * state.xbar
            w = jax.scipy.linalg.cho_solve((Li, True), Ai @ v)
            return (v - Ai.T @ w) / xi          # (A^T A + xi I)^{-1} v

        x_new = jax.vmap(worker)(factors.A, factors.chol, state.Atb)
        return ADMMState(xbar=jnp.mean(x_new, axis=0), t=state.t + 1,
                         Atb=state.Atb)

    def extract(self, state):
        return state.xbar

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx):
        return ADMMFactors(A=P(ctx.w, None, ctx.n), chol=P(ctx.w, None, None))

    def mesh_state_specs(self, ctx):
        return ADMMState(xbar=P(ctx.n), t=P(), Atb=P(ctx.w, ctx.n))

    def mesh_prepare(self, A, params, ctx):
        G = ctx.psum_model(blockops.bgram(A))
        eye = jnp.eye(G.shape[1], dtype=G.dtype)
        return ADMMFactors(A=A,
                           chol=jnp.linalg.cholesky(G + params["xi"] * eye))

    def mesh_step(self, factors, b, state, params, ctx):
        xi = params["xi"]
        v = state.Atb + xi * state.xbar[None, :]          # (m_loc, n_loc)
        Av = ctx.psum_model(blockops.bmatvec_each(factors.A, v))
        w = _cho_solve_workers(factors.chol, Av)
        x_new = (v - blockops.brmatvec(factors.A, w)) / xi
        m = ctx.workers_total(x_new.shape[0])
        xbar = ctx.psum_workers(jnp.sum(x_new, axis=0)) / m
        return ADMMState(xbar=xbar, t=state.t + 1, Atb=state.Atb)
