"""The canonical solver API: one lifecycle, one result type.

Every distributed solver in this repo — APC and all of the paper's
comparison methods — implements the same three-phase lifecycle:

    factors = solver.prepare(A_blocks, params)   # one-time, b-INDEPENDENT
    state   = solver.init(factors, b_blocks, params)
    state   = solver.step(factors, b_blocks, state, params)

on top of which this module provides the shared drivers:

    solver.solve(sys, iters=..., **params)       -> SolveResult
    solver.solve_many(sys, B, iters=...)         -> SolveResult (batched)

``prepare`` must not look at the right-hand side: everything expensive
(Gram Cholesky factors, preconditioners) depends only on A, which is what
lets ``solve_many`` amortize one factorization across a batch of RHS — the
serving hot path — and lets a cached ``factors`` be reused across requests.

Warm starts: any prior ``SolveResult.state`` (or a state restored via
``repro.checkpoint.ckpt``) can be passed back as ``solve(...,
warm_state=state)`` to resume iterating instead of starting from scratch.

Projection-family solvers (``apc``, ``consensus``, ``cimmino``) additionally
accept ``use_kernel=True`` to route the per-worker projection through the
Pallas TPU kernels — on BOTH backends (each mesh shard runs the kernel on
its local block; the psum contract is unchanged), and with ``solve_many``
batches fused through the multi-RHS kernels (one A/B read serves the whole
batch) — and auto-tune their parameters from the Theorem-1 spectral
analysis when none are given.

Backends: ``solve(..., backend="mesh", mesh=...)`` runs the same lifecycle
sharded across a device mesh via shard_map (see ``solvers/mesh.py``) — the
row blocks shard over the mesh's worker axes, the master update becomes a
psum, and setup runs on-mesh so no host materializes the full A.  States
keep global shapes, so warm starts and checkpoints round-trip between the
two backends.
"""
from __future__ import annotations

import dataclasses
import logging
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blockops
from repro.core.partition import BlockSystem
from repro.solvers.capability import (CapabilityError, ExecutionPlan,
                                      resolve_plan)

log = logging.getLogger("repro.solvers")

__all__ = ["Solver", "SolveResult", "CapabilityError", "ExecutionPlan",
           "iters_to_tolerance"]


_UNSET = object()     # sentinel distinguishing "not passed" from None

# legacy kwarg -> ExecutionPlan field (the use_kernel rename is the only
# non-identity entry); everything here goes through the deprecation shim
_LEGACY_PLAN_KWARGS = {
    "use_kernel": "kernel", "precision": "precision",
    "warm_state": "warm_state", "factors": "factors", "store": "store",
    "backend": "backend", "mesh": "mesh", "worker_axes": "worker_axes",
    "model_axis": "model_axis", "redundancy": "redundancy",
    "alive_schedule": "alive_schedule",
}


def _coerce_plan(plan: Optional[ExecutionPlan], legacy: Dict[str, Any],
                 *, context: str) -> ExecutionPlan:
    """Resolve the plan/legacy-kwarg split of a solve call.

    Exactly one of the two surfaces may be used: an explicit ``plan=``
    wins, loose legacy kwargs build one through this shim and emit
    exactly ONE ``DeprecationWarning`` per call (however many kwargs
    were passed), and mixing the two is an error — silently merging
    would make the plan lie about what runs.
    """
    given = {k: v for k, v in legacy.items() if v is not _UNSET}
    if plan is not None:
        if given:
            raise ValueError(
                f"{context} was called with both plan= and the legacy "
                f"kwargs {sorted(given)}; put everything on the "
                f"ExecutionPlan")
        if not isinstance(plan, ExecutionPlan):
            raise TypeError(f"plan= must be an ExecutionPlan, got "
                            f"{type(plan).__name__}")
        return plan
    if not given:
        return ExecutionPlan()
    warnings.warn(
        f"passing {sorted(given)} to {context} as loose kwargs is "
        f"deprecated; build an ExecutionPlan and pass plan= instead "
        f"(e.g. plan=ExecutionPlan("
        + ", ".join(f"{_LEGACY_PLAN_KWARGS[k]}=..." for k in sorted(given))
        + "))", DeprecationWarning, stacklevel=3)
    return ExecutionPlan(**{_LEGACY_PLAN_KWARGS[k]: v
                            for k, v in given.items()})


class _LocalPsum:
    """Degenerate psum context for the local backend: a single shard, so
    both reductions are identities.  Lets the LS-mode hooks be written
    once against the MeshContext psum contract and run on both backends."""

    @staticmethod
    def psum_workers(x):
        return x

    @staticmethod
    def psum_model(x):
        return x


LOCAL_PSUM = _LocalPsum()


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Unified result record returned by every registered solver.

    For ``solve_many`` the leading axis of ``x`` / ``residuals`` /
    ``iters_to_tol`` is the RHS batch and ``errors`` is None.
    """
    name: str                      # registry key of the solver that ran
    x: jnp.ndarray                 # final global estimate (n,) or (k, n)
    state: Any                     # full solver state (checkpoint / warm-start)
    residuals: jnp.ndarray         # (T,) or (k, T)  ||Ax-b|| / ||b|| per iter
    errors: Optional[jnp.ndarray]  # (T,) ||x-x*||/||x*|| if sys.x_true given
    params: Dict[str, float]       # hyper-parameters actually used
    iters_to_tol: Any = -1         # first 1-based iter with residual < tol;
                                   # the sentinel -1 means "never reached"
                                   # (int for solve, (k,) int array for
                                   # solve_many — SAME sentinel in both)
    tol: float = 1e-6              # tolerance iters_to_tol was computed at

    def iters_to(self, tol: float):
        """Iterations needed to push the residual below ``tol``."""
        return iters_to_tolerance(self.residuals, tol)


def iters_to_tolerance(residuals, tol: float):
    """First 1-based iteration whose residual is < tol; -1 = never reached.

    Returns an int for a (T,) history and a (k,) int array for a batched
    (k, T) history — the never-reached sentinel is -1 in BOTH cases, so
    ``solve`` and ``solve_many`` results compare uniformly.
    """
    r = np.asarray(residuals)
    hit = r < tol
    if r.ndim == 1:
        return int(np.argmax(hit)) + 1 if hit.any() else -1
    first = np.argmax(hit, axis=-1) + 1
    return np.where(hit.any(axis=-1), first, -1)


class Solver:
    """Base class / protocol for every registered solver.

    Subclasses override the four lifecycle hooks (and ``default_params``)
    and inherit the shared ``solve`` / ``solve_many`` drivers.
    """

    name: str = "solver"
    paper_name: str = ""           # display name used in the paper's tables
    supports_kernel: bool = False  # Pallas block-projection path available
    param_names: Tuple[str, ...] = ()
    # System classes this solver handles; checked at dispatch against the
    # system's (mode, structure) — see solvers/capability.py.  "square" =
    # a consistent system with an exact solution; "least_squares" = the
    # iteration converges to argmin ||Ax-b|| on inconsistent systems (and
    # the LS hooks below are implemented); "sparse" = the step chain
    # consumes blockops.SparseBlocks operands.
    supports: frozenset = frozenset({"square"})
    # A prior state is a valid warm start for a DIFFERENT right-hand side:
    # the iteration re-reads b every step and the state caches nothing
    # RHS-dependent.  True for the gradient family and Cimmino; False for
    # APC (iterates stay feasible for the OLD b), M-ADMM (caches A^T b),
    # and P-DHBM (caches S b).  The serving layer gates perturbed-RHS warm
    # starts on this flag.
    warm_rhs_ok: bool = False

    # ----- lifecycle hooks (override) -------------------------------------
    def default_params(self, sys: BlockSystem) -> Dict[str, float]:
        """Analysis-time auto-tuning (Theorem 1 / Sec 4 closed forms)."""
        return {}

    def prepare(self, A: jnp.ndarray, params: Dict[str, float]) -> Any:
        """One-time factorization from the (m, p, n) row blocks only.

        MUST be independent of b — solve_many reuses it across a RHS batch.
        """
        raise NotImplementedError

    def init(self, factors: Any, b: jnp.ndarray,
             params: Dict[str, float]) -> Any:
        """Initial state for right-hand side blocks ``b`` of shape (m, p)."""
        raise NotImplementedError

    def step(self, factors: Any, b: jnp.ndarray, state: Any,
             params: Dict[str, float], *, use_kernel: bool = False) -> Any:
        """One synchronous iteration (all workers + master)."""
        raise NotImplementedError

    def step_many(self, factors: Any, Bb: jnp.ndarray, states: Any,
                  params: Dict[str, float], *,
                  use_kernel: bool = False) -> Any:
        """One iteration over a (k,)-batched RHS/state bundle.

        The default vmaps ``step`` over the batch axis; projection-family
        solvers override the ``use_kernel=True`` branch with the true
        multi-RHS Pallas kernels, where ONE read of every A/B tile serves
        the whole batch (``solve_many`` / ``LinsysServer`` hot path).
        """
        return jax.vmap(
            lambda b, s: self.step(factors, b, s, params,
                                   use_kernel=use_kernel),
            in_axes=(0, 0))(Bb, states)

    def extract(self, state: Any) -> jnp.ndarray:
        """The global estimate x (n,) carried by ``state``."""
        raise NotImplementedError

    # A solver that can rebuild a valid state for a NEW partition from
    # nothing but the global estimate sets this and implements
    # ``lift_state``.  This is the cross-partition warm start the elastic
    # runtime uses when the fleet is repartitioned (join/rejoin): states
    # are global-SHAPED but their per-block invariants (e.g. APC's
    # A_i x_i = b_i feasibility) are partition-specific, so a plain
    # ``warm_state=`` handoff across a repartition would be wrong.
    supports_lift: bool = False

    # ``prepare`` factorizes each row block independently and every factor
    # leaf carries a leading worker axis — the contract that lets
    # ``FactorStore.blockwise_factors`` assemble full factors from cached
    # per-block slices after a repartition.
    supports_block_store: bool = False

    def lift_state(self, factors: Any, b: jnp.ndarray,
                   params: Dict[str, float], x: jnp.ndarray) -> Any:
        """A state for THIS partition warm-started from the global
        estimate ``x`` of a previous (differently-partitioned) run.
        Must satisfy every invariant ``init`` establishes; ``extract``
        of the result should be (close to) ``x``."""
        raise NotImplementedError(
            f"solver {self.name!r} cannot lift a state across partitions "
            f"(supports_lift=False)")

    # ----- optional analysis hooks ----------------------------------------
    def theoretical_rate(self, sys: BlockSystem) -> Optional[float]:
        """Closed-form optimal spectral radius rho, if known (Table 1)."""
        return None

    def analyze(self, sys: BlockSystem):
        """(auto-tuned params, theoretical rho) in ONE spectral pass.

        Subclasses whose default_params and theoretical_rate share the same
        eigendecomposition override this to avoid computing it twice.
        """
        return self.default_params(sys), self.theoretical_rate(sys)

    def kernel_factors(self, factors: Any) -> Any:
        """Augment factors with kernel-path precomputation (pinv factors).

        Called once per solve when ``use_kernel=True`` so per-step code
        never refactorizes iteration-invariant quantities.  MUST be
        idempotent: implementations detect already-augmented factors (or
        tag them) and return them unchanged, so cached or user-supplied
        factors passed back into ``solve(use_kernel=True)`` are never
        re-augmented — the ``FactorStore`` relies on this to write the
        augmentation back into the cache slot exactly once.
        """
        return factors

    # ----- fused residual hooks -------------------------------------------
    # A kernel-capable solver whose gather pass already computes the
    # consumed state's residual blocks sets ``supports_fused_residual``
    # and implements the ``*_residual`` step variants, each returning
    # ``(new_state, rsq)`` with ``rsq`` the SQUARED residual norm of the
    # state the step CONSUMED (scalar, or (k,) for the batched variants).
    # The history drivers then record ‖Ax−b‖ per iteration withOUT a
    # second full read of A: the lagged records are shifted by one and the
    # history closes with a single true-A residual of the final state.
    supports_fused_residual: bool = False

    def step_residual(self, factors: Any, b: jnp.ndarray, state: Any,
                      params: Dict[str, float]) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the fused residual")

    def step_many_residual(self, factors: Any, Bb: jnp.ndarray, states: Any,
                           params: Dict[str, float]
                           ) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the fused residual")

    def mesh_step_residual(self, factors: Any, b: jnp.ndarray, state: Any,
                           params: Dict[str, float], ctx
                           ) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the fused residual")

    def mesh_step_many_residual(self, factors: Any, Bb: jnp.ndarray,
                                states: Any, params: Dict[str, float], ctx
                                ) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the fused residual")

    # ----- mixed-precision tile streams -----------------------------------
    def cast_factors(self, factors: Any, precision: str) -> Any:
        """Cast the kernel tile streams to the storage precision.

        ``precision="mixed"`` stores the memory-bound operand streams
        (A/B tiles) in bfloat16 while every kernel contraction still
        accumulates in f32 and the factorization (Cholesky) stays in the
        working precision.  Idempotent — casting already-cast factors is
        a no-op — so store-cached mixed factors round-trip freely.
        """
        if precision == "default":
            return factors
        raise NotImplementedError(
            f"solver {self.name!r} does not implement precision="
            f"{precision!r}")

    def _check_precision(self, precision: str, use_kernel: bool) -> None:
        if precision == "default":
            return
        if precision != "mixed":
            raise ValueError(f"unknown precision {precision!r}; expected "
                             f"'default' or 'mixed'")
        if not (use_kernel and self.supports_kernel):
            raise ValueError(
                "precision='mixed' casts the Pallas kernel tile streams "
                "(bf16 storage, f32 accumulation) and therefore requires "
                "use_kernel=True on a kernel-capable solver; "
                f"{self.name!r} was dispatched with use_kernel="
                f"{use_kernel} (supports_kernel={self.supports_kernel})")

    # ----- least-squares mode hooks ---------------------------------------
    # A solver declaring "least_squares" in ``supports`` implements BOTH
    # hooks (lint rule R008 enforces this).  ``ls_moment`` is the solver's
    # optimality map: the (weighted) normal-equation residual its fixed
    # point zeroes — plain A^T(Ax-b) for the gradient family, the
    # G^{-1}-weighted A^T G^{-1}(Ax-b) for Cimmino.  It is written against
    # the psum context so the same code runs locally (identity psums) and
    # inside shard_map; residual histories in LS mode report
    # ||ls_moment(x)|| / ||ls_moment(0)||, the scale-free LS optimality
    # measure, and ``iters_to_tol`` keys off it.

    def ls_moment(self, factors: Any, A, b: jnp.ndarray, x: jnp.ndarray,
                  params: Dict[str, float], ctx) -> jnp.ndarray:
        """The (n,) optimality vector this solver drives to zero."""
        raise NotImplementedError(
            f"solver {self.name!r} does not support least-squares mode")

    def ls_reference(self, sys: BlockSystem) -> jnp.ndarray:
        """The (n,) solution this solver converges to on an inconsistent
        system — the reference ``errors`` compares against when
        ``sys.x_true`` is absent."""
        raise NotImplementedError(
            f"solver {self.name!r} does not support least-squares mode")

    # ----- mesh-backend hooks (see solvers/mesh.py) ------------------------
    # The mesh backend runs these INSIDE shard_map: every array argument is
    # the device-local shard (worker axis and optionally the n axis cut),
    # and cross-shard reductions go through the MeshContext psum helpers.
    # Specs use ctx.w (worker axis entry) / ctx.n (column axis entry).

    def mesh_factor_specs(self, ctx):
        """PartitionSpec pytree matching ``prepare``'s factor structure."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the mesh backend")

    def mesh_state_specs(self, ctx):
        """PartitionSpec pytree matching the solver state structure."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the mesh backend")

    def mesh_prepare(self, A: jnp.ndarray, params: Dict[str, float], ctx):
        """On-mesh ``prepare`` from a local (m_loc, p, n_loc) shard of A."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the mesh backend")

    def mesh_init(self, factors: Any, b: jnp.ndarray,
                  params: Dict[str, float], ctx) -> Any:
        """On-mesh ``init``; the default reuses ``init``, which is correct
        whenever it contains no cross-worker/cross-column reduction."""
        return self.init(factors, b, params)

    def mesh_step(self, factors: Any, b: jnp.ndarray, state: Any,
                  params: Dict[str, float], ctx) -> Any:
        """One iteration on local shards (collectives via ``ctx``)."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement the mesh backend")

    def mesh_step_many(self, factors: Any, Bb: jnp.ndarray, states: Any,
                       params: Dict[str, float], ctx, *,
                       use_kernel: bool = False) -> Any:
        """Batched mesh step (RHS axis leading, replicated across shards).

        Default vmaps ``mesh_step``; projection solvers override the
        kernel branch with the multi-RHS Pallas kernels on the local
        (p × n_local) blocks — shard_map composes with Pallas, and the
        psum contract is identical (``use_kernel`` only reaches solvers
        with ``supports_kernel``, so the base may ignore it)."""
        return jax.vmap(
            lambda bb, st: self.mesh_step(factors, bb, st, params, ctx),
            in_axes=(0, 0))(Bb, states)

    def mesh_factors(self, factors: Any) -> Any:
        """Strip host-only fields before reusing factors on the mesh."""
        return factors

    # ----- redundancy hooks (see solvers/redundant.py) ---------------------
    # Straggler-tolerant execution replicates the row blocks r-redundantly
    # (cyclic assignment) and replaces the worker-axis reduction with a
    # masked block-unique one.  ``red_step``/``red_init`` are written ONCE
    # against the MeshContext psum contract: on the local backend the psums
    # are identities, on backend="mesh" they are the usual collectives.
    # Array layouts grow a slot axis: factors/b (m, r, ...), W is the
    # (m, r) selection-weight mask for the iteration.

    supports_redundancy: bool = False

    def red_factors(self, factors: Any, assign) -> Any:
        """Replicate b-independent factors along the cyclic assignment.

        Default: gather every leaf's leading worker axis through
        ``assign.holder`` — correct whenever all factor leaves are
        per-worker (leading axis m)."""
        return jax.tree.map(lambda f: jnp.asarray(f)[assign.holder], factors)

    def red_init(self, factors: Any, b: jnp.ndarray,
                 params: Dict[str, float], W0, ctx) -> Any:
        """Initial GLOBAL-structure state from replicated factors/b and the
        all-alive selection weights ``W0``."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement redundant execution")

    def red_step(self, factors: Any, b: jnp.ndarray, state: Any,
                 params: Dict[str, float], W, ctx) -> Any:
        """One masked iteration: every replica updates, the master reduce
        takes each block exactly once via ``W``."""
        raise NotImplementedError(
            f"solver {self.name!r} does not implement redundant execution")

    def red_expand(self, state: Any, assign) -> Any:
        """Lift a plain global-shape state to the replicated internal one
        (exactness invariant: replicas are identical copies).  Default:
        identity, for states with no per-block leaves."""
        return state

    def red_collapse(self, state: Any, assign) -> Any:
        """Inverse of ``red_expand``: back to the plain global shape so
        warm starts and checkpoints round-trip with non-redundant runs."""
        return state

    def red_factor_specs(self, ctx):
        """Mesh placement of replicated factors: the slot axis is local to
        its worker, so insert an unsharded dim after the worker axis."""
        from jax.sharding import PartitionSpec as _P
        return jax.tree.map(
            lambda s: _P(tuple(s)[0], None, *tuple(s)[1:]),
            self.mesh_factor_specs(ctx),
            is_leaf=lambda s: isinstance(s, _P))

    def red_state_specs(self, ctx):
        """Mesh placement of the replicated internal state (defaults to the
        plain state specs; override when state gains a slot axis)."""
        return self.mesh_state_specs(ctx)

    # ----- shared drivers --------------------------------------------------
    def resolve_params(self, sys: BlockSystem, **overrides) -> Dict[str, float]:
        """Merge explicit overrides over the auto-tuned defaults.

        The (possibly expensive) spectral analysis in ``default_params`` is
        skipped when the caller pins every required parameter.
        """
        given = {k: v for k, v in overrides.items() if v is not None}
        if self.param_names and all(k in given for k in self.param_names):
            return given
        return {**self.default_params(sys), **given}

    def _check_kernel(self, use_kernel: bool):
        if use_kernel and not self.supports_kernel:
            raise ValueError(
                f"solver {self.name!r} is not projection-based and has no "
                f"Pallas kernel path (use_kernel=True unsupported)")

    def _dispatch_mesh(self, backend: str, use_kernel: bool,
                       mesh: Any) -> bool:
        if backend == "local":
            if mesh is not None:
                raise ValueError("a mesh was passed but backend is 'local' "
                                 "— did you mean backend='mesh'?")
            return False
        if backend != "mesh":
            raise ValueError(f"unknown backend {backend!r}; "
                             "expected 'local' or 'mesh'")
        # use_kernel composes with the mesh backend: shard_map hands each
        # worker shard its local (p, n_local) block and the Pallas kernels
        # run on it unchanged (the psum contract is outside the kernel).
        self._check_kernel(use_kernel)
        return True

    def _store_factors(self, store, sys, factors, params, *,
                       use_kernel: bool = False, resume: bool = False):
        """Route the ``factors is None`` branch through a ``FactorStore``.

        Returns ``(factors, params)`` with params fully resolved when the
        store was consulted (so downstream ``resolve_params`` calls are
        cheap no-ops and the store key matches what actually runs).
        """
        if factors is not None or store is None:
            return factors, params
        prm = self.resolve_params(sys, **params)
        return store.factors(self, sys, use_kernel=use_kernel,
                             resume=resume, **prm), prm

    def solve(self, sys: BlockSystem, *, iters: int = 1000, tol: float = 1e-6,
              plan: Optional[ExecutionPlan] = None,
              use_kernel: Any = _UNSET, precision: Any = _UNSET,
              warm_state: Any = _UNSET,
              factors: Any = _UNSET, store: Any = _UNSET,
              backend: Any = _UNSET, mesh: Any = _UNSET,
              worker_axes: Any = _UNSET, model_axis: Any = _UNSET,
              redundancy: Any = _UNSET, alive_schedule: Any = _UNSET,
              **params) -> SolveResult:
        """End-to-end solve: prepare -> init (or warm-start) -> scan steps.

        The execution surface travels on ONE validated object::

            solve(sys, plan=ExecutionPlan(backend="mesh", kernel=True),
                  iters=500, tol=1e-6, **params)

        ``plan.factors`` (from an earlier ``prepare`` with the same
        params) skips the one-time factorization; ``plan.store``
        (``solvers.FactorStore``) turns the ``factors is None`` branch
        into a content-addressed cache lookup (memory LRU, optional disk
        tier) instead of an unconditional re-``prepare``.  Cached-factor
        serving (``solvers.serve``) and the checkpoint-resume driver use
        these.

        ``backend="mesh"`` runs the identical lifecycle sharded over a
        device mesh (``mesh=None`` builds one over the available
        devices); ``worker_axes``/``model_axis`` choose which mesh axes
        the row blocks and the n dimension shard over.

        ``redundancy=r`` (projection family, both backends) replicates
        the row blocks r-redundantly so iterations tolerate stragglers
        named by ``alive_schedule`` (callable t -> (m,) mask, a mask
        array, or a ``runtime.fault.HeartbeatMonitor``) with EXACT
        semantics — see ``solvers/redundant.py``.

        ``precision="mixed"`` (kernel path only) stores the streamed A/B
        tiles in bfloat16 with f32 accumulation — residual histories hold
        to the bf16 storage tolerance (~1e-2 relative) at half the HBM
        bytes per iteration.

        The loose kwargs (``use_kernel=``, ``backend=``, ...) are a
        DEPRECATED shim: they build the same plan and warn once.
        """
        plan = _coerce_plan(plan, dict(
            use_kernel=use_kernel, precision=precision,
            warm_state=warm_state, factors=factors, store=store,
            backend=backend, mesh=mesh, worker_axes=worker_axes,
            model_axis=model_axis, redundancy=redundancy,
            alive_schedule=alive_schedule), context="solve")
        plan = resolve_plan(self, sys, plan, context="solve")
        resume = plan.warm_state is not None
        if plan.is_redundant:
            factors, params = self._store_factors(
                plan.store, sys, plan.factors, params, resume=resume)
            from . import redundant as red_backend
            return red_backend.solve_redundant(
                self, sys, r=plan.redundancy, iters=iters, tol=tol,
                alive_schedule=plan.alive_schedule,
                warm_state=plan.warm_state, factors=factors,
                backend=plan.backend, mesh=plan.mesh,
                worker_axes=plan.worker_axes, model_axis=plan.model_axis,
                **params)
        if plan.backend == "mesh":
            # the store is threaded INTO the backend: a miss there runs
            # the on-mesh sharded mesh_prepare (no host factorization)
            # and inserts the result, so hits flow both ways
            from . import mesh as mesh_backend
            return mesh_backend.solve_mesh(
                self, sys, mesh=plan.mesh, iters=iters, tol=tol,
                worker_axes=plan.worker_axes, model_axis=plan.model_axis,
                warm_state=plan.warm_state, factors=plan.factors,
                store=plan.store, use_kernel=plan.kernel,
                precision=plan.precision, **params)
        use_kernel, precision = plan.kernel, plan.precision
        warm_state, factors, store = plan.warm_state, plan.factors, plan.store
        prm = self.resolve_params(sys, **params)
        if factors is None:
            if store is not None:
                factors = store.factors(self, sys, use_kernel=use_kernel,
                                        resume=resume, precision=precision,
                                        **prm)
            else:
                if resume:
                    # a warm-start resume silently repaying the full
                    # b-independent prepare is the cost a FactorStore
                    # exists to amortize — make it visible
                    log.info(
                        "solve(warm_state=...) without cached factors: "
                        "re-running the full prepare for %r (pass store= "
                        "to count and amortize this as a cache miss)",
                        self.name)
                factors = self.prepare(sys.A_op, prm)
        if use_kernel:
            factors = self.kernel_factors(factors)
        if precision != "default":
            factors = self.cast_factors(factors, precision)   # idempotent
        state = (self.init(factors, sys.b_blocks, prm)
                 if warm_state is None else warm_state)
        step = lambda f, b, s: self.step(f, b, s, prm, use_kernel=use_kernel)
        residual_fn = self._ls_residual_fn(sys, factors, prm)
        xt = sys.x_true
        if xt is None and sys.mode == "least_squares":
            xt = jnp.asarray(self.ls_reference(sys))
        step_res = None
        if (use_kernel and self.supports_fused_residual
                and residual_fn is None and iters > 0):
            step_res = lambda f, b, s: self.step_residual(f, b, s, prm)
        state, res, err = _history_scan(step, self.extract, factors,
                                        sys.b_blocks, state, sys.A_op,
                                        xt, iters, residual_fn=residual_fn,
                                        step_residual=step_res)
        return SolveResult(
            name=self.name, x=self.extract(state), state=state, residuals=res,
            errors=err if xt is not None else None, params=prm,
            iters_to_tol=iters_to_tolerance(res, tol), tol=tol)

    def _ls_residual_fn(self, sys: BlockSystem, factors: Any,
                        prm: Dict[str, float]):
        """The LS-mode residual closure for the local scan drivers, or
        None in square mode (the plain ``||Ax-b||/||b||`` path)."""
        if sys.mode != "least_squares":
            return None
        A_op, ctx = sys.A_op, LOCAL_PSUM
        zero = jnp.zeros(sys.n, sys.b_blocks.dtype)

        def optim(b, x):
            mom = self.ls_moment(factors, A_op, b, x, prm, ctx)
            return jnp.sqrt(jnp.sum(mom * mom))

        def residual_fn(b, x):
            return optim(b, x) / optim(b, zero)

        return residual_fn

    def solve_many(self, sys: BlockSystem, B, *, iters: int = 1000,
                   tol: float = 1e-6,
                   plan: Optional[ExecutionPlan] = None,
                   use_kernel: Any = _UNSET, precision: Any = _UNSET,
                   factors: Any = _UNSET, store: Any = _UNSET,
                   backend: Any = _UNSET,
                   mesh: Any = _UNSET, worker_axes: Any = _UNSET,
                   model_axis: Any = _UNSET,
                   redundancy: Any = _UNSET, alive_schedule: Any = _UNSET,
                   **params) -> SolveResult:
        """Batched multi-RHS solve sharing ONE ``prepare`` factorization.

        ``B`` is (k, N) — k right-hand sides for the same A.  Returns a
        batched SolveResult: x (k, n), residuals (k, T), errors None.
        The ``plan=`` surface behaves as in ``solve`` (redundancy is
        rejected at plan resolution — run redundant solves per RHS); the
        loose kwargs are the same deprecated shim.
        """
        plan = _coerce_plan(plan, dict(
            use_kernel=use_kernel, precision=precision, factors=factors,
            store=store, backend=backend, mesh=mesh,
            worker_axes=worker_axes, model_axis=model_axis,
            redundancy=redundancy, alive_schedule=alive_schedule),
            context="solve_many")
        plan = resolve_plan(self, sys, plan, context="solve_many")
        if plan.backend == "mesh":
            from . import mesh as mesh_backend
            return mesh_backend.solve_many_mesh(
                self, sys, B, mesh=plan.mesh, iters=iters, tol=tol,
                worker_axes=plan.worker_axes, model_axis=plan.model_axis,
                factors=plan.factors, store=plan.store,
                use_kernel=plan.kernel, precision=plan.precision,
                **params)
        use_kernel, precision = plan.kernel, plan.precision
        factors, store = plan.factors, plan.store
        B = jnp.asarray(B)
        if B.ndim == 1:
            B = B[None, :]
        if B.shape[-1] != sys.N:
            raise ValueError(f"RHS batch has {B.shape[-1]} rows, need N={sys.N}")
        k = B.shape[0]
        Bb = B.reshape(k, sys.m, sys.p)
        prm = self.resolve_params(sys, **params)
        if factors is None:
            if store is not None:
                factors = store.factors(self, sys, use_kernel=use_kernel,
                                        precision=precision, **prm)
            else:
                factors = self.prepare(sys.A_op, prm)  # once, shared
        if use_kernel:
            factors = self.kernel_factors(factors)
        if precision != "default":
            factors = self.cast_factors(factors, precision)   # idempotent
        states = jax.vmap(lambda b: self.init(factors, b, prm))(Bb)
        step_many = lambda f, bb, sts: self.step_many(
            f, bb, sts, prm, use_kernel=use_kernel)
        residual_fn = self._ls_residual_fn(sys, factors, prm)
        step_many_res = None
        if (use_kernel and self.supports_fused_residual
                and residual_fn is None and iters > 0):
            step_many_res = lambda f, bb, sts: self.step_many_residual(
                f, bb, sts, prm)
        states, res = _history_scan_many(
            step_many, self.extract, factors, Bb, states, sys.A_op, iters,
            residual_fn=residual_fn, step_many_residual=step_many_res)
        X = jax.vmap(self.extract)(states)
        return SolveResult(
            name=self.name, x=X, state=states, residuals=res, errors=None,
            params=prm, iters_to_tol=iters_to_tolerance(res, tol), tol=tol)


# ---------------------------------------------------------------------------
# Shared jitted history drivers
# ---------------------------------------------------------------------------


def _history_scan(step, extract, factors, b, state, A, x_true, iters: int,
                  residual_fn=None, step_residual=None):
    """Scan ``step`` for ``iters`` iterations recording residual/error.

    ``A`` is either the dense (m, p, n) stack or a ``SparseBlocks``
    operand; the dense matvec is the identical einsum the driver always
    used, so dense histories are bit-exact.  ``residual_fn(b, x)``
    (LS mode) replaces the plain ``||Ax-b||/||b||`` history.

    ``step_residual(factors, b, state) -> (state, rsq)`` switches to the
    FUSED residual: each step harvests ‖Ax−b‖² of the state it consumed
    from its own gather pass, so the history costs no second full read of
    A per iteration.  The lagged records are shifted by one and the
    history closes with ONE true-A residual of the final state — same
    indexing as the plain path (entry t = residual after step t+1).
    """
    b_norm = jnp.sqrt(jnp.sum(b * b))
    xt = x_true
    xt_norm = None if xt is None else jnp.linalg.norm(xt)

    if step_residual is not None:
        def body(state, _):
            state, rsq = step_residual(factors, b, state)
            res = jnp.sqrt(rsq) / b_norm
            x = extract(state)
            err = (jnp.linalg.norm(x - xt) / xt_norm) if xt is not None \
                else res
            return state, (res, err)

        state, (res, err) = jax.lax.scan(body, state, None, length=iters)
        r = blockops.bmatvec(A, extract(state)) - b
        final = jnp.sqrt(jnp.sum(r * r)) / b_norm
        res = jnp.concatenate([res[1:], final[None]])
        if xt is None:
            err = res          # error channel aliases the shifted history
        return state, res, err

    def body(state, _):
        state = step(factors, b, state)
        x = extract(state)
        if residual_fn is None:
            r = blockops.bmatvec(A, x) - b
            res = jnp.sqrt(jnp.sum(r * r)) / b_norm
        else:
            res = residual_fn(b, x)
        err = (jnp.linalg.norm(x - xt) / xt_norm) if xt is not None else res
        return state, (res, err)

    state, (res, err) = jax.lax.scan(body, state, None, length=iters)
    return state, res, err


def _history_scan_many(step_many, extract, factors, Bb, states, A,
                       iters: int, residual_fn=None,
                       step_many_residual=None):
    """Batched variant: states/Bb carry a leading (k,) RHS axis.

    ``step_many`` is the solver's batched iteration — a vmap of ``step``
    by default, the fused multi-RHS kernel path for the projection family
    under ``use_kernel=True``.  ``residual_fn(b, x)`` is the per-RHS LS
    residual; it is vmapped over the batch.  ``step_many_residual`` is the
    batched fused-residual variant (same lagged-shift contract as
    ``_history_scan``).
    """
    b_norms = jnp.sqrt(jnp.sum(Bb * Bb, axis=(1, 2)))

    if step_many_residual is not None:
        def body(states, _):
            states, rsq = step_many_residual(factors, Bb, states)
            return states, jnp.sqrt(rsq) / b_norms         # (k,)

        states, res = jax.lax.scan(body, states, None, length=iters)
        X = jax.vmap(extract)(states)
        r = blockops.bmatvec_many(A, X) - Bb
        final = jnp.sqrt(jnp.sum(r * r, axis=(1, 2))) / b_norms
        res = jnp.concatenate([res[1:], final[None]], axis=0)
        return states, res.T                               # (k, T)

    def body(states, _):
        states = step_many(factors, Bb, states)
        X = jax.vmap(extract)(states)                      # (k, n)
        if residual_fn is None:
            r = blockops.bmatvec_many(A, X) - Bb
            res = jnp.sqrt(jnp.sum(r * r, axis=(1, 2))) / b_norms
        else:
            res = jax.vmap(residual_fn)(Bb, X)
        return states, res

    states, res = jax.lax.scan(body, states, None, length=iters)
    return states, res.T                                   # (k, T)
