"""The canonical solver API: one lifecycle, one result type.

Every distributed solver in this repo — APC and all of the paper's
comparison methods — implements the same three-phase lifecycle:

    factors = solver.prepare(A_blocks, params)   # one-time, b-INDEPENDENT
    state   = solver.init(factors, b_blocks, params)
    state   = solver.step(factors, b_blocks, state, params)

on top of which this module provides the shared drivers:

    solver.solve(sys, iters=..., **params)       -> SolveResult
    solver.solve_many(sys, B, iters=...)         -> SolveResult (batched)

``prepare`` must not look at the right-hand side: everything expensive
(Gram Cholesky factors, preconditioners) depends only on A, which is what
lets ``solve_many`` amortize one factorization across a batch of RHS — the
serving hot path — and lets a cached ``factors`` be reused across requests.

Warm starts: any prior ``SolveResult.state`` (or a state restored via
``repro.checkpoint.ckpt``) can be passed back as ``solve(...,
warm_state=state)`` to resume iterating instead of starting from scratch.

Projection-family solvers (``apc``, ``consensus``, ``cimmino``) additionally
accept ``use_kernel=True`` to route the per-worker projection through the
Pallas TPU kernel, and auto-tune their parameters from the Theorem-1
spectral analysis when none are given.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import BlockSystem


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Unified result record returned by every registered solver.

    For ``solve_many`` the leading axis of ``x`` / ``residuals`` /
    ``iters_to_tol`` is the RHS batch and ``errors`` is None.
    """
    name: str                      # registry key of the solver that ran
    x: jnp.ndarray                 # final global estimate (n,) or (k, n)
    state: Any                     # full solver state (checkpoint / warm-start)
    residuals: jnp.ndarray         # (T,) or (k, T)  ||Ax-b|| / ||b|| per iter
    errors: Optional[jnp.ndarray]  # (T,) ||x-x*||/||x*|| if sys.x_true given
    params: Dict[str, float]       # hyper-parameters actually used
    iters_to_tol: Any = None       # first iter with residual < tol (None/-1 =
                                   # never reached); array (k,) for solve_many
    tol: float = 1e-6              # tolerance iters_to_tol was computed at

    def iters_to(self, tol: float):
        """Iterations needed to push the residual below ``tol``."""
        return iters_to_tolerance(self.residuals, tol)


def iters_to_tolerance(residuals, tol: float):
    """First 1-based iteration whose residual is < tol.

    Returns None (scalar history) or -1 (batched history) where the
    tolerance was never reached.
    """
    r = np.asarray(residuals)
    hit = r < tol
    if r.ndim == 1:
        return int(np.argmax(hit)) + 1 if hit.any() else None
    first = np.argmax(hit, axis=-1) + 1
    return np.where(hit.any(axis=-1), first, -1)


class Solver:
    """Base class / protocol for every registered solver.

    Subclasses override the four lifecycle hooks (and ``default_params``)
    and inherit the shared ``solve`` / ``solve_many`` drivers.
    """

    name: str = "solver"
    paper_name: str = ""           # display name used in the paper's tables
    supports_kernel: bool = False  # Pallas block-projection path available
    param_names: Tuple[str, ...] = ()

    # ----- lifecycle hooks (override) -------------------------------------
    def default_params(self, sys: BlockSystem) -> Dict[str, float]:
        """Analysis-time auto-tuning (Theorem 1 / Sec 4 closed forms)."""
        return {}

    def prepare(self, A: jnp.ndarray, params: Dict[str, float]) -> Any:
        """One-time factorization from the (m, p, n) row blocks only.

        MUST be independent of b — solve_many reuses it across a RHS batch.
        """
        raise NotImplementedError

    def init(self, factors: Any, b: jnp.ndarray,
             params: Dict[str, float]) -> Any:
        """Initial state for right-hand side blocks ``b`` of shape (m, p)."""
        raise NotImplementedError

    def step(self, factors: Any, b: jnp.ndarray, state: Any,
             params: Dict[str, float], *, use_kernel: bool = False) -> Any:
        """One synchronous iteration (all workers + master)."""
        raise NotImplementedError

    def extract(self, state: Any) -> jnp.ndarray:
        """The global estimate x (n,) carried by ``state``."""
        raise NotImplementedError

    # ----- optional analysis hooks ----------------------------------------
    def theoretical_rate(self, sys: BlockSystem) -> Optional[float]:
        """Closed-form optimal spectral radius rho, if known (Table 1)."""
        return None

    def analyze(self, sys: BlockSystem):
        """(auto-tuned params, theoretical rho) in ONE spectral pass.

        Subclasses whose default_params and theoretical_rate share the same
        eigendecomposition override this to avoid computing it twice.
        """
        return self.default_params(sys), self.theoretical_rate(sys)

    def kernel_factors(self, factors: Any) -> Any:
        """Augment factors with kernel-path precomputation (pinv factors).

        Called once per solve when ``use_kernel=True`` so per-step code
        never refactorizes iteration-invariant quantities.
        """
        return factors

    # ----- shared drivers --------------------------------------------------
    def resolve_params(self, sys: BlockSystem, **overrides) -> Dict[str, float]:
        """Merge explicit overrides over the auto-tuned defaults.

        The (possibly expensive) spectral analysis in ``default_params`` is
        skipped when the caller pins every required parameter.
        """
        given = {k: v for k, v in overrides.items() if v is not None}
        if self.param_names and all(k in given for k in self.param_names):
            return given
        return {**self.default_params(sys), **given}

    def _check_kernel(self, use_kernel: bool):
        if use_kernel and not self.supports_kernel:
            raise ValueError(
                f"solver {self.name!r} is not projection-based and has no "
                f"Pallas kernel path (use_kernel=True unsupported)")

    def solve(self, sys: BlockSystem, *, iters: int = 1000, tol: float = 1e-6,
              use_kernel: bool = False, warm_state: Any = None,
              factors: Any = None, **params) -> SolveResult:
        """End-to-end solve: prepare -> init (or warm-start) -> scan steps.

        Pass ``factors`` (from an earlier ``prepare`` with the same params)
        to skip the one-time factorization — cached-factor serving and the
        checkpoint-resume driver use this.
        """
        self._check_kernel(use_kernel)
        prm = self.resolve_params(sys, **params)
        if factors is None:
            factors = self.prepare(sys.A_blocks, prm)
        if use_kernel:
            factors = self.kernel_factors(factors)
        state = (self.init(factors, sys.b_blocks, prm)
                 if warm_state is None else warm_state)
        step = lambda f, b, s: self.step(f, b, s, prm, use_kernel=use_kernel)
        state, res, err = _history_scan(step, self.extract, factors,
                                        sys.b_blocks, state, sys.A_blocks,
                                        sys.x_true, iters)
        return SolveResult(
            name=self.name, x=self.extract(state), state=state, residuals=res,
            errors=err if sys.x_true is not None else None, params=prm,
            iters_to_tol=iters_to_tolerance(res, tol), tol=tol)

    def solve_many(self, sys: BlockSystem, B, *, iters: int = 1000,
                   tol: float = 1e-6, use_kernel: bool = False,
                   factors: Any = None, **params) -> SolveResult:
        """Batched multi-RHS solve sharing ONE ``prepare`` factorization.

        ``B`` is (k, N) — k right-hand sides for the same A.  Returns a
        batched SolveResult: x (k, n), residuals (k, T), errors None.
        ``factors`` behaves as in ``solve``.
        """
        self._check_kernel(use_kernel)
        B = jnp.asarray(B)
        if B.ndim == 1:
            B = B[None, :]
        if B.shape[-1] != sys.N:
            raise ValueError(f"RHS batch has {B.shape[-1]} rows, need N={sys.N}")
        k = B.shape[0]
        Bb = B.reshape(k, sys.m, sys.p)
        prm = self.resolve_params(sys, **params)
        if factors is None:
            factors = self.prepare(sys.A_blocks, prm)      # once, shared
        if use_kernel:
            factors = self.kernel_factors(factors)
        states = jax.vmap(lambda b: self.init(factors, b, prm))(Bb)
        step = lambda f, b, s: self.step(f, b, s, prm, use_kernel=use_kernel)
        states, res = _history_scan_many(step, self.extract, factors, Bb,
                                         states, sys.A_blocks, iters)
        X = jax.vmap(self.extract)(states)
        return SolveResult(
            name=self.name, x=X, state=states, residuals=res, errors=None,
            params=prm, iters_to_tol=iters_to_tolerance(res, tol), tol=tol)


# ---------------------------------------------------------------------------
# Shared jitted history drivers
# ---------------------------------------------------------------------------


def _history_scan(step, extract, factors, b, state, A, x_true, iters: int):
    """Scan ``step`` for ``iters`` iterations recording residual/error."""
    b_norm = jnp.sqrt(jnp.sum(b * b))
    xt = x_true
    xt_norm = None if xt is None else jnp.linalg.norm(xt)

    def body(state, _):
        state = step(factors, b, state)
        x = extract(state)
        r = jnp.einsum("mpn,n->mp", A, x) - b
        res = jnp.sqrt(jnp.sum(r * r)) / b_norm
        err = (jnp.linalg.norm(x - xt) / xt_norm) if xt is not None else res
        return state, (res, err)

    state, (res, err) = jax.lax.scan(body, state, None, length=iters)
    return state, res, err


def _history_scan_many(step, extract, factors, Bb, states, A, iters: int):
    """Batched variant: states/Bb carry a leading (k,) RHS axis."""
    b_norms = jnp.sqrt(jnp.sum(Bb * Bb, axis=(1, 2)))
    vstep = jax.vmap(lambda b, s: step(factors, b, s), in_axes=(0, 0))

    def body(states, _):
        states = vstep(Bb, states)
        X = jax.vmap(extract)(states)                      # (k, n)
        r = jnp.einsum("mpn,kn->kmp", A, X) - Bb
        res = jnp.sqrt(jnp.sum(r * r, axis=(1, 2))) / b_norms
        return states, res

    states, res = jax.lax.scan(body, states, None, length=iters)
    return states, res.T                                   # (k, T)
