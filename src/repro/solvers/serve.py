"""Linear-system request server on the unified solver API.

``LinsysServer`` turns the paper's cost split into a serving loop: a
stream of ``(system_fingerprint, rhs)`` requests is coalesced into
same-system ``solve_many`` batches, every factorization comes from a
content-addressed ``FactorStore`` (memory LRU + optional disk tier), and a
compile-once executor cache keyed by (solver, shapes, params, backend)
means steady-state serving never retraces.

    store = FactorStore(directory="/ckpt/factors")
    srv = LinsysServer(store, solver="apc", iters=500, batch=4)
    fp = srv.register(sys)                      # fingerprint the system
    srv.submit(fp, b1); srv.submit(fp, b2)      # enqueue right-hand sides
    for served in srv.drain():                  # FIFO, coalesced batches
        served.x, served.residual

Batching follows the LM serving driver's queue semantics (``take_group``
lives here and ``repro.launch.serve`` imports it): groups are FIFO, a
short final group is padded by repeating the last request so the compiled
batch shape stays stable, and padding is NEVER counted in throughput.

Kernel serving (``use_kernel=True``, projection solvers): every coalesced
batch runs through the fused multi-RHS Pallas kernels — one read of each
A/B tile serves the whole batch — on either backend; the store entry is
augmented with the pinv factors exactly once.

Warm starts (``warm_start=True``): a system's previous batch state seeds
the next one.  Repeated right-hand sides always qualify (that is exactly
``solve(warm_state=...)`` resume); PERTURBED right-hand sides only
qualify for solvers whose iteration re-reads b every step and whose state
caches nothing RHS-dependent (``Solver.warm_rhs_ok`` — the gradient
family and Cimmino; APC iterates stay feasible for the OLD b, and
M-ADMM / P-DHBM cache transformed right-hand sides in their state, so the
server silently falls back to a cold init for them).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.partition import BlockSystem

from .api import LOCAL_PSUM, _history_scan_many, iters_to_tolerance
from .capability import (ExecutionPlan, check_capability,
                         resolve_use_kernel)
from .store import FactorStore


def take_group(queue, batch: int):
    """Pop the next slot group off the request queue, FIFO.

    Returns ``(group, n_real)``: up to ``batch`` requests in arrival order,
    padded by repeating the last one so the compiled batch shape is stable.
    Only ``n_real`` requests were actually served — padding must never be
    counted in throughput.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    n_real = min(batch, len(queue))
    group = [queue.popleft() for _ in range(n_real)]
    while group and len(group) < batch:
        group.append(group[-1])
    return group, n_real


class Request(NamedTuple):
    rid: int            # server-assigned id, arrival order
    fp: str             # system fingerprint (FactorStore key)
    rhs: np.ndarray     # (N,) right-hand side


class Served(NamedTuple):
    """Per-request result handed back by ``step``/``drain``."""
    rid: int
    fp: str
    x: np.ndarray       # (n,) solution estimate
    residual: float     # final relative residual ||Ax-b||/||b||
    iters_to_tol: int   # -1 sentinel = tolerance never reached
    warm: bool          # batch was warm-started from a prior state


@dataclasses.dataclass
class ServerStats:
    served: int = 0             # real requests completed (padding excluded)
    padded: int = 0             # pad slots run (never counted as traffic)
    batches: int = 0
    warm_batches: int = 0
    executor_builds: int = 0    # compile-once cache misses
    admitted: int = 0           # accepted into the pipeline (async server)
    shed: int = 0               # rejected at admission (async server
                                # backpressure; the sync server never sheds)


@dataclasses.dataclass
class _System:
    """Per-registered-system serving state."""
    sys: BlockSystem
    prm: Dict[str, float]
    dtype: Any                      # A's dtype, read once at register()
    executor_key: Tuple             # compile-once cache key, built once
    use_kernel: bool = False        # per-system resolution (downgraded only
                                    # for solvers with no kernel engine)
    A_placed: Any = None            # backend-placed A blocks
    factors_placed: Any = None      # backend-placed factors
    placed_src: Any = None          # host factors the placement came from
    last_states: Any = None         # prior batch's final states (warm start)
    last_Bb: Optional[np.ndarray] = None


class _LocalExecutor:
    """Compile-once single-host executor: jitted init+scan over a padded
    (batch, m, p) RHS block.  One instance serves every system that shares
    its (shapes, params) key.  ``use_kernel=True`` routes the batched step
    through the fused multi-RHS Pallas kernels (``Solver.step_many``).
    ``ls_mode=True`` (least-squares systems) reports the LS optimality
    moment instead of the raw relative residual."""

    def __init__(self, solver, prm, iters: int, use_kernel: bool = False,
                 ls_mode: bool = False):
        fused_res = (use_kernel and solver.supports_fused_residual
                     and not ls_mode and iters > 0)

        def _residual_fn(A, factors):
            if not ls_mode:
                return None

            def optim(b, x):
                mom = solver.ls_moment(factors, A, b, x, prm, LOCAL_PSUM)
                return jnp.sqrt(jnp.sum(mom * mom))

            return lambda b, x: optim(b, x) / optim(b, jnp.zeros_like(x))

        def _run(A, factors, Bb, states):
            step_many = lambda f, bb, sts: solver.step_many(
                f, bb, sts, prm, use_kernel=use_kernel)
            step_many_res = (lambda f, bb, sts: solver.step_many_residual(
                f, bb, sts, prm)) if fused_res else None
            states, res = _history_scan_many(
                step_many, solver.extract, factors, Bb, states, A, iters,
                residual_fn=_residual_fn(A, factors),
                step_many_residual=step_many_res)
            return states, jax.vmap(solver.extract)(states), res

        def _cold(A, factors, Bb):
            states = jax.vmap(lambda b: solver.init(factors, b, prm))(Bb)
            return _run(A, factors, Bb, states)

        self._cold = jax.jit(_cold)
        self._warm = jax.jit(_run)

    def place_system(self, sys: BlockSystem, factors):
        return sys.A_op, factors

    def place_B(self, Bb: np.ndarray):
        # an explicit device_put so the host->device transfer happens on
        # the CALLING thread — the async pipeline runs this on its
        # assembly thread, double-buffering the copy behind execution
        return jax.device_put(jnp.asarray(Bb))

    def run(self, A, factors, Bb, states=None):
        if states is None:
            return self._cold(A, factors, Bb)
        return self._warm(A, factors, Bb, states)

    def cache_size(self) -> int:
        sizes = [getattr(f, "_cache_size", lambda: -1)()
                 for f in (self._cold, self._warm)]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)


class _MeshExecutor:
    """Mesh twin: wraps ``mesh.batched_runner`` and owns placement."""

    def __init__(self, solver, prm, iters: int, sys: BlockSystem,
                 mesh, worker_axes, model_axis, use_kernel: bool = False):
        from . import mesh as mesh_backend
        self.solver = solver
        self.use_kernel = use_kernel
        self.mesh = mesh if mesh is not None \
            else mesh_backend._default_mesh(sys.m)
        self.ctx = mesh_backend.make_context(
            self.mesh, sys, worker_axes=worker_axes, model_axis=model_axis)
        self.runner = mesh_backend.batched_runner(
            solver, self.ctx, prm, iters, use_kernel=use_kernel,
            a_spec=mesh_backend.operand_specs(sys, self.ctx),
            ls_mode=sys.mode == "least_squares",
            fused_residual=use_kernel)

    def place_system(self, sys: BlockSystem, factors):
        from . import mesh as mesh_backend
        A = mesh_backend._put_tree(sys.A_op, self.runner.A_spec, self.mesh)
        f = mesh_backend._put_tree(
            mesh_backend._host_factors(self.solver, factors,
                                       self.use_kernel),
            self.runner.factor_specs, self.mesh)
        return A, f

    def place_B(self, Bb: np.ndarray):
        return jax.device_put(jnp.asarray(Bb),
                              NamedSharding(self.mesh, self.runner.Bb_spec))

    def run(self, A, factors, Bb, states=None):
        if states is None:
            states = self.runner.init(factors, Bb)
        return self.runner.run(A, Bb, factors, states)

    def cache_size(self) -> int:
        return self.runner.cache_size()


class LinsysServer:
    """Batched linear-system serving on the unified solver lifecycle.

    Requests for the SAME system (by content fingerprint) are coalesced
    into ``solve_many`` batches; the oldest pending request picks which
    system is served next, so no system starves while coalescing still
    fills batches.  All factor acquisition goes through the
    ``FactorStore`` — the first request for a system pays ``prepare``
    (a store miss, or a disk hit after a restart), every later one is a
    cache hit.
    """

    def __init__(self, store: Optional[FactorStore] = None, *,
                 solver="apc", iters: int = 500, tol: float = 1e-6,
                 batch: int = 4, plan: Optional[ExecutionPlan] = None,
                 backend: str = "local", mesh=None,
                 warm_start: bool = False, use_kernel: bool = False,
                 precision: str = "default",
                 worker_axes: Sequence[str] = ("data",),
                 model_axis: Optional[str] = "model", **params):
        if plan is not None:
            if not isinstance(plan, ExecutionPlan):
                raise TypeError(f"plan must be an ExecutionPlan, got "
                                f"{type(plan).__name__}")
            if (backend != "local" or mesh is not None or use_kernel
                    or precision != "default"
                    or tuple(worker_axes) != ("data",)
                    or model_axis != "model"):
                raise ValueError(
                    "pass the execution surface EITHER on plan= OR as "
                    "loose kwargs, not both")
            if plan.is_redundant:
                raise ValueError(
                    "redundant execution is not servable: the coalesced "
                    "solve_many batches have no coded replicated layout; "
                    "run solve(plan=ExecutionPlan(redundancy=..., "
                    "alive_schedule=...)) per right-hand side")
            if plan.warm_state is not None or plan.factors is not None:
                raise ValueError(
                    "a server plan cannot carry warm_state=/factors= — "
                    "warm starts are per-system (warm_start=True) and "
                    "factors flow through the FactorStore")
            if store is None and plan.store is not None:
                store = plan.store
            backend, mesh = plan.backend, plan.mesh
            use_kernel, precision = plan.kernel, plan.precision
            worker_axes, model_axis = plan.worker_axes, plan.model_axis
        else:
            plan = ExecutionPlan(backend=backend, kernel=use_kernel,
                                 precision=precision, mesh=mesh,
                                 worker_axes=tuple(worker_axes),
                                 model_axis=model_axis)
        if backend not in ("local", "mesh"):
            raise ValueError(f"unknown backend {backend!r}; "
                             "expected 'local' or 'mesh'")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        from .registry import get
        self.store = store if store is not None else FactorStore()
        self.solver = get(solver) if isinstance(solver, str) else solver
        self.solver._check_kernel(use_kernel)
        self.solver._check_precision(precision, use_kernel)
        self.plan = plan
        self.iters, self.tol, self.batch = iters, tol, batch
        self.backend, self.mesh = backend, mesh
        self.warm_start = warm_start
        self.use_kernel = use_kernel
        self.precision = precision
        self.worker_axes, self.model_axis = tuple(worker_axes), model_axis
        self.params = params
        self.stats = ServerStats()
        self._systems: Dict[str, _System] = {}
        self._queues: Dict[str, deque] = {}
        self._executors: Dict[Tuple, Any] = {}
        self._rid = 0

    # ----- request intake ---------------------------------------------------
    def register(self, sys: BlockSystem, **params) -> str:
        """Fingerprint ``sys`` and make it servable.  Factors are NOT
        prefetched — the first request pays the store miss (or disk hit),
        which is what the cold/warm benchmarks measure.  Per-register
        ``params`` override the server-level ones key by key.

        Capability is checked HERE — an unservable (solver, system-mode)
        pair fails at registration, not on the first request.  The kernel
        flag resolves per system: sparse systems on kernel-capable solvers
        keep the fused path (the compressed-support Pallas pair); only a
        solver with no kernel engine downgrades it, loudly."""
        check_capability(self.solver, sys, context="register")
        use_kernel = resolve_use_kernel(self.solver, sys, self.use_kernel)
        # re-check per system: a sparse downgrade of the kernel flag must
        # not silently serve full-precision under precision="mixed"
        self.solver._check_precision(self.precision, use_kernel)
        prm = self.solver.resolve_params(sys, **{**self.params, **params})
        fp = self.store.key(self.solver, sys, precision=self.precision,
                            **prm)
        dtype = sys.A_blocks.dtype
        # the dispatch identity is the PLAN's signature (backend, kernel,
        # precision, worker/model axes...) with the per-system kernel
        # resolution folded in — plus the shape/params/batch dimensions
        # the compiled executor closes over
        executor_key = (self.solver.name, sys.m, sys.p, sys.n, str(dtype),
                        sys.structure, sys.mode,
                        tuple(sorted(prm.items())),
                        self.plan.replace(kernel=use_kernel).signature(),
                        self.batch, self.iters)
        self._systems[fp] = _System(sys=sys, prm=prm, dtype=dtype,
                                    executor_key=executor_key,
                                    use_kernel=use_kernel)
        self._queues.setdefault(fp, deque())
        return fp

    def _validated(self, fp: str, rhs) -> Tuple[_System, np.ndarray]:
        """Shared admission validation: the fingerprint must have been
        ``register()``-ed and the RHS must match the system's shape.  The
        KeyError names the FULL fingerprint so operators can grep it
        against their registry."""
        ent = self._systems.get(fp)
        if ent is None:
            raise KeyError(f"unknown system fingerprint {fp!r}; "
                           "register() the system first")
        rhs = np.asarray(rhs, dtype=ent.dtype)
        if rhs.shape != (ent.sys.N,):
            raise ValueError(f"rhs has shape {rhs.shape}, need "
                             f"({ent.sys.N},) for this system")
        return ent, rhs

    def submit(self, fp: str, rhs) -> int:
        """Enqueue one right-hand side for a registered system."""
        _, rhs = self._validated(fp, rhs)
        rid = self._rid
        self._rid += 1
        self._queues[fp].append(Request(rid=rid, fp=fp, rhs=rhs))
        return rid

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ----- executors (compile-once cache) -----------------------------------
    def _executor(self, ent: _System):
        key = ent.executor_key
        ex = self._executors.get(key)
        if ex is None:
            self.stats.executor_builds += 1
            if self.backend == "mesh":
                ex = _MeshExecutor(self.solver, ent.prm, self.iters,
                                   ent.sys, self.mesh, self.worker_axes,
                                   self.model_axis,
                                   use_kernel=ent.use_kernel)
            else:
                ex = _LocalExecutor(self.solver, ent.prm, self.iters,
                                    use_kernel=ent.use_kernel,
                                    ls_mode=ent.sys.mode == "least_squares")
            self._executors[key] = ex
        return ex

    def jit_cache_size(self) -> int:
        """Total jit-cache entries across executors (-1 if the running
        jax cannot report it).  Constant across batches == zero retraces.
        (Snapshots the executor dict so the async pipeline's assembly
        thread can add executors while another thread reads this.)"""
        sizes = [ex.cache_size() for ex in list(self._executors.values())]
        if not sizes:
            return 0
        return -1 if any(s < 0 for s in sizes) else sum(sizes)

    # ----- serving ----------------------------------------------------------
    def _warm_ok(self, ent: _System, Bb: np.ndarray) -> bool:
        if not self.warm_start or ent.last_states is None \
                or ent.last_Bb is None:
            return False
        if np.array_equal(ent.last_Bb, Bb):
            return True                       # repeated RHS: plain resume
        return bool(getattr(self.solver, "warm_rhs_ok", False))

    def step(self):
        """Serve ONE coalesced batch (the oldest pending request's system).

        Returns the list of ``Served`` results for the REAL requests in
        the batch.  With ZERO pending requests this is a true no-op:
        it returns [] before any executor, store, or device work — no
        empty-batch compile, no jit-cache growth, no stats movement.
        """
        # oldest pending request picks the system; coalescing then fills
        # the batch with that system's next requests (which may have
        # arrived later than other systems' — that is the point)
        pending = [(q[0].rid, fp) for fp, q in self._queues.items() if q]
        if not pending:
            return []
        fp = min(pending)[1]
        ent = self._systems[fp]
        group, n_real = take_group(self._queues[fp], self.batch)

        # every factor acquisition goes through the store (hit after the
        # first batch; key precomputed at register() so no re-hash of A;
        # the kernel path augments the cached entry with the pinv factors
        # exactly once — ``kernel_factors`` is idempotent)
        factors = self.store.factors(self.solver, ent.sys, key=fp,
                                     use_kernel=ent.use_kernel,
                                     precision=self.precision, **ent.prm)
        ex = self._executor(ent)
        if ent.placed_src is not factors:     # first batch / post-eviction
            ent.A_placed, ent.factors_placed = ex.place_system(ent.sys,
                                                               factors)
            ent.placed_src = factors

        Bb = np.stack([r.rhs for r in group]).reshape(
            len(group), ent.sys.m, ent.sys.p)
        warm = self._warm_ok(ent, Bb)
        states, X, res = ex.run(ent.A_placed, ent.factors_placed,
                                ex.place_B(Bb),
                                ent.last_states if warm else None)
        ent.last_states, ent.last_Bb = states, Bb

        self.stats.batches += 1
        self.stats.served += n_real
        self.stats.padded += len(group) - n_real
        self.stats.warm_batches += int(warm)
        X = np.asarray(X)
        res = np.asarray(res)
        to_tol = np.atleast_1d(iters_to_tolerance(res, self.tol))
        return [Served(rid=r.rid, fp=fp, x=X[i],
                       residual=float(res[i, -1]),
                       iters_to_tol=int(to_tol[i]), warm=warm)
                for i, r in enumerate(group[:n_real])]

    def drain(self):
        """Serve until every queue is empty; results in served order."""
        out = []
        while True:
            batch = self.step()
            if not batch:
                return out
            out.extend(batch)


class StreamReport(NamedTuple):
    """Outcome of a ``solve_stream`` run."""
    served: list        # Served results, completion order
    batches: int        # coalesced batches executed for this stream
    warm_batches: int   # batches that started from a prior state
    warm_hit_rate: float  # warm_batches / batches (0.0 on an empty stream)


def solve_stream(server, stream, *, drain_every: int = 1) -> StreamReport:
    """Drive a server through an ordered stream of ``(fp, rhs)`` requests.

    The streaming mode of the system layer: clients repeatedly re-solve
    REGISTERED systems under perturbed right-hand sides (sensor updates,
    tracking loops — the serve-traffic scenario).  Requests are submitted
    in order and served every ``drain_every`` submissions, so consecutive
    same-system requests land in the same coalesced batch only when the
    cadence allows it; the report separates warm from cold batches, which
    is the quantity the warm-start gating (``Solver.warm_rhs_ok``) moves.

    Works with both servers: the sync ``LinsysServer`` and the pipelined
    ``AsyncLinsysServer`` (whose ``submit`` may shed under backpressure —
    shed requests simply do not appear in ``served``).
    """
    if drain_every < 1:
        raise ValueError(f"drain_every must be >= 1, got {drain_every}")
    b0, w0 = server.stats.batches, server.stats.warm_batches
    served = []
    for i, (fp, rhs) in enumerate(stream):
        server.submit(fp, rhs)
        if (i + 1) % drain_every == 0:
            served.extend(server.drain())
    served.extend(server.drain())
    batches = server.stats.batches - b0
    warm = server.stats.warm_batches - w0
    return StreamReport(served=served, batches=batches, warm_batches=warm,
                        warm_hit_rate=warm / batches if batches else 0.0)
