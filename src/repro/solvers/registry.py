"""String-keyed solver registry.

    from repro import solvers
    res = solvers.get("apc").solve(sys, iters=500)
    solvers.available()   # ['apc', 'cimmino', 'consensus', 'dgd', ...]

Adding a new solver is a subclass + a decorator:

    @register("mymethod")
    class MySolver(Solver):
        ...
"""
from __future__ import annotations

from typing import Dict, List

from .api import Solver

_REGISTRY: Dict[str, Solver] = {}


def register(name: str):
    """Class decorator: instantiate and register under ``name``."""
    def deco(cls):
        if not issubclass(cls, Solver):
            raise TypeError(f"{cls!r} must subclass solvers.Solver")
        cls.name = name
        _REGISTRY[name] = cls()
        return cls
    return deco


def get(name: str) -> Solver:
    """Look up a registered solver instance by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown solver {name!r}; available: "
                       f"{', '.join(available())}") from None


def available() -> List[str]:
    """Sorted names of every registered solver."""
    return sorted(_REGISTRY)
