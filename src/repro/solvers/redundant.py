"""Redundant, straggler-tolerant execution for the projection family.

The paper's synchronous taskmaster waits for *all* m machines every
iteration — one straggler stalls the fleet.  This backend lowers the same
prepare/init/step lifecycle through an r-redundant cyclic block assignment
in the style of gradient coding [20]: worker i holds blocks
{i, i+1, ..., i+r-1 mod m}, so any iteration can be completed from the
responses of workers whose union of blocks covers {0..m-1}; with
r-redundancy, ANY m - r + 1 workers suffice.

    from repro import solvers
    res = solvers.get("apc").solve(sys, redundancy=2,
                                   alive_schedule=lambda t: mask_t)

``alive_schedule`` may be a callable ``t -> (m,) bool mask``, a static
``(m,)`` or per-iteration ``(iters, m)`` mask array, or a
``runtime.fault.HeartbeatMonitor``.  The whole schedule is lowered to
selection weights ONCE, before the scan launches — a monitor is therefore
a launch-time snapshot (``drop_set()`` queried per iteration index, but
with no solve running in between); drive a long-lived deployment in
warm-started segments to re-sample it.

The master's Eq. (2b) average needs each block's x_j exactly once.  Given
the alive-mask a ∈ {0,1}^m we pick for each block j its lowest-index alive
holder (deterministic, no communication needed — the mask is broadcast with
the heartbeat), expressed as a weight matrix W(a) ∈ {0,1}^{m x r} so the
masked block-unique mean stays a single reduction: locally an einsum inside
one jitted ``lax.scan`` over the precomputed per-iteration weights, on
``backend="mesh"`` the SAME psum over the worker axes that the mesh
contract already uses for the no-failure master update.

Semantics are EXACT, not approximate: an iteration under any covering
alive-mask computes the same x̄(t+1) as a non-redundant iteration over all
m blocks, because each block's update x_j(t+1) only depends on
(x_j(t), x̄(t)) — every replica of block j holds an identical copy of
x_j(t).  (Replicas apply identical deterministic updates from identical
inputs, so they never diverge while alive; a worker that *rejoins* must
refresh its replicas from a live holder — ``HeartbeatMonitor.rejoin``
models that handshake.)  Exactness is also what keeps states GLOBAL-shaped:
the replicated internal state is a pure gather of the plain one, so warm
starts and ``repro.checkpoint`` round-trip freely between redundant/plain
runs and local/mesh backends.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import BlockSystem

from .api import SolveResult, iters_to_tolerance


@dataclasses.dataclass(frozen=True)
class Assignment:
    """Cyclic r-redundant block assignment over m workers."""
    m: int
    r: int

    @property
    def holder(self) -> np.ndarray:
        """(m, r) block id held in slot k of worker i: (i + k) mod m."""
        return (np.arange(self.m)[:, None] + np.arange(self.r)[None, :]) \
            % self.m


class _LocalContext:
    """Degenerate MeshContext twin: the whole fleet is one host, so the
    worker/model psums are identities.  Lets every ``red_*`` solver hook be
    written ONCE against the psum contract and run on both backends."""

    def psum_workers(self, v):
        return v

    def psum_model(self, v):
        return v

    def workers_total(self, m_local: int) -> int:
        return m_local


_LOCAL = _LocalContext()


def schedule_weights(alive: np.ndarray, r: int) -> np.ndarray:
    """Lower a (T, m) alive schedule to (T, m, r) selection-weight masks.

    W[t, i, k] = 1 iff worker i is the designated provider of the block in
    its slot k at iteration t; provider = lowest-index alive holder (ties
    broken by slot), so each block contributes exactly once to the masked
    mean.  Vectorized over T — the whole schedule is precomputed host-side
    and scanned over, nothing per-iteration runs in Python.

    Raises if some block has no alive holder (the fleet lost >= r
    cyclically-adjacent workers); the runtime then falls back to a full
    re-partition (runtime/fault.py).
    """
    alive = np.atleast_2d(np.asarray(alive, dtype=bool))
    T, m = alive.shape
    ks = np.arange(r)
    # block j's slot-k holder is worker (j - k) mod m
    holders = (np.arange(m)[:, None] - ks[None, :]) % m          # (m, r)
    ok = alive[:, holders]                                       # (T, m, r)
    # lexicographic (worker, slot) preference key; +inf-like when dead
    key = np.where(ok, holders * r + ks[None, :], m * r)
    sel = key.argmin(axis=-1)                                    # (T, m)
    covered = np.take_along_axis(ok, sel[..., None], axis=-1)[..., 0]
    if not covered.all():
        t, blk = np.argwhere(~covered)[0]
        raise RuntimeError(
            f"block {blk} unrecoverable at iteration {t}: no alive holder "
            f"(r={r}; lost >= {r} cyclically-adjacent workers)")
    i_sel = (np.arange(m)[None, :] - sel) % m                    # (T, m)
    W = np.zeros((T, m, r))
    W[np.repeat(np.arange(T), m), i_sel.ravel(), sel.ravel()] = 1.0
    return W


def selection_weights(alive: np.ndarray, m: int, r: int) -> np.ndarray:
    """Single-mask form of ``schedule_weights`` (W ∈ {0,1}^{m x r})."""
    alive = np.asarray(alive, dtype=bool).reshape(1, m)
    return schedule_weights(alive, r)[0]


def monitor_schedule(monitor) -> Any:
    """Adapt a ``runtime.fault.HeartbeatMonitor`` into an alive schedule
    excluding its ``drop_set()`` (dead OR straggling workers).  NOTE: the
    schedule is lowered before the scan launches, so this is a launch-time
    snapshot — re-lower (e.g. warm-started solve segments) to track a
    fleet whose health changes mid-run."""
    return lambda t: ~monitor.drop_set()


def resolve_schedule(alive_schedule, m: int, iters: int) -> np.ndarray:
    """Normalize any accepted alive-schedule form to a (iters, m) array."""
    if alive_schedule is None:
        return np.ones((iters, m), dtype=bool)
    from repro.runtime.fault import HeartbeatMonitor
    if isinstance(alive_schedule, HeartbeatMonitor):
        if alive_schedule.n_workers != m:
            raise ValueError(
                f"HeartbeatMonitor tracks {alive_schedule.n_workers} "
                f"workers but the system has m={m} blocks")
        alive_schedule = monitor_schedule(alive_schedule)
    if callable(alive_schedule):
        masks = [np.asarray(alive_schedule(t), dtype=bool)
                 for t in range(iters)]
        alive = np.stack(masks) if masks else np.ones((0, m), bool)
    else:
        alive = np.asarray(alive_schedule, dtype=bool)
        if alive.ndim == 1:
            alive = np.broadcast_to(alive, (iters, m)).copy()
    if alive.shape != (iters, m):
        raise ValueError(f"alive schedule has shape {alive.shape}, "
                         f"need ({iters}, {m})")
    return alive


def replicate_system(sys: BlockSystem, assign: Assignment):
    """(A_rep, b_rep): A_rep[i, k] = A_blocks[(i + k) % m], likewise b."""
    idx = assign.holder
    return (jnp.asarray(sys.A_blocks)[idx], jnp.asarray(sys.b_blocks)[idx])


def _check_solver(solver, sys: BlockSystem, r: int):
    if not getattr(solver, "supports_redundancy", False):
        raise ValueError(
            f"solver {solver.name!r} does not support redundant execution "
            "(projection family only: the coded masked mean needs the "
            "block-local update structure of apc/consensus/cimmino)")
    if sys.is_sparse or sys.mode != "square":
        raise ValueError(
            f"redundant execution is dense-square only: got a "
            f"mode={sys.mode!r}, structure={sys.structure!r} system — the "
            f"replicated (m, r, p, n) factor layout has no sparse variant "
            f"and the straggler theory assumes a consistent system; "
            f"densify()/drop redundancy=r to proceed")
    if not (1 <= r <= sys.m):
        raise ValueError(f"redundancy r={r} must be in [1, m={sys.m}]")


class RedundantEngine:
    """Compile-once, re-enterable segment runner for redundant execution.

    An engine binds the FIXED part of a redundant solve — solver, system
    partition, r, resolved params, backend, mesh placement, replicated
    factors — and compiles the scan ONCE.  Segments then re-enter the
    SAME jitted computation with a new ``(state, W_seq)`` pair: as long
    as shapes match (same partition, same segment length), a membership
    change costs one host-side schedule re-lowering (``lower``) and zero
    retraces.  That is exactly the death path of
    ``solvers.elastic.ElasticRuntime``, which also caches one engine per
    partition signature so a rejoin to a previously-seen fleet size
    reuses the compiled scan too.

    ``solve_redundant`` is a thin wrapper over one engine + one segment,
    so every existing redundant test exercises this code path.
    """

    def __init__(self, solver, sys: BlockSystem, *, r: int,
                 backend: str = "local", mesh: Any = None,
                 worker_axes: Sequence[str] = ("data",),
                 model_axis: Optional[str] = "model",
                 factors: Any = None, **params):
        _check_solver(solver, sys, r)
        self.solver, self.sys = solver, sys
        self.r = int(r)
        self.assign = Assignment(m=sys.m, r=self.r)
        self.backend = backend
        self.prm = solver.resolve_params(sys, **params)
        self.dtype = jnp.asarray(sys.A_blocks).dtype
        self.W_all = jnp.asarray(
            selection_weights(np.ones(sys.m, bool), sys.m, self.r),
            dtype=self.dtype)
        if backend == "mesh":
            from . import mesh as mesh_backend
            self._mesh_runner = mesh_backend.RedundantRunner(
                solver, sys, self.assign, self.prm, mesh=mesh,
                worker_axes=worker_axes, model_axis=model_axis,
                factors=factors)
        else:
            self._mesh_runner = None
            if factors is None:
                factors = solver.prepare(sys.A_blocks, self.prm)
            # strip host-only fields (e.g. kernel pinv factors) before
            # replicating
            self._frep = solver.red_factors(solver.mesh_factors(factors),
                                            self.assign)
            _, self._b_rep = replicate_system(sys, self.assign)
            xt = sys.x_true
            self._xt = () if xt is None else (jnp.asarray(xt),)
            self._run = jax.jit(self._segment)

    def _segment(self, frep, b_rep, A, b, state, W_seq, *rest):
        solver, prm = self.solver, self.prm
        b_norm = jnp.sqrt(jnp.sum(b * b))
        xt = rest[0] if rest else None
        xt_norm = None if xt is None else jnp.linalg.norm(xt)

        def body(st, Wt):
            st = solver.red_step(frep, b_rep, st, prm, Wt, _LOCAL)
            x = solver.extract(st)
            rr = jnp.einsum("mpn,n->mp", A, x) - b
            res = jnp.sqrt(jnp.sum(rr * rr)) / b_norm
            err = (jnp.linalg.norm(x - xt) / xt_norm) if xt is not None \
                else res
            return st, (res, err)

        state, (res, err) = jax.lax.scan(body, state, W_seq)
        return state, res, err

    def lower(self, alive) -> jnp.ndarray:
        """(T, m) alive masks -> (T, m, r) selection weights.  Raises the
        loud ``unrecoverable`` RuntimeError if a block has no alive
        holder — the caller then repartitions or gives up."""
        return jnp.asarray(
            schedule_weights(np.asarray(alive, dtype=bool), self.r),
            dtype=self.dtype)

    def init_state(self, warm_state: Any = None):
        """Fresh ``red_init`` or a replicated expansion of a GLOBAL-shape
        warm state (any backend/redundancy produced it)."""
        if self._mesh_runner is not None:
            return self._mesh_runner.init_state(warm_state, self.W_all)
        if warm_state is None:
            return self.solver.red_init(self._frep, self._b_rep, self.prm,
                                        self.W_all, _LOCAL)
        return self.solver.red_expand(warm_state, self.assign)

    def run(self, state, W_seq):
        """One segment: scan ``red_step`` over ``W_seq`` from ``state``;
        returns ``(state, residuals, errors)``.  Re-entering with a
        same-shaped pair hits the jit cache."""
        if self._mesh_runner is not None:
            return self._mesh_runner.run(state, W_seq)
        return self._run(self._frep, self._b_rep,
                         jnp.asarray(self.sys.A_blocks),
                         jnp.asarray(self.sys.b_blocks), state, W_seq,
                         *self._xt)

    def collapse(self, state):
        """Replicated -> plain GLOBAL-shape state."""
        return self.solver.red_collapse(state, self.assign)

    def cache_size(self) -> int:
        """Total jit-cache entries across the engine's compiled callables
        (-1 when the runtime does not expose cache introspection) — the
        zero-steady-state-retrace benchmarks assert this stays flat."""
        if self._mesh_runner is not None:
            return self._mesh_runner.cache_size()
        return getattr(self._run, "_cache_size", lambda: -1)()


def solve_redundant(solver, sys: BlockSystem, *, r: int, iters: int = 1000,
                    tol: float = 1e-6, alive_schedule=None,
                    warm_state: Any = None, factors: Any = None,
                    backend: str = "local", mesh: Any = None,
                    worker_axes: Sequence[str] = ("data",),
                    model_axis: Optional[str] = "model",
                    **params) -> SolveResult:
    """Shared driver for ``solve(..., redundancy=r, alive_schedule=...)``.

    Lowers the alive schedule to per-iteration selection weights once, then
    runs one ``RedundantEngine`` segment over them — locally or under
    shard_map on ``backend="mesh"``.  The returned ``SolveResult`` carries
    the plain GLOBAL-shape state.
    """
    _check_solver(solver, sys, r)
    alive = resolve_schedule(alive_schedule, sys.m, iters)
    # lower BEFORE the (expensive) engine build so an uncoverable schedule
    # fails loudly without paying for prepare/compile
    W_host = schedule_weights(alive, r)
    engine = RedundantEngine(solver, sys, r=r, backend=backend, mesh=mesh,
                             worker_axes=worker_axes, model_axis=model_axis,
                             factors=factors, **params)
    state = engine.init_state(warm_state)
    state, res, err = engine.run(state,
                                 jnp.asarray(W_host, dtype=engine.dtype))
    state = engine.collapse(state)
    return SolveResult(
        name=solver.name, x=solver.extract(state), state=state,
        residuals=res, errors=err if sys.x_true is not None else None,
        params=engine.prm, iters_to_tol=iters_to_tolerance(res, tol),
        tol=tol)


def _red_mesh_prepare(solver, A_rep, prm, ctx):
    """On-mesh replicated ``prepare``: replicas are just more worker blocks,
    so flatten (m_loc, r) -> m_loc*r, reuse ``mesh_prepare``, and fold the
    slot axis back into every factor leaf."""
    m_loc, r = A_rep.shape[:2]
    flat = solver.mesh_prepare(
        A_rep.reshape((m_loc * r,) + A_rep.shape[2:]), prm, ctx)
    return jax.tree.map(
        lambda f: f.reshape((m_loc, r) + f.shape[1:]), flat)
