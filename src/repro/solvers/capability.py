"""Per-solver capability declarations, checked at dispatch.

Every solver declares the system classes it supports::

    supports = frozenset({"square", "least_squares", "sparse"})

``solve``/``solve_many``/``LinsysServer.register`` call
:func:`check_capability` before any work happens, so a square-only
solver handed a least-squares system raises a :class:`CapabilityError`
naming the solver and the mode instead of silently diverging — the
failure the paper's consistency assumption would otherwise hide.

``use_kernel=True`` on a sparse system dispatches the fused sparse
Pallas pair (compressed-support gather/scatter — see ``kernels/ops``)
silently, exactly like the dense engine: :func:`resolve_use_kernel`
only downgrades the flag — loudly, with a ``RuntimeWarning`` plus a log
line — on the genuinely unsupported cells (a kernel-capable solver in a
mode its kernels do not cover, or a solver with no kernel engine at
all).  ``redundancy=`` + kernel stays a hard ``ValueError`` in
``solve`` (the coded-block path has no kernel layout).
"""
from __future__ import annotations

import logging
import warnings

log = logging.getLogger("repro.solvers")

CAPABILITIES = ("square", "least_squares", "sparse")


class CapabilityError(ValueError):
    """A solver was dispatched on a system class it does not support."""


def required_capabilities(sys) -> set:
    """The capability set a system demands of its solver."""
    need = {sys.mode}
    if sys.is_sparse:
        need.add("sparse")
    return need


def check_capability(solver, sys, *, context: str = "solve") -> None:
    """Raise :class:`CapabilityError` unless ``solver`` declares every
    capability ``sys`` requires (its mode, plus sparsity)."""
    missing = required_capabilities(sys) - set(solver.supports)
    if missing:
        raise CapabilityError(
            f"solver {solver.name!r} does not support "
            f"{sorted(missing)} systems: {context} was called with a "
            f"mode={sys.mode!r}, structure={sys.structure!r} system but "
            f"{solver.name!r} declares supports="
            f"{sorted(solver.supports)}. Pick an LS/sparse-capable solver "
            f"(e.g. 'cimmino' or the gradient family) or densify/square "
            f"the system.")


def resolve_use_kernel(solver, sys, use_kernel: bool) -> bool:
    """Resolve the ``use_kernel`` flag against the solver's kernel engine.

    Sparse systems now dispatch the fused sparse Pallas pair silently on
    kernel-capable solvers (``supports_kernel=True``) — same contract as
    the dense engine.  The only remaining downgrade cell is a solver
    with *no* kernel engine at all handed ``use_kernel=True`` on a
    sparse system; that one warns (``RuntimeWarning`` + log line) and
    falls back to the unfused sparse path.  Returns the flag to
    actually use.
    """
    if (use_kernel and sys.is_sparse
            and not getattr(solver, "supports_kernel", False)):
        msg = (f"use_kernel=True on a sparse system: solver "
               f"{solver.name!r} declares supports_kernel=False (no "
               f"Pallas engine); falling back to the unfused sparse "
               f"path")
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
        log.warning(msg)
        return False
    return use_kernel
