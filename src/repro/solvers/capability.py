"""Per-solver capability declarations, checked at dispatch.

Every solver declares the system classes it supports::

    supports = frozenset({"square", "least_squares", "sparse"})

``solve``/``solve_many``/``LinsysServer.register`` call
:func:`check_capability` before any work happens, so a square-only
solver handed a least-squares system raises a :class:`CapabilityError`
naming the solver and the mode instead of silently diverging — the
failure the paper's consistency assumption would otherwise hide.

``use_kernel=True`` on a sparse system is a *fallback*, not an error:
the fused Pallas engine has no sparse layout yet (ROADMAP item 2), so
:func:`resolve_use_kernel` downgrades the flag LOUDLY (a
``RuntimeWarning`` plus a log line) and the unfused sparse path runs.
"""
from __future__ import annotations

import logging
import warnings

log = logging.getLogger("repro.solvers")

CAPABILITIES = ("square", "least_squares", "sparse")


class CapabilityError(ValueError):
    """A solver was dispatched on a system class it does not support."""


def required_capabilities(sys) -> set:
    """The capability set a system demands of its solver."""
    need = {sys.mode}
    if sys.is_sparse:
        need.add("sparse")
    return need


def check_capability(solver, sys, *, context: str = "solve") -> None:
    """Raise :class:`CapabilityError` unless ``solver`` declares every
    capability ``sys`` requires (its mode, plus sparsity)."""
    missing = required_capabilities(sys) - set(solver.supports)
    if missing:
        raise CapabilityError(
            f"solver {solver.name!r} does not support "
            f"{sorted(missing)} systems: {context} was called with a "
            f"mode={sys.mode!r}, structure={sys.structure!r} system but "
            f"{solver.name!r} declares supports="
            f"{sorted(solver.supports)}. Pick an LS/sparse-capable solver "
            f"(e.g. 'cimmino' or the gradient family) or densify/square "
            f"the system.")


def resolve_use_kernel(solver, sys, use_kernel: bool) -> bool:
    """Downgrade ``use_kernel=True`` on sparse systems — loudly.

    The fused Pallas engine streams dense (p, n) tiles; a sparse layout
    is recorded future work (ROADMAP item 2).  Returns the flag to
    actually use.
    """
    if use_kernel and sys.is_sparse:
        msg = (f"use_kernel=True on a sparse system: solver "
               f"{solver.name!r} has no sparse Pallas kernel yet "
               f"(ROADMAP item 2); falling back to the unfused sparse "
               f"path")
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
        log.warning(msg)
        return False
    return use_kernel
