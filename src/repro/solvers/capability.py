"""Per-solver capability declarations and the ExecutionPlan, both
checked ONCE at dispatch.

Every solver declares the system classes it supports::

    supports = frozenset({"square", "least_squares", "sparse"})

``solve``/``solve_many``/``LinsysServer.register`` call
:func:`check_capability` before any work happens, so a square-only
solver handed a least-squares system raises a :class:`CapabilityError`
naming the solver and the mode instead of silently diverging — the
failure the paper's consistency assumption would otherwise hide.

The execution surface that accreted across PRs (``backend=``, ``mesh=``,
``use_kernel=``, ``redundancy=``, ``alive_schedule=``, ``store=``,
``precision=``, ``warm_state=``, ``factors=``, axis names) is one
validated object now::

    plan = ExecutionPlan(backend="mesh", kernel=True, precision="mixed")
    res = solvers.get("apc").solve(sys, plan=plan, iters=500)

:func:`resolve_plan` performs EVERY dispatch check in one place —
capability, kernel resolution, precision, backend/mesh consistency,
redundancy conflicts — and returns the resolved plan the drivers then
execute without re-validating per branch.  The legacy loose kwargs keep
working through a thin shim in ``Solver.solve``/``solve_many`` that
builds the plan and emits exactly one ``DeprecationWarning`` (lint rule
R009 keeps internal call sites off the shim).

``use_kernel=True`` on a sparse system dispatches the fused sparse
Pallas pair (compressed-support gather/scatter — see ``kernels/ops``)
silently, exactly like the dense engine: :func:`resolve_use_kernel`
only downgrades the flag — loudly, with a ``RuntimeWarning`` plus a log
line — on the genuinely unsupported cells (a kernel-capable solver in a
mode its kernels do not cover, or a solver with no kernel engine at
all).  ``kernel=True`` + ``redundancy=`` is a :class:`CapabilityError`
at plan resolution (the coded-block path has no kernel layout) naming
the solver, the conflicting plan fields, and the supported ways out.
"""
from __future__ import annotations

import dataclasses
import logging
import warnings
from typing import Any, Optional, Tuple

log = logging.getLogger("repro.solvers")

CAPABILITIES = ("square", "least_squares", "sparse")


class CapabilityError(ValueError):
    """A solver was dispatched on a system class it does not support."""


def required_capabilities(sys) -> set:
    """The capability set a system demands of its solver."""
    need = {sys.mode}
    if sys.is_sparse:
        need.add("sparse")
    return need


def check_capability(solver, sys, *, context: str = "solve") -> None:
    """Raise :class:`CapabilityError` unless ``solver`` declares every
    capability ``sys`` requires (its mode, plus sparsity)."""
    missing = required_capabilities(sys) - set(solver.supports)
    if missing:
        raise CapabilityError(
            f"solver {solver.name!r} does not support "
            f"{sorted(missing)} systems: {context} was called with a "
            f"mode={sys.mode!r}, structure={sys.structure!r} system but "
            f"{solver.name!r} declares supports="
            f"{sorted(solver.supports)}. Pick an LS/sparse-capable solver "
            f"(e.g. 'cimmino' or the gradient family) or densify/square "
            f"the system.")


def resolve_use_kernel(solver, sys, use_kernel: bool) -> bool:
    """Resolve the ``use_kernel`` flag against the solver's kernel engine.

    Sparse systems now dispatch the fused sparse Pallas pair silently on
    kernel-capable solvers (``supports_kernel=True``) — same contract as
    the dense engine.  The only remaining downgrade cell is a solver
    with *no* kernel engine at all handed ``use_kernel=True`` on a
    sparse system; that one warns (``RuntimeWarning`` + log line) and
    falls back to the unfused sparse path.  Returns the flag to
    actually use.
    """
    if (use_kernel and sys.is_sparse
            and not getattr(solver, "supports_kernel", False)):
        msg = (f"use_kernel=True on a sparse system: solver "
               f"{solver.name!r} declares supports_kernel=False (no "
               f"Pallas engine); falling back to the unfused sparse "
               f"path")
        warnings.warn(msg, RuntimeWarning, stacklevel=3)
        log.warning(msg)
        return False
    return use_kernel


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The validated execution surface of one solve.

    Dispatch-identity fields (``backend``, ``kernel``, ``precision``,
    ``redundancy``, axis names) decide WHAT compiled program runs and
    together form :meth:`signature`, the hashable key the serving layer
    caches executors under.  Payload fields (``mesh``, ``store``,
    ``warm_state``, ``factors``, ``alive_schedule``) carry run-specific
    objects and stay out of the signature.

    Plans are frozen: derive variants with :meth:`replace` (e.g. the
    elastic runtime swaps ``alive_schedule``/``warm_state`` per segment
    while the dispatch identity — hence the compiled program — is
    unchanged).
    """

    backend: str = "local"
    kernel: bool = False
    precision: str = "default"
    redundancy: int = 1
    worker_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    # payload (unhashable / per-run) fields
    mesh: Any = None
    alive_schedule: Any = None
    store: Any = None
    warm_state: Any = None
    factors: Any = None

    def __post_init__(self):
        object.__setattr__(self, "worker_axes", tuple(self.worker_axes))
        object.__setattr__(self, "kernel", bool(self.kernel))
        if not isinstance(self.redundancy, (int,)) or self.redundancy < 1:
            raise ValueError(
                f"ExecutionPlan.redundancy must be an int >= 1, got "
                f"{self.redundancy!r}")

    def replace(self, **changes) -> "ExecutionPlan":
        """A copy with ``changes`` applied (plans are immutable)."""
        return dataclasses.replace(self, **changes)

    def signature(self) -> tuple:
        """Hashable dispatch identity: what compiled program this plan
        selects.  Payload fields (mesh/store/warm_state/factors and the
        schedule values) are deliberately excluded — only whether a
        schedule exists matters for dispatch."""
        return (self.backend, self.kernel, self.precision,
                int(self.redundancy), self.alive_schedule is not None,
                self.worker_axes, self.model_axis)

    @property
    def is_redundant(self) -> bool:
        return self.redundancy != 1 or self.alive_schedule is not None


def resolve_plan(solver, sys, plan: ExecutionPlan, *,
                 context: str = "solve") -> ExecutionPlan:
    """Validate ``plan`` against ``solver``/``sys`` and resolve it ONCE.

    This is the single dispatch gate: capability check, kernel-flag
    resolution (sparse downgrade), precision check, backend/mesh
    consistency, kernel validity, and the redundancy conflicts all
    happen here — the drivers downstream execute the returned plan
    without re-validating per branch.  Returns the plan with ``kernel``
    resolved to the flag that actually runs.
    """
    check_capability(solver, sys, context=context)
    kernel = resolve_use_kernel(solver, sys, plan.kernel)
    solver._check_precision(plan.precision, kernel)
    if plan.backend == "local":
        if plan.mesh is not None:
            raise ValueError("a mesh was passed but backend is 'local' "
                             "— did you mean backend='mesh'?")
    elif plan.backend != "mesh":
        raise ValueError(f"unknown backend {plan.backend!r}; "
                         "expected 'local' or 'mesh'")
    solver._check_kernel(kernel)
    if plan.is_redundant:
        if context.startswith("solve_many"):
            # fail loudly rather than let the fields run the batch
            # withOUT the straggler tolerance it asked for
            raise ValueError(
                "redundant execution is not supported by solve_many; run "
                "solve(redundancy=..., alive_schedule=...) per right-hand "
                "side, or batch without redundancy")
        if kernel:
            fields = [f"redundancy={plan.redundancy}"]
            if plan.alive_schedule is not None:
                fields.append("alive_schedule=<set>")
            raise CapabilityError(
                f"solver {solver.name!r} cannot run kernel=True "
                f"(use_kernel=True) together with {', '.join(fields)}: "
                f"the coded replicated (m, r, p, n) layout has no Pallas "
                f"kernel. Drop kernel=True to keep the straggler "
                f"tolerance, or drop redundancy=/alive_schedule= to keep "
                f"the fused kernels.")
    return plan if kernel == plan.kernel else plan.replace(kernel=kernel)
