"""Gradient-family solvers: DGD, D-NAG, D-HBM, and preconditioned D-HBM.

Each worker computes its partial gradient g_i = A_i^T (A_i x - b_i); the
master sums them (psum in the distributed runtime, einsum here).  P-DHBM
(paper Sec 6) premultiplies each local block by (A_i A_i^T)^{-1/2} so that
heavy-ball attains the APC rate — the preconditioner S depends only on A,
so it lives in ``prepare``; the transformed RHS S_i b_i is cached in the
state at ``init`` time.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import blockops
from repro.core import spectral
from repro.core.partition import BlockSystem
from repro.core.precond import _inv_sqrt_psd

from .api import Solver
from .registry import register


class GradFactors(NamedTuple):
    A: jnp.ndarray      # (m, p, n) row blocks, or a blockops.SparseBlocks


class PrecondFactors(NamedTuple):
    C: jnp.ndarray      # (m, p, n) preconditioned blocks S_i A_i
    S: jnp.ndarray      # (m, p, p) per-worker (A_i A_i^T)^{-1/2}


def _grad(A, b, x):
    """Full gradient sum_i A_i^T (A_i x - b_i) of (1/2)||Ax-b||^2."""
    return blockops.brmatvec_sum(A, blockops.bmatvec(A, x) - b)


class _GradientSolver(Solver):
    """Shared lifecycle scaffolding for the gradient family.

    Every member is "psum of per-worker partial gradients + a master-side
    momentum update": ``step``/``mesh_step`` compute the gradient of
    (1/2)||Cx-d||^2 over the blocks from ``_blocks``/``_rhs`` and hand it
    to the per-solver ``_update`` — so the single-host and mesh backends
    share the update math verbatim.

    The iteration re-reads b every step, so a prior state warm-starts a
    PERTURBED right-hand side too (``warm_rhs_ok``) — except P-DHBM,
    whose state caches the transformed RHS S b (overridden below).

    The family is gradient descent on (1/2)||Ax-b||^2, whose minimizer IS
    the least-squares solution — inconsistent systems are first-class
    (``supports`` includes "least_squares"), with the plain normal
    equations as the optimality moment.
    """

    warm_rhs_ok = True
    supports = frozenset({"square", "least_squares", "sparse"})

    def prepare(self, A, params):
        return GradFactors(A=A)

    def _blocks(self, factors):
        """The (m, p, n) row blocks the gradient runs over."""
        return factors.A

    def _rhs(self, factors, b, state):
        """The (m, p) right-hand side paired with ``_blocks``."""
        return b

    def _update(self, state, g, params):
        """Master update from the summed gradient g (override per solver)."""
        raise NotImplementedError

    def step(self, factors, b, state, params, *, use_kernel=False):
        g = _grad(self._blocks(factors), self._rhs(factors, b, state),
                  state.x)
        return self._update(state, g, params)

    def _zeros(self, factors):
        A = factors.A if isinstance(factors, GradFactors) else factors.C
        return jnp.zeros(blockops.ncols(A), blockops.block_dtype(A))

    def extract(self, state):
        return state.x

    # ----- least-squares mode ---------------------------------------------
    def ls_moment(self, factors, A, b, x, params, ctx):
        """Normal-equations optimality moment A^T(Ax - b) (psum'd)."""
        r = ctx.psum_model(blockops.bmatvec(A, x)) - b
        return ctx.psum_workers(blockops.brmatvec_sum(A, r))

    def ls_reference(self, sys: BlockSystem) -> jnp.ndarray:
        A, b = sys.dense()
        x, *_ = np.linalg.lstsq(np.asarray(A, np.float64),
                                np.asarray(b, np.float64), rcond=None)
        return jnp.asarray(x, sys.b_blocks.dtype)

    # ----- mesh backend ---------------------------------------------------
    def mesh_factor_specs(self, ctx):
        return GradFactors(A=P(ctx.w, None, ctx.n))

    def mesh_prepare(self, A, params, ctx):
        return GradFactors(A=A)

    def mesh_step(self, factors, b, state, params, ctx):
        A = self._blocks(factors)
        d = self._rhs(factors, b, state)
        Ax = ctx.psum_model(blockops.bmatvec(A, state.x))
        g = ctx.psum_workers(blockops.brmatvec_sum(A, Ax - d))
        return self._update(state, g, params)


class DGDState(NamedTuple):
    x: jnp.ndarray
    t: jnp.ndarray


@register("dgd")
class DGDSolver(_GradientSolver):
    """Distributed gradient descent, Eq. (8)."""

    paper_name = "DGD"
    param_names = ("alpha",)

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        alpha, rho = spectral.dgd_optimal(*spectral.ata_extremes(sys))
        return {"alpha": alpha}, rho

    def init(self, factors, b, params):
        return DGDState(x=self._zeros(factors), t=jnp.zeros((), jnp.int32))

    def _update(self, state, g, params):
        return DGDState(x=state.x - params["alpha"] * g, t=state.t + 1)

    def mesh_state_specs(self, ctx):
        return DGDState(x=P(ctx.n), t=P())


class DNAGState(NamedTuple):
    x: jnp.ndarray
    y_prev: jnp.ndarray
    t: jnp.ndarray


@register("dnag")
class DNAGSolver(_GradientSolver):
    """Distributed Nesterov accelerated gradient, Eq. (10)."""

    paper_name = "D-NAG"
    param_names = ("alpha", "beta")

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        a, b_, rho = spectral.dnag_optimal(*spectral.ata_extremes(sys))
        return {"alpha": a, "beta": b_}, rho

    def init(self, factors, b, params):
        z = self._zeros(factors)
        return DNAGState(x=z, y_prev=z, t=jnp.zeros((), jnp.int32))

    def _update(self, state, g, params):
        alpha, beta = params["alpha"], params["beta"]
        y = state.x - alpha * g
        return DNAGState(x=(1.0 + beta) * y - beta * state.y_prev, y_prev=y,
                         t=state.t + 1)

    def mesh_state_specs(self, ctx):
        return DNAGState(x=P(ctx.n), y_prev=P(ctx.n), t=P())


class DHBMState(NamedTuple):
    x: jnp.ndarray
    z: jnp.ndarray
    t: jnp.ndarray


@register("dhbm")
class DHBMSolver(_GradientSolver):
    """Distributed heavy-ball method, Eq. (12)."""

    paper_name = "D-HBM"
    param_names = ("alpha", "beta")

    def default_params(self, sys: BlockSystem):
        return self.analyze(sys)[0]

    def theoretical_rate(self, sys: BlockSystem):
        return self.analyze(sys)[1]

    def analyze(self, sys: BlockSystem):
        a, b_, rho = spectral.dhbm_optimal(*spectral.ata_extremes(sys))
        return {"alpha": a, "beta": b_}, rho

    def init(self, factors, b, params):
        z = self._zeros(factors)
        return DHBMState(x=z, z=z, t=jnp.zeros((), jnp.int32))

    def _update(self, state, g, params):
        z_new = params["beta"] * state.z + g
        return DHBMState(x=state.x - params["alpha"] * z_new, z=z_new,
                         t=state.t + 1)

    def mesh_state_specs(self, ctx):
        return DHBMState(x=P(ctx.n), z=P(ctx.n), t=P())


class PDHBMState(NamedTuple):
    x: jnp.ndarray
    z: jnp.ndarray
    t: jnp.ndarray
    d: jnp.ndarray      # (m, p) cached preconditioned RHS S_i b_i


@register("pdhbm")
class PDHBMSolver(DHBMSolver):
    """D-HBM on the Sec-6 preconditioned system — matches the APC rate.

    C^T C = m X exactly, so the optimal (alpha, beta) come from the
    spectrum of X scaled by m, with no eigensolve on C itself.
    """

    paper_name = "P-DHBM"
    param_names = ("alpha", "beta")
    warm_rhs_ok = False     # state caches S b — stale under a new RHS
    # the numpy eigensolve in prepare() and the cached S b both assume the
    # dense square setting of Sec 6 — keep the original contract
    supports = frozenset({"square"})

    def analyze(self, sys: BlockSystem):
        X = spectral.x_matrix(sys)
        mu_min, mu_max = spectral.mu_extremes(X)
        a, b_, rho = spectral.dhbm_optimal(sys.m * mu_min, sys.m * mu_max)
        return {"alpha": a, "beta": b_}, rho

    def prepare(self, A, params):
        A64 = np.asarray(A, dtype=np.float64)
        S = np.stack([_inv_sqrt_psd(Ai @ Ai.T) for Ai in A64])
        C = np.einsum("mpq,mqn->mpn", S, A64)
        dt = A.dtype
        return PrecondFactors(C=jnp.asarray(C, dt), S=jnp.asarray(S, dt))

    def init(self, factors, b, params):
        z = self._zeros(factors)
        return PDHBMState(x=z, z=z, t=jnp.zeros((), jnp.int32),
                          d=jnp.einsum("mpq,mq->mp", factors.S, b))

    def _blocks(self, factors):
        return factors.C

    def _rhs(self, factors, b, state):
        return state.d

    def _update(self, state, g, params):
        z_new = params["beta"] * state.z + g
        return PDHBMState(x=state.x - params["alpha"] * z_new, z=z_new,
                          t=state.t + 1, d=state.d)

    def mesh_factor_specs(self, ctx):
        return PrecondFactors(C=P(ctx.w, None, ctx.n), S=P(ctx.w, None, None))

    def mesh_prepare(self, A, params, ctx):
        # On-mesh (A_i A_i^T)^{-1/2}: the Gram is a psum over column shards,
        # the p x p inverse square root an eigh on every worker's shard.
        # Eigenvalues are clamped like core/precond._inv_sqrt_psd so a
        # rank-deficient block yields a huge-but-finite preconditioner
        # instead of NaN (eigh can return ~0/slightly-negative values);
        # precision follows the running dtype — enable x64 for
        # ill-conditioned blocks, where cond(G) = cond(A_i)^2.
        G = ctx.psum_model(jnp.einsum("mpn,mqn->mpq", A, A))
        w, V = jnp.linalg.eigh(G)
        w = jnp.maximum(w, jnp.finfo(w.dtype).tiny)
        S = jnp.einsum("mpq,mq,mrq->mpr", V, 1.0 / jnp.sqrt(w), V)
        return PrecondFactors(C=jnp.einsum("mpq,mqn->mpn", S, A), S=S)

    def mesh_state_specs(self, ctx):
        return PDHBMState(x=P(ctx.n), z=P(ctx.n), t=P(), d=P(ctx.w, None))
