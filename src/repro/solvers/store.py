"""Content-addressed factor store: cached one-time factorizations.

The paper's cost split — an expensive b-INDEPENDENT ``prepare`` (Gram
Cholesky factors, preconditioners) followed by cheap per-RHS iterations —
is exactly what serve traffic amortizes.  This module makes that explicit:
``FactorStore`` is the ONE way any driver, benchmark, or example obtains
factors.

    store = FactorStore(capacity=8, directory="/ckpt/factors")
    factors = store.factors(solvers.get("apc"), sys, **params)
    store.stats            # hits / disk_hits / misses / evictions / ...

Systems are fingerprinted by a sha256 over the A-blocks' CONTENT, the
partition (m, p, n), the dtype, the solver name, and the resolved
parameters — so a hit is bit-equivalent to re-running ``prepare``, never a
lookup on an object identity that might alias a different system.

Two tiers:

  * memory — an LRU of device factors (capacity entries, per-store);
  * disk (optional) — every miss is persisted using the checkpoint
    layout from ``repro.checkpoint.ckpt`` (tmp dir -> leaf_*.npy +
    manifest.json + COMMIT marker -> atomic ``os.replace``), so
    factorizations survive restarts and a cold process warm-starts from
    disk.  The manifest is validated on load (solver / partition / dtype /
    leaf shapes) and drift fails LOUDLY — a silently-cast factor makes a
    resumed solve diverge from the uninterrupted one.

Factors obtained here round-trip both backends: the mesh path accepts
host factors (``Solver.mesh_factors`` strips host-only fields before
placement) and the redundant layer replicates them itself.

Kernel path: ``factors(..., use_kernel=True)`` augments the cached entry
with the pinv precomputation ONCE (``Solver.kernel_factors`` is
idempotent — it detects already-augmented factors) and writes the
augmented factors back into the cache slot, so repeated kernel solves on
a hit never re-run the augmentation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import importlib
import json
import logging
import os
import shutil
from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import COMMIT
from repro.core.partition import BlockSystem

log = logging.getLogger("repro.solvers.store")


def fingerprint(solver_name: str, sys: BlockSystem,
                params: Dict[str, Any], precision: str = "default") -> str:
    """Content hash identifying (A-blocks, partition, solver, params).

    Everything ``prepare`` can depend on is in the digest; b is NOT — the
    factorization is b-independent by the lifecycle contract, so one entry
    serves every right-hand side of the same system.

    Sparse systems additionally hash their structure tag and column
    support: ``prepare`` consumes the compressed ``sys.A_op`` operand
    there, so a sparse system and its densified twin hold the SAME values
    but different factor pytrees — they must never share a slot.  Dense
    digests are byte-identical to what they always were.

    A non-default ``precision`` (mixed bf16 tile streams) enters the
    digest too — a cast entry must never serve a full-precision request —
    while ``precision="default"`` adds NOTHING, keeping every existing
    fingerprint byte-stable.
    """
    A = np.asarray(jax.device_get(sys.A_blocks))
    h = hashlib.sha256()
    h.update(f"solver={solver_name}".encode())
    h.update(f"partition={tuple(A.shape)}".encode())
    h.update(f"dtype={A.dtype}".encode())
    for k in sorted(params):
        try:
            # normalize numeric types: 1.3, np.float64(1.3) and a jax
            # scalar must hash identically or cross-call-path lookups
            # (auto-tuned vs hand-passed params) silently always miss
            v = repr(float(params[k]))
        except (TypeError, ValueError):
            v = repr(params[k])
        h.update(f"param:{k}={v}".encode())
    h.update(np.ascontiguousarray(A).tobytes())
    if sys.is_sparse:
        cols = np.asarray(jax.device_get(sys.cols))
        h.update(b"structure=sparse")
        h.update(f"support={tuple(cols.shape)}".encode())
        h.update(np.ascontiguousarray(cols).tobytes())
    if precision != "default":
        h.update(f"precision={precision}".encode())
    return h.hexdigest()


@dataclasses.dataclass
class StoreStats:
    """Running counters; ``hits``/``disk_hits`` vs ``misses`` is the
    serve-traffic amortization the benchmarks report."""
    hits: int = 0           # in-memory LRU hits
    disk_hits: int = 0      # restored from the disk tier
    misses: int = 0         # full ``prepare`` re-runs
    evictions: int = 0      # LRU drops (memory tier only)
    disk_writes: int = 0    # entries persisted
    resume_misses: int = 0  # misses during a warm-start resume (visible
                            # cost that used to be silent — see api.solve)
    block_hits: int = 0     # per-block reuses (``blockwise_factors``)
    block_misses: int = 0   # per-block refactorizations

    @property
    def total_hits(self) -> int:
        return self.hits + self.disk_hits


class BlockReuse(NamedTuple):
    """What a ``blockwise_factors`` assembly reused vs refactorized —
    the number the elastic runtime reports after a repartition."""
    reused: int
    prepared: int


def block_fingerprint(solver_name: str, A_block: np.ndarray,
                      params: Dict[str, Any],
                      precision: str = "default") -> str:
    """Content hash of ONE row block's factorization inputs.

    Mirrors :func:`fingerprint` at block granularity: solver, the block's
    partition slice shape (p, n), dtype, resolved params, the block's
    bytes, and a non-default precision.  Two partitions that happen to
    cut identical (content, shape) blocks therefore share entries — that
    is the point: a worker rejoining a previously-seen partition reuses
    every unchanged block's factors instead of re-preparing them.
    """
    A_block = np.asarray(A_block)
    h = hashlib.sha256()
    h.update(f"block-solver={solver_name}".encode())
    h.update(f"slice={tuple(A_block.shape)}".encode())
    h.update(f"dtype={A_block.dtype}".encode())
    for k in sorted(params):
        try:
            v = repr(float(params[k]))
        except (TypeError, ValueError):
            v = repr(params[k])
        h.update(f"param:{k}={v}".encode())
    h.update(np.ascontiguousarray(A_block).tobytes())
    if precision != "default":
        h.update(f"precision={precision}".encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Pytree (de)serialization for the disk tier.  Factor pytrees are
# NamedTuples / tuples / dicts of arrays with optional None fields; the
# structure is recorded in the manifest so a COLD process can restore an
# entry without re-running ``prepare`` to obtain a template.
# ---------------------------------------------------------------------------


def _encode(node: Any, leaves: list) -> Any:
    if node is None:
        return {"kind": "none"}
    if hasattr(node, "_fields"):                       # NamedTuple
        cls = type(node)
        return {"kind": "namedtuple",
                "cls": f"{cls.__module__}:{cls.__qualname__}",
                "fields": [[f, _encode(getattr(node, f), leaves)]
                           for f in node._fields]}
    if isinstance(node, dict):
        return {"kind": "dict",
                "items": [[k, _encode(v, leaves)]
                          for k, v in sorted(node.items())]}
    if isinstance(node, (list, tuple)):
        return {"kind": "list" if isinstance(node, list) else "tuple",
                "items": [_encode(v, leaves) for v in node]}
    leaves.append(np.asarray(jax.device_get(node)))
    return {"kind": "leaf", "index": len(leaves) - 1}


def _decode(spec: Any, leaves: list) -> Any:
    kind = spec["kind"]
    if kind == "none":
        return None
    if kind == "leaf":
        return jnp.asarray(leaves[spec["index"]])
    if kind == "namedtuple":
        mod, qual = spec["cls"].split(":")
        cls: Any = importlib.import_module(mod)
        for part in qual.split("."):
            cls = getattr(cls, part)
        return cls(**{f: _decode(s, leaves) for f, s in spec["fields"]})
    if kind == "dict":
        return {k: _decode(s, leaves) for k, s in spec["items"]}
    if kind in ("list", "tuple"):
        items = [_decode(s, leaves) for s in spec["items"]]
        return items if kind == "list" else tuple(items)
    raise ValueError(f"unknown factor-structure node kind {kind!r}")


class FactorStore:
    """Content-addressed cache of b-independent solver factorizations.

    ``factors(solver, sys, **params)`` is the one entry point; drivers
    pass the store down via ``Solver.solve(..., store=...)``.
    """

    def __init__(self, capacity: int = 8,
                 directory: Optional[str] = None,
                 block_capacity: int = 256) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if block_capacity < 1:
            raise ValueError(
                f"block_capacity must be >= 1, got {block_capacity}")
        self.capacity = capacity
        self.block_capacity = block_capacity
        self.directory = directory
        self.stats = StoreStats()
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        self._block_mem: "OrderedDict[str, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem

    def clear(self) -> None:
        """Drop the memory tier (the disk tier, if any, is untouched)."""
        self._mem.clear()

    # ----- keys ------------------------------------------------------------
    @staticmethod
    def _as_solver(solver):
        if isinstance(solver, str):
            from .registry import get
            return get(solver)
        return solver

    def key(self, solver, sys: BlockSystem, *, precision: str = "default",
            **params) -> str:
        """The content-addressed key a ``factors`` call would use."""
        solver = self._as_solver(solver)
        prm = solver.resolve_params(sys, **params)
        return fingerprint(solver.name, sys, prm, precision)

    # ----- the one way to obtain factors ------------------------------------
    def factors(self, solver, sys: BlockSystem, *, use_kernel: bool = False,
                resume: bool = False, key: Optional[str] = None,
                precision: str = "default", **params):
        """Cached ``solver.prepare(sys.A_op, params)``.

        Lookup order: memory LRU -> disk tier -> full ``prepare`` (counted
        as a miss; persisted when a ``directory`` is configured).  Pass a
        precomputed ``key`` (from ``self.key``) to skip re-hashing A on
        hot serving paths.  ``resume=True`` marks the call as part of a
        warm-start resume so a miss there is counted separately — resume
        cost should be visible, not silent.

        ``precision="mixed"`` entries live under their OWN fingerprint and
        cache the already-cast factors (prepare and the pinv augmentation
        still run in full precision on a miss; the cast happens last).
        """
        solver = self._as_solver(solver)
        prm = solver.resolve_params(sys, **params)
        if key is None:
            key = fingerprint(solver.name, sys, prm, precision)
        factors = self.lookup(solver, sys, key=key, use_kernel=use_kernel,
                              precision=precision, **prm)
        if factors is None:
            factors = self.insert(solver, sys,
                                  solver.prepare(sys.A_op, prm),
                                  resume=resume, key=key,
                                  use_kernel=use_kernel,
                                  precision=precision, **prm)
        return factors

    def _augment(self, solver, key: str, factors):
        """Kernel-path augmentation, ONCE per cache slot: later hits get
        the augmented factors back and ``kernel_factors`` detects them
        (idempotent), so the pinv precomputation never re-runs."""
        augmented = solver.kernel_factors(factors)
        if augmented is not factors and key in self._mem:
            self._mem[key] = augmented
        return augmented

    def lookup(self, solver, sys: BlockSystem, *,
               key: Optional[str] = None, use_kernel: bool = False,
               precision: str = "default", **params):
        """Memory/disk lookup that does NOT prepare on a miss (returns
        None instead).  Backends whose factorization should not run on
        the host (the mesh backend prepares on-mesh under shard_map) use
        this + ``insert`` so a miss is repaid THEIR way while hits and
        persistence still flow through the store.  ``use_kernel=True``
        augments a hit with the pinv factors and writes the augmentation
        back into the slot — the same once-per-entry contract as
        ``factors`` — so the mesh-side split gets it too."""
        solver = self._as_solver(solver)
        if key is None:
            prm = solver.resolve_params(sys, **params)
            key = fingerprint(solver.name, sys, prm, precision)
        factors = self._mem.get(key)
        if factors is not None:
            self._mem.move_to_end(key)
            self.stats.hits += 1
            return self._augment(solver, key, factors) if use_kernel \
                else factors
        factors = self._disk_load(key, solver, sys)
        if factors is not None:
            self.stats.disk_hits += 1
            self._insert(key, factors)
            return self._augment(solver, key, factors) if use_kernel \
                else factors
        return None

    def insert(self, solver, sys: BlockSystem, factors, *,
               resume: bool = False, key: Optional[str] = None,
               use_kernel: bool = False, precision: str = "default",
               **params):
        """Record a caller-prepared factorization: counts the miss the
        caller just repaid, persists to the disk tier, and caches it.
        ``use_kernel=True`` ensures the cached entry carries the pinv
        augmentation (a no-op when the caller's prepare — e.g. the
        on-mesh kernel ``mesh_prepare`` — already computed it); a
        non-default ``precision`` casts the tile streams LAST, so the
        cached entry is the cast one (``cast_factors`` is idempotent)."""
        solver = self._as_solver(solver)
        prm = solver.resolve_params(sys, **params)
        if key is None:
            key = fingerprint(solver.name, sys, prm, precision)
        if use_kernel:
            factors = solver.kernel_factors(factors)
        if precision != "default":
            factors = solver.cast_factors(factors, precision)
        self.stats.misses += 1
        if resume:
            self.stats.resume_misses += 1
            log.warning(
                "factor-store MISS during warm-start resume: re-running "
                "the full b-independent prepare for solver %r (configure "
                "a disk tier to amortize resumes across processes)",
                solver.name)
        self._disk_store(key, solver, sys, prm, factors)
        self._insert(key, factors)
        return factors

    def _insert(self, key: str, factors: Any) -> None:
        self._mem[key] = factors
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    # ----- block tier (per-block reuse across repartitions) -----------------
    # A repartition (elastic join/rejoin) changes the system fingerprint,
    # so the whole-system tiers above always miss — but any block whose
    # (content, slice shape, dtype, solver, params) is unchanged has the
    # SAME factorization.  ``blockwise_factors`` assembles the full factor
    # pytree from cached per-block slices plus ONE stacked ``prepare``
    # over the missing blocks, and reports reuse vs refactorization.
    # Valid only for solvers whose ``prepare`` is per-block independent
    # and whose factor leaves all carry a leading worker axis
    # (``supports_block_store`` — the projection family).

    def blockwise_factors(self, solver, sys: BlockSystem, *,
                          use_kernel: bool = False,
                          precision: str = "default", **params):
        """``(factors, BlockReuse)`` for ``sys`` with per-block caching.

        Counts one ``block_hit`` per reused block and one ``block_miss``
        per refactorized one; the missing blocks are prepared in ONE
        stacked ``solver.prepare`` call (they are just fewer worker
        blocks).  The assembled full-system entry is also written to the
        whole-system tiers, so later same-partition solves hit there.
        """
        solver = self._as_solver(solver)
        if not getattr(solver, "supports_block_store", False):
            raise ValueError(
                f"solver {solver.name!r} does not declare a per-block-"
                f"independent prepare (supports_block_store=False); "
                f"blockwise reuse would assemble wrong factors")
        if sys.is_sparse:
            raise ValueError(
                "blockwise factor reuse is dense-only: sparse operands "
                "carry a shared column support that a per-block cache "
                "cannot slice; densify() or use the whole-system tiers")
        prm = solver.resolve_params(sys, **params)
        A = np.asarray(jax.device_get(sys.A_blocks))
        keys = [block_fingerprint(solver.name, A[i], prm, precision)
                for i in range(sys.m)]
        blocks: Dict[int, Any] = {}
        for i, bk in enumerate(keys):
            blk = self._block_lookup(bk)
            if blk is not None:
                blocks[i] = blk
        missing = [i for i in range(sys.m) if i not in blocks]
        self.stats.block_hits += sys.m - len(missing)
        self.stats.block_misses += len(missing)
        if missing:
            sub = solver.prepare(jnp.asarray(A[np.array(missing)]), prm)
            for j, i in enumerate(missing):
                blk = jax.tree.map(lambda leaf: leaf[j], sub)
                self._block_insert(keys[i], solver, prm, blk)
                blocks[i] = blk
        factors = jax.tree.map(
            lambda *leaves: jnp.stack(leaves, axis=0),
            *[blocks[i] for i in range(sys.m)])
        reuse = BlockReuse(reused=sys.m - len(missing),
                           prepared=len(missing))
        # seed the whole-system tiers so same-partition callers hit there
        # (NOT through ``insert`` — an assembly is neither a system-level
        # hit nor a miss; only the per-block counters moved).  Transforms
        # apply to the RETURNED factors on every path, seeded or not.
        sys_key = fingerprint(solver.name, sys, prm, precision)
        if use_kernel:
            factors = (self._augment(solver, sys_key, factors)
                       if sys_key in self._mem
                       else solver.kernel_factors(factors))
        if precision != "default":
            factors = solver.cast_factors(factors, precision)
        if sys_key not in self._mem:
            self._disk_store(sys_key, solver, sys, prm, factors)
            self._insert(sys_key, factors)
        return factors, reuse

    def _block_lookup(self, key: str):
        blk = self._block_mem.get(key)
        if blk is not None:
            self._block_mem.move_to_end(key)
            return blk
        blk = self._block_disk_load(key)
        if blk is not None:
            self._block_mem[key] = blk
            self._trim_blocks()
        return blk

    def _block_insert(self, key: str, solver, prm: Dict[str, Any],
                      blk: Any) -> None:
        self._block_mem[key] = blk
        self._block_mem.move_to_end(key)
        self._trim_blocks()
        self._block_disk_store(key, solver, prm, blk)

    def _trim_blocks(self) -> None:
        while len(self._block_mem) > self.block_capacity:
            self._block_mem.popitem(last=False)
            self.stats.evictions += 1

    def _block_dir(self, key: str) -> str:
        return os.path.join(self.directory, "blocks", key)

    def _block_disk_store(self, key: str, solver, prm: Dict[str, Any],
                          blk: Any) -> None:
        if self.directory is None:
            return
        root = os.path.join(self.directory, "blocks")
        os.makedirs(root, exist_ok=True)
        tmp = os.path.join(root, f"tmp.{key}")
        final = self._block_dir(key)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves: list = []
        structure = _encode(blk, leaves)
        manifest = {
            "key": key,
            "solver": solver.name,
            "params": {k: float(v) for k, v in prm.items()},
            "structure": structure,
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in leaves],
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self.stats.disk_writes += 1

    def _block_disk_load(self, key: str) -> Any:
        if self.directory is None:
            return None
        path = self._block_dir(key)
        if not os.path.exists(os.path.join(path, COMMIT)):
            return None
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = []
        for i, ref in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if list(arr.shape) != list(ref["shape"]) \
                    or str(arr.dtype) != ref["dtype"]:
                raise ValueError(
                    f"factor-store block entry corrupt at {path}: leaf "
                    f"{i} is {arr.shape}/{arr.dtype}, manifest says "
                    f"{ref['shape']}/{ref['dtype']}")
            leaves.append(arr)
        return _decode(manifest["structure"], leaves)

    # ----- disk tier --------------------------------------------------------
    def _entry_dir(self, key: str) -> str:
        return os.path.join(self.directory, key)

    def _disk_store(self, key: str, solver, sys: BlockSystem,
                    prm: Dict[str, Any], factors: Any) -> None:
        if self.directory is None:
            return
        os.makedirs(self.directory, exist_ok=True)
        tmp = os.path.join(self.directory, f"tmp.{key}")
        final = self._entry_dir(key)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves: list = []
        structure = _encode(factors, leaves)
        manifest = {
            "key": key,
            "solver": solver.name,
            "partition": [sys.m, sys.p, sys.n],
            "system_structure": sys.structure,
            "dtype": str(np.asarray(sys.A_blocks).dtype),
            "params": {k: float(v) for k, v in prm.items()},
            "structure": structure,
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype)}
                       for l in leaves],
        }
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self.stats.disk_writes += 1

    def _disk_load(self, key: str, solver, sys: BlockSystem) -> Any:
        """Restore a committed entry, failing LOUDLY on manifest drift."""
        if self.directory is None:
            return None
        path = self._entry_dir(key)
        if not os.path.exists(os.path.join(path, COMMIT)):
            return None
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        want_part = [sys.m, sys.p, sys.n]
        want_dtype = str(np.asarray(sys.A_blocks).dtype)
        if manifest.get("solver") != solver.name:
            raise ValueError(
                f"factor-store manifest drift at {path}: entry was written "
                f"by solver {manifest.get('solver')!r}, requested "
                f"{solver.name!r}")
        if manifest.get("system_structure", "dense") != sys.structure:
            raise ValueError(
                f"factor-store manifest drift at {path}: entry holds "
                f"{manifest.get('system_structure', 'dense')!r} factors, "
                f"requested {sys.structure!r} — the fingerprint should "
                f"have separated these; entry may be corrupt")
        if list(manifest.get("partition", [])) != want_part:
            raise ValueError(
                f"factor-store manifest drift at {path}: partition "
                f"{manifest.get('partition')} != running {want_part} — was "
                f"the system re-partitioned since the entry was written?")
        if manifest.get("dtype") != want_dtype:
            raise ValueError(
                f"factor-store manifest drift at {path}: dtype "
                f"{manifest.get('dtype')} != running {want_dtype} — was the "
                f"x64 flag changed since the entry was written?")
        leaves = []
        for i, ref in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if list(arr.shape) != list(ref["shape"]) \
                    or str(arr.dtype) != ref["dtype"]:
                raise ValueError(
                    f"factor-store entry corrupt at {path}: leaf {i} is "
                    f"{arr.shape}/{arr.dtype}, manifest says "
                    f"{ref['shape']}/{ref['dtype']}")
            leaves.append(arr)
        return _decode(manifest["structure"], leaves)
