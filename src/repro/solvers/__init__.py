"""repro.solvers — the unified distributed-solver API.

One lifecycle (prepare/init/step), one registry, one result type for every
solver in the paper's comparison:

    from repro import solvers
    res = solvers.get("apc").solve(sys, iters=500)      # -> SolveResult
    solvers.available()
    # ['apc', 'cimmino', 'consensus', 'dgd', 'dhbm', 'dnag', 'madmm', 'pdhbm']

Batched serving (one factorization, many right-hand sides):

    res = solvers.get("apc").solve_many(sys, B)          # B: (k, N)

Execution surface: everything beyond iters/tol/params travels on ONE
validated ``ExecutionPlan`` (backend, mesh, kernel, precision,
redundancy, store, warm_state, ...), resolved once at dispatch.  The
old loose kwargs (``backend=``, ``use_kernel=``, ...) still work via a
shim but are deprecated (one ``DeprecationWarning`` per call; lint rule
R009 keeps internal call sites off them):

    from repro.solvers import ExecutionPlan
    res = solvers.get("apc").solve(
        sys, plan=ExecutionPlan(backend="mesh", kernel=True), iters=500)

Warm starts / resume (feeds repro.checkpoint.ckpt):

    r1 = solvers.get("apc").solve(sys, iters=100)
    r2 = solvers.get("apc").solve(
        sys, iters=100, plan=ExecutionPlan(warm_state=r1.state))

Straggler-tolerant redundant execution (projection family, both backends):

    res = solvers.get("apc").solve(
        sys, plan=ExecutionPlan(redundancy=2,
                                alive_schedule=lambda t: mask_t))

Elastic fleet execution (membership changes mid-solve — deaths re-lower
the redundant schedule over the survivors, joins/rejoins repartition and
warm-start with per-block factor reuse, taskmaster loss recovers from
the store's disk tier):

    from repro.runtime.fault import HeartbeatMonitor
    rt = solvers.ElasticRuntime(solvers.get("apc"), sys,
                                plan=ExecutionPlan(redundancy=2),
                                monitor=HeartbeatMonitor(n_workers=sys.m))
    rt.monitor.mark_dead(2)          # death -> re-lower, keep iterating
    rep = rt.run(iters=600)          # rep.reused_blocks / rep.events

Cached factorizations + request serving (the serve-traffic hot path):

    store = solvers.FactorStore(directory="/ckpt/factors")
    res = solvers.get("apc").solve(
        sys, plan=ExecutionPlan(store=store))            # hit after 1st
    srv = solvers.LinsysServer(store, solver="apc", batch=4)

Async pipelined serving (overlapped admission/assembly/execution, per-
request futures, SLO latency report):

    asrv = solvers.AsyncLinsysServer(store, solver="apc", batch=4,
                                     pipeline_depth=2)
    with asrv:
        tickets = [asrv.submit(fp, b) for b in stream]

System modes (dense square / least-squares / block-sparse) flow through
every entry point above; each solver declares ``supports`` and a request
outside it raises ``CapabilityError`` at dispatch:

    ls  = linsys.tall_gaussian(1000, 500, 4, noise=0.01)   # inconsistent LS
    res = solvers.get("dgd").solve(ls, iters=2000)          # optimality res.
    sp  = linsys.banded_system(768, 4, bandwidth=9)         # already sparse
    res = solvers.get("apc").solve(sp, iters=300)           # sparse blockops

Streaming perturbed right-hand sides through a server (warm-start gating):

    rep = solvers.solve_stream(srv, [(fp, b0), (fp, b1), ...])
    rep.warm_hit_rate   # 1.0 for warm_rhs_ok solvers after the first batch

See ``api.Solver`` for the protocol, ``registry.register`` for adding a
new method, ``mesh`` for the sharded backend, ``redundant`` for the
r-redundant straggler-tolerant layer, ``store`` for the content-addressed
factor cache, ``serve`` for the linear-system request server, and
``pipeline`` for its async pipelined twin.
"""
from .api import Solver, SolveResult, iters_to_tolerance  # noqa: F401
from .capability import (CapabilityError, ExecutionPlan,  # noqa: F401
                         resolve_plan)
from .registry import available, get, register  # noqa: F401

# Importing the implementation modules populates the registry.
from . import admm, gradient, projection  # noqa: F401, E402
from . import mesh  # noqa: F401, E402  (the shard_map execution backend)
from . import redundant  # noqa: F401, E402  (straggler-tolerant layer)
from .store import BlockReuse, FactorStore, fingerprint  # noqa: F401, E402
from .serve import LinsysServer, StreamReport, solve_stream  # noqa: F401, E402
from .pipeline import AsyncLinsysServer, Shed, Ticket  # noqa: F401, E402
from .elastic import ElasticReport, ElasticRuntime  # noqa: F401, E402
