"""repro.solvers — the unified distributed-solver API.

One lifecycle (prepare/init/step), one registry, one result type for every
solver in the paper's comparison:

    from repro import solvers
    res = solvers.get("apc").solve(sys, iters=500)      # -> SolveResult
    solvers.available()
    # ['apc', 'cimmino', 'consensus', 'dgd', 'dhbm', 'dnag', 'madmm', 'pdhbm']

Batched serving (one factorization, many right-hand sides):

    res = solvers.get("apc").solve_many(sys, B)          # B: (k, N)

Warm starts / resume (feeds repro.checkpoint.ckpt):

    r1 = solvers.get("apc").solve(sys, iters=100)
    r2 = solvers.get("apc").solve(sys, iters=100, warm_state=r1.state)

Mesh execution (shard_map over a device mesh, any registered solver):

    res = solvers.get("apc").solve(sys, backend="mesh", mesh=mesh)

Straggler-tolerant redundant execution (projection family, both backends):

    res = solvers.get("apc").solve(sys, redundancy=2,
                                   alive_schedule=lambda t: mask_t)

Cached factorizations + request serving (the serve-traffic hot path):

    store = solvers.FactorStore(directory="/ckpt/factors")
    res = solvers.get("apc").solve(sys, store=store)     # hit after 1st
    srv = solvers.LinsysServer(store, solver="apc", batch=4)

Async pipelined serving (overlapped admission/assembly/execution, per-
request futures, SLO latency report):

    asrv = solvers.AsyncLinsysServer(store, solver="apc", batch=4,
                                     pipeline_depth=2)
    with asrv:
        tickets = [asrv.submit(fp, b) for b in stream]

System modes (dense square / least-squares / block-sparse) flow through
every entry point above; each solver declares ``supports`` and a request
outside it raises ``CapabilityError`` at dispatch:

    ls  = linsys.tall_gaussian(1000, 500, 4, noise=0.01)   # inconsistent LS
    res = solvers.get("dgd").solve(ls, iters=2000)          # optimality res.
    sp  = linsys.banded_system(768, 4, bandwidth=9)         # already sparse
    res = solvers.get("apc").solve(sp, iters=300)           # sparse blockops

Streaming perturbed right-hand sides through a server (warm-start gating):

    rep = solvers.solve_stream(srv, [(fp, b0), (fp, b1), ...])
    rep.warm_hit_rate   # 1.0 for warm_rhs_ok solvers after the first batch

See ``api.Solver`` for the protocol, ``registry.register`` for adding a
new method, ``mesh`` for the sharded backend, ``redundant`` for the
r-redundant straggler-tolerant layer, ``store`` for the content-addressed
factor cache, ``serve`` for the linear-system request server, and
``pipeline`` for its async pipelined twin.
"""
from .api import Solver, SolveResult, iters_to_tolerance  # noqa: F401
from .capability import CapabilityError  # noqa: F401
from .registry import available, get, register  # noqa: F401

# Importing the implementation modules populates the registry.
from . import admm, gradient, projection  # noqa: F401, E402
from . import mesh  # noqa: F401, E402  (the shard_map execution backend)
from . import redundant  # noqa: F401, E402  (straggler-tolerant layer)
from .store import FactorStore, fingerprint  # noqa: F401, E402
from .serve import LinsysServer, StreamReport, solve_stream  # noqa: F401, E402
from .pipeline import AsyncLinsysServer, Shed, Ticket  # noqa: F401, E402
