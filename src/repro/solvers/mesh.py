"""Mesh execution backend: any registered solver, sharded via shard_map.

Every solver in the registry runs distributed on a device mesh through the
same lifecycle it uses on a single host:

    from repro import solvers
    res = solvers.get("dhbm").solve(sys, backend="mesh", mesh=mesh)

Mapping of the paper's roles onto the mesh (generalizing the APC-only
runtime that used to live in ``core/distributed.py``):

  * worker i   -> a slice of the ``data`` mesh axis (the m row blocks shard
                  over one or more ``worker_axes``).
  * taskmaster -> no physical node; every master update is a ``psum`` over
                  the worker axes (mean of x_i for the projection family and
                  M-ADMM, sum of partial gradients A_i^T(A_i x - b_i) for
                  the gradient family, sum of row projections for Cimmino).
  * columns    -> optionally sharded along ``model`` so a (p, n) block with
                  n ~ 10^6+ fits per-device memory; worker-local GEMVs then
                  need one extra p-sized psum over ``model``.

Setup is on-mesh: ``mesh_prepare`` (Gram Cholesky, preconditioners) and
``mesh_init`` run under shard_map, so no host ever materializes the full A.
States use GLOBAL shapes and the same pytree structure as the single-host
path — warm starts and ``repro.checkpoint.ckpt`` round-trip freely between
backends.

Per-solver code lives in the ``mesh_*`` hooks on each Solver subclass (see
``api.Solver``); this module owns placement, the jitted scan with
per-iteration residual/error history, and the unified ``SolveResult``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax.shard_map is the stable spelling on newer releases
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map

from repro.core import blockops
from repro.core.partition import BlockSystem

from .api import SolveResult, iters_to_tolerance
from .capability import check_capability, resolve_use_kernel


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Collective helpers handed to every ``mesh_*`` solver hook.

    ``w`` / ``n`` are the PartitionSpec entries for the worker and column
    (model) dimensions; the ``psum_*`` helpers are the only collectives a
    solver ever needs (the taskmaster is a psum, never a device).
    """
    mesh: Mesh
    worker_axes: Tuple[str, ...]
    model_axis: Optional[str]

    @property
    def w(self):
        """Spec entry for the worker-sharded leading axis."""
        return (self.worker_axes if len(self.worker_axes) > 1
                else self.worker_axes[0])

    @property
    def n(self) -> Optional[str]:
        """Spec entry for the column-sharded n axis (None = replicated)."""
        return self.model_axis

    def psum_workers(self, v):
        """Sum over every worker axis (the Eq. 2b 'taskmaster' reduction)."""
        return jax.lax.psum(v, self.worker_axes)

    def psum_model(self, v):
        """Sum over the column shards (no-op when n is not sharded)."""
        if self.model_axis is None:
            return v
        return jax.lax.psum(v, self.model_axis)

    def workers_total(self, m_local: int) -> int:
        """Global worker count m from a local shard's leading axis."""
        for ax in self.worker_axes:
            m_local = m_local * self.mesh.shape[ax]
        return m_local


def make_context(mesh: Mesh, sys: BlockSystem, *,
                 worker_axes: Sequence[str] = ("data",),
                 model_axis: Optional[str] = "model") -> MeshContext:
    """Validate mesh axes against the system and build a MeshContext.

    Axes the mesh does not have are dropped rather than rejected — the
    defaults name the production axes, and a smaller mesh (e.g. a 1-axis
    test mesh without "model") simply runs unsharded along the missing
    dimension.  Mind the consequence: a misspelled axis name degrades to
    replication silently, so double-check names against mesh.axis_names
    when a solve does not scale the way the mesh shape says it should.
    """
    worker_axes = tuple(a for a in worker_axes if a in mesh.axis_names)
    if not worker_axes:
        raise ValueError(f"mesh {mesh.axis_names} has none of the requested "
                         f"worker axes")
    if model_axis is not None and model_axis not in mesh.axis_names:
        model_axis = None
    if sys.is_sparse:
        # sparse column indices address the GLOBAL n axis, so sparse
        # systems shard over worker axes only (blocks are already
        # column-compressed; a model shard would re-split the support)
        model_axis = None
    ctx = MeshContext(mesh=mesh, worker_axes=worker_axes,
                      model_axis=model_axis)
    wsize = ctx.workers_total(1)
    if sys.m % wsize:
        raise ValueError(f"worker axes {worker_axes} have {wsize} shards, "
                         f"which does not divide m={sys.m}")
    nsize = mesh.shape[model_axis] if model_axis is not None else 1
    if sys.n % nsize:
        raise ValueError(f"model axis {model_axis!r} has {nsize} shards, "
                         f"which does not divide n={sys.n}")
    return ctx


def residual_shard(A, b, x, b_norm, ctx: MeshContext):
    """Relative residual ||Ax-b||/||b|| from local shards (replicated out)."""
    r = ctx.psum_model(blockops.bmatvec(A, x)) - b
    return jnp.sqrt(ctx.psum_workers(jnp.sum(r * r))) / b_norm


def operand_specs(sys: BlockSystem, ctx: MeshContext):
    """PartitionSpec (pytree) for ``sys.A_op``: a single spec for the dense
    stack, a matching ``SparseBlocks`` of specs for sparse operands."""
    if sys.is_sparse:
        return blockops.SparseBlocks(vals=P(ctx.w, None, None),
                                     cols=P(ctx.w, None), span=P(None))
    return P(ctx.w, None, ctx.n)


def _patch_factor_specs(fspecs, a_spec):
    """Swap a sparse operand spec into a factor pytree's ``A`` field."""
    if blockops.is_sparse(a_spec) and hasattr(fspecs, "_replace") \
            and "A" in getattr(fspecs, "_fields", ()):
        return fspecs._replace(A=a_spec)
    return fspecs


def _default_mesh(workers: int) -> Mesh:
    from repro.launch import mesh as mesh_lib
    return mesh_lib.solver_mesh_for(workers)


def _put_tree(tree: Any, specs: Any, mesh: Mesh) -> Any:
    """device_put every leaf with its NamedSharding (global shapes in)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), NamedSharding(mesh, s)),
        tree, specs)


def _batched_specs(specs: Any) -> Any:
    """Prepend a replicated RHS-batch dimension to every state spec."""
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), specs,
                        is_leaf=lambda s: isinstance(s, P))


def _factor_specs(solver, ctx: MeshContext, use_kernel: bool):
    """Factor specs, with the kernel-path augmentation (pinv factors)
    included when requested.  ``use_kernel`` only reaches solvers with
    ``supports_kernel``, whose specs hook takes the kwarg."""
    if use_kernel:
        return solver.mesh_factor_specs(ctx, use_kernel=True)
    return solver.mesh_factor_specs(ctx)


def _host_factors(solver, factors, use_kernel: bool):
    """Host-side factor normalization before placement: strip host-only
    fields, or (kernel path) idempotently ensure the pinv augmentation."""
    if use_kernel:
        return solver.mesh_factors(factors, use_kernel=True)
    return solver.mesh_factors(factors)


def _place(solver, sys: BlockSystem, ctx: MeshContext, prm, factors,
           store=None, resume: bool = False, use_kernel: bool = False,
           precision: str = "default"):
    """Shard A/b, run on-mesh prepare (unless factors are given).

    With a ``store``, the ``factors is None`` branch becomes a cache
    lookup; a MISS still runs the on-mesh sharded ``mesh_prepare`` (no
    host ever factorizes the full A) and the result is inserted back, so
    later solves — either backend — hit it.  An entry therefore holds
    whichever mathematically-equivalent factorization first populated it
    (host or on-mesh prepare; for most solvers they are bit-identical).

    ``use_kernel=True`` keeps the pinv factors: a store hit is augmented
    ONCE and written back (``lookup(use_kernel=True)``), an on-mesh miss
    computes them shard-locally inside ``mesh_prepare`` and the inserted
    entry carries them, so later kernel solves on either backend never
    re-run the augmentation.
    """
    mesh = ctx.mesh
    A_spec, b_spec = operand_specs(sys, ctx), P(ctx.w, None)
    fspecs = _patch_factor_specs(_factor_specs(solver, ctx, use_kernel),
                                 A_spec)
    A = _put_tree(sys.A_op, A_spec, mesh)
    b = jax.device_put(sys.b_blocks, NamedSharding(mesh, b_spec))
    if factors is None and store is not None:
        factors = store.lookup(solver, sys, use_kernel=use_kernel,
                               precision=precision, **prm)
    if factors is None:
        prep_fn = ((lambda A_: solver.mesh_prepare(A_, prm, ctx,
                                                   use_kernel=True))
                   if use_kernel
                   else (lambda A_: solver.mesh_prepare(A_, prm, ctx)))
        prep = jax.jit(shard_map(
            prep_fn, mesh=mesh, in_specs=(A_spec,), out_specs=fspecs))
        factors = prep(A)
        if store is not None:
            store.insert(solver, sys, factors, resume=resume,
                         use_kernel=use_kernel, precision=precision, **prm)
    else:
        factors = _put_tree(_host_factors(solver, factors, use_kernel),
                            fspecs, mesh)
    if precision != "default":
        # cast LAST: an elementwise astype preserves each leaf's sharding,
        # and cast_factors is idempotent for store-returned mixed entries
        factors = solver.cast_factors(factors, precision)
    return A, b, A_spec, b_spec, fspecs, factors


class CompiledSolve(NamedTuple):
    """A placed, compile-once mesh solve: call ``run(*args)`` repeatedly.

    ``run`` returns ``(state, residuals, errors)``; ``has_errors`` says
    whether the error channel is real (x_true given) or aliases the
    residuals.  Benchmarks time repeat executions of the SAME callable so
    trace/compile cost drops out; ``solve_mesh`` builds one per call.
    """
    run: Any
    args: Tuple
    params: dict
    has_errors: bool


def compile_solve(solver, sys: BlockSystem, *, mesh: Optional[Mesh] = None,
                  iters: int = 1000,
                  worker_axes: Sequence[str] = ("data",),
                  model_axis: Optional[str] = "model",
                  warm_state: Any = None, factors: Any = None,
                  store: Any = None, use_kernel: bool = False,
                  precision: str = "default",
                  **params) -> CompiledSolve:
    """Placement + on-mesh setup + the jitted scan, without executing it."""
    check_capability(solver, sys, context="solve(mesh)")
    use_kernel = resolve_use_kernel(solver, sys, use_kernel)
    solver._check_precision(precision, use_kernel)
    if mesh is None:
        mesh = _default_mesh(sys.m)
    ctx = make_context(mesh, sys, worker_axes=worker_axes,
                       model_axis=model_axis)
    prm = solver.resolve_params(sys, **params)
    A, b, A_spec, b_spec, fspecs, factors = _place(
        solver, sys, ctx, prm, factors, store=store,
        resume=warm_state is not None, use_kernel=use_kernel,
        precision=precision)
    sspecs = solver.mesh_state_specs(ctx)

    if warm_state is None:
        init_fn = jax.jit(shard_map(
            lambda f, b_: solver.mesh_init(f, b_, prm, ctx), mesh=mesh,
            in_specs=(fspecs, b_spec), out_specs=sspecs))
        state = init_fn(factors, b)
    else:
        state = _put_tree(warm_state, sspecs, mesh)

    xt = sys.x_true
    if xt is None and sys.mode == "least_squares":
        xt = solver.ls_reference(sys)       # error channel vs the LS optimum
    args = (A, b, factors, state)
    in_specs = (A_spec, b_spec, fspecs, sspecs)
    if xt is not None:
        args += (jax.device_put(xt, NamedSharding(mesh, P(ctx.n))),)
        in_specs += (P(ctx.n),)

    step_fn = ((lambda f, b_, st: solver.mesh_step(f, b_, st, prm, ctx,
                                                   use_kernel=True))
               if use_kernel
               else (lambda f, b_, st: solver.mesh_step(f, b_, st, prm,
                                                        ctx)))
    ls_mode = sys.mode == "least_squares"
    fused_res = (use_kernel and solver.supports_fused_residual
                 and not ls_mode and iters > 0)

    def run_body(A_, b_, f_, s_, *rest):
        b_norm = jnp.sqrt(ctx.psum_workers(jnp.sum(b_ * b_)))
        xt_ = rest[0] if rest else None
        xt_norm = (jnp.sqrt(ctx.psum_model(jnp.sum(xt_ * xt_)))
                   if xt_ is not None else None)

        if ls_mode:
            # LS residual channel: ‖AᵀW(Ax−b)‖ relative to x = 0 — the
            # optimality moment of the solver's own LS objective
            def ls_norm(x):
                mom = solver.ls_moment(f_, A_, b_, x, prm, ctx)
                return jnp.sqrt(ctx.psum_model(jnp.sum(mom * mom)))

            ls_denom = ls_norm(jnp.zeros_like(solver.extract(s_)))

        if fused_res:
            # fused residual: every step harvests ‖Ax−b‖ of the state it
            # CONSUMED from its own gather pass; shift the lagged records
            # by one and close with a single true-A residual — no second
            # per-iteration read of A
            def body(st, _):
                st, rsq = solver.mesh_step_residual(f_, b_, st, prm, ctx)
                res = jnp.sqrt(rsq) / b_norm
                if xt_ is not None:
                    dx = solver.extract(st) - xt_
                    err = (jnp.sqrt(ctx.psum_model(jnp.sum(dx * dx)))
                           / xt_norm)
                else:
                    err = res
                return st, (res, err)

            s_, (res, err) = jax.lax.scan(body, s_, None, length=iters)
            final = residual_shard(A_, b_, solver.extract(s_), b_norm, ctx)
            res = jnp.concatenate([res[1:], final[None]])
            if xt_ is None:
                err = res
            return s_, res, err

        def body(st, _):
            st = step_fn(f_, b_, st)
            x = solver.extract(st)
            if ls_mode:
                res = ls_norm(x) / ls_denom
            else:
                res = residual_shard(A_, b_, x, b_norm, ctx)
            if xt_ is not None:
                dx = x - xt_
                err = jnp.sqrt(ctx.psum_model(jnp.sum(dx * dx))) / xt_norm
            else:
                err = res
            return st, (res, err)

        s_, (res, err) = jax.lax.scan(body, s_, None, length=iters)
        return s_, res, err

    # pallas_call has no shard_map replication rule — the kernel path
    # disables the check (the psum contract itself is unchanged)
    run = jax.jit(shard_map(run_body, mesh=mesh, in_specs=in_specs,
                            out_specs=(sspecs, P(), P()),
                            check_rep=not use_kernel))
    return CompiledSolve(run=run, args=args, params=prm,
                         has_errors=xt is not None)


def solve_mesh(solver, sys: BlockSystem, *, mesh: Optional[Mesh] = None,
               iters: int = 1000, tol: float = 1e-6,
               worker_axes: Sequence[str] = ("data",),
               model_axis: Optional[str] = "model",
               warm_state: Any = None, factors: Any = None,
               store: Any = None, use_kernel: bool = False,
               precision: str = "default",
               **params) -> SolveResult:
    """Sharded ``solve``: the mesh twin of ``Solver.solve``.

    Returns the same ``SolveResult`` (full residual/error history,
    warm-startable state with global shapes) as the single-host driver.
    ``use_kernel=True`` (projection family) runs each worker shard's
    update through the Pallas kernels on its local (p × n_local) block.
    """
    cs = compile_solve(solver, sys, mesh=mesh, iters=iters,
                       worker_axes=worker_axes, model_axis=model_axis,
                       warm_state=warm_state, factors=factors, store=store,
                       use_kernel=use_kernel, precision=precision, **params)
    state, res, err = cs.run(*cs.args)
    return SolveResult(
        name=solver.name, x=solver.extract(state), state=state,
        residuals=res, errors=err if cs.has_errors else None,
        params=cs.params, iters_to_tol=iters_to_tolerance(res, tol), tol=tol)


class BatchedRunner(NamedTuple):
    """Compile-once batched executor for one (solver, params, mesh) config.

    ``init``/``run`` are jitted shard_map callables over PLACED arrays —
    calling them repeatedly with same-shape/same-sharding arguments never
    retraces, which is what lets ``solvers.serve.LinsysServer`` keep a
    steady-state serving loop at zero retraces.  ``cache_size()`` exposes
    the underlying jit caches so benchmarks can assert exactly that.
    """
    init: Any           # (factors, Bb)            -> states
    run: Any            # (A, Bb, factors, states) -> (states, X, res (k,T))
    A_spec: Any
    Bb_spec: Any
    factor_specs: Any
    state_specs: Any

    def cache_size(self) -> int:
        sizes = [getattr(f, "_cache_size", lambda: -1)()
                 for f in (self.init, self.run)]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)


def batched_runner(solver, ctx: MeshContext, prm, iters: int,
                   use_kernel: bool = False, *, a_spec: Any = None,
                   ls_mode: bool = False,
                   fused_residual: bool = False) -> BatchedRunner:
    """Build the jitted multi-RHS init/run pair shared by ``solve_many_mesh``
    and the serving layer.  Nothing system-specific is baked in beyond the
    params and the mesh context: A / b / factors / states are arguments, so
    one runner serves every same-shape system.  ``use_kernel=True`` routes
    the batched step through ``mesh_step_many``'s fused multi-RHS Pallas
    path (projection family).  ``a_spec`` overrides the operand spec (a
    ``SparseBlocks`` spec pytree for sparse systems, see ``operand_specs``);
    ``ls_mode`` switches the residual channel to the per-RHS LS optimality
    moment; ``fused_residual`` (kernel path, square mode) harvests the
    per-iteration history from the gather pass instead of a second full
    read of A (lagged-shift contract, see ``api._history_scan``)."""
    mesh = ctx.mesh
    if a_spec is None:
        a_spec = P(ctx.w, None, ctx.n)
    A_spec, Bb_spec = a_spec, P(None, ctx.w, None)
    fspecs = _patch_factor_specs(_factor_specs(solver, ctx, use_kernel),
                                 A_spec)
    sspecs = _batched_specs(solver.mesh_state_specs(ctx))
    fused_residual = (fused_residual and use_kernel and not ls_mode
                      and iters > 0 and solver.supports_fused_residual)

    init_fn = jax.jit(shard_map(
        lambda f, Bb_: jax.vmap(
            lambda bb: solver.mesh_init(f, bb, prm, ctx))(Bb_),
        mesh=mesh, in_specs=(fspecs, Bb_spec), out_specs=sspecs))

    def run_body(A_, Bb_, f_, s_):
        b_norms = jnp.sqrt(ctx.psum_workers(jnp.sum(Bb_ * Bb_, axis=(1, 2))))

        def vstep(Bb__, sts):
            return solver.mesh_step_many(f_, Bb__, sts, prm, ctx,
                                         use_kernel=use_kernel)

        if ls_mode:
            def ls_norm(bb, x):
                mom = solver.ls_moment(f_, A_, bb, x, prm, ctx)
                return jnp.sqrt(ctx.psum_model(jnp.sum(mom * mom)))

            X0 = jax.vmap(solver.extract)(s_)
            ls_denoms = jax.vmap(ls_norm)(Bb_, jnp.zeros_like(X0))

        if fused_residual:
            def body(sts, _):
                sts, rsq = solver.mesh_step_many_residual(f_, Bb_, sts,
                                                          prm, ctx)
                return sts, jnp.sqrt(rsq) / b_norms           # (k,)

            s_, res = jax.lax.scan(body, s_, None, length=iters)
            X = jax.vmap(solver.extract)(s_)
            r = ctx.psum_model(blockops.bmatvec_many(A_, X)) - Bb_
            final = jnp.sqrt(
                ctx.psum_workers(jnp.sum(r * r, axis=(1, 2)))) / b_norms
            res = jnp.concatenate([res[1:], final[None]], axis=0)
            return s_, X, res.T                               # (k, T)

        def body(sts, _):
            sts = vstep(Bb_, sts)
            X = jax.vmap(solver.extract)(sts)                  # (k, n_loc)
            if ls_mode:
                res = jax.vmap(ls_norm)(Bb_, X) / ls_denoms
            else:
                r = ctx.psum_model(blockops.bmatvec_many(A_, X)) - Bb_
                res = jnp.sqrt(
                    ctx.psum_workers(jnp.sum(r * r, axis=(1, 2)))) / b_norms
            return sts, res

        s_, res = jax.lax.scan(body, s_, None, length=iters)
        return s_, jax.vmap(solver.extract)(s_), res.T         # (k, T)

    run = jax.jit(shard_map(run_body, mesh=mesh,
                            in_specs=(A_spec, Bb_spec, fspecs, sspecs),
                            out_specs=(sspecs, P(None, ctx.n), P()),
                            check_rep=not use_kernel))
    return BatchedRunner(init=init_fn, run=run, A_spec=A_spec,
                         Bb_spec=Bb_spec, factor_specs=fspecs,
                         state_specs=sspecs)


def solve_many_mesh(solver, sys: BlockSystem, B, *,
                    mesh: Optional[Mesh] = None, iters: int = 1000,
                    tol: float = 1e-6,
                    worker_axes: Sequence[str] = ("data",),
                    model_axis: Optional[str] = "model",
                    factors: Any = None, store: Any = None,
                    use_kernel: bool = False, precision: str = "default",
                    **params) -> SolveResult:
    """Sharded multi-RHS solve: one on-mesh factorization, k right-hand
    sides batched inside the shard_map body (batch axis replicated) — the
    fused multi-RHS kernels under ``use_kernel=True``."""
    check_capability(solver, sys, context="solve_many(mesh)")
    use_kernel = resolve_use_kernel(solver, sys, use_kernel)
    solver._check_precision(precision, use_kernel)
    if mesh is None:
        mesh = _default_mesh(sys.m)
    ctx = make_context(mesh, sys, worker_axes=worker_axes,
                       model_axis=model_axis)
    B = jnp.asarray(B)
    if B.ndim == 1:
        B = B[None, :]
    if B.shape[-1] != sys.N:
        raise ValueError(f"RHS batch has {B.shape[-1]} rows, need N={sys.N}")
    k = B.shape[0]
    prm = solver.resolve_params(sys, **params)
    A, _, _, _, _, factors = _place(solver, sys, ctx, prm, factors,
                                    store=store, use_kernel=use_kernel,
                                    precision=precision)
    runner = batched_runner(solver, ctx, prm, iters, use_kernel=use_kernel,
                            a_spec=operand_specs(sys, ctx),
                            ls_mode=sys.mode == "least_squares",
                            fused_residual=use_kernel)

    Bb = jax.device_put(B.reshape(k, sys.m, sys.p),
                        NamedSharding(mesh, runner.Bb_spec))
    states = runner.init(factors, Bb)
    states, X, res = runner.run(A, Bb, factors, states)
    return SolveResult(
        name=solver.name, x=X, state=states, residuals=res, errors=None,
        params=prm, iters_to_tol=iters_to_tolerance(res, tol), tol=tol)


class RedundantRunner:
    """Compile-once mesh runner for the r-redundant scan.

    Built by ``redundant.RedundantEngine`` on ``backend="mesh"``: all
    placement and both jits (on-mesh replicated prepare/init plus the
    segment scan) are constructed ONCE here, and ``run`` re-enters the
    SAME compiled shard_map scan with a freshly lowered selection-weight
    schedule of identical shape.  A membership change that keeps the
    partition (a worker death under r-redundancy) therefore costs a
    schedule re-lowering, never a retrace — the property the elastic
    runtime's benchmarks gate on.
    """

    def __init__(self, solver, sys: BlockSystem, assign, prm, *,
                 mesh: Optional[Mesh] = None,
                 worker_axes: Sequence[str] = ("data",),
                 model_axis: Optional[str] = "model",
                 factors: Any = None):
        from . import redundant as red  # lazy: redundant.py imports us

        if mesh is None:
            mesh = _default_mesh(sys.m)
        ctx = make_context(mesh, sys, worker_axes=worker_axes,
                           model_axis=model_axis)
        self.solver, self.assign = solver, assign
        self.mesh, self.ctx, self.prm = mesh, ctx, prm
        A_spec, b_spec = P(ctx.w, None, ctx.n), P(ctx.w, None)
        Arep_spec = P(ctx.w, None, None, ctx.n)
        brep_spec = P(ctx.w, None, None)
        self._W_spec, self._Wseq_spec = P(ctx.w, None), P(None, ctx.w, None)
        fspecs = solver.red_factor_specs(ctx)
        self._sspecs = sspecs = solver.red_state_specs(ctx)

        put = lambda v, s: jax.device_put(v, NamedSharding(mesh, s))
        A_rep, b_rep = red.replicate_system(sys, assign)
        self._A, self._b = put(sys.A_blocks, A_spec), put(sys.b_blocks, b_spec)
        A_rep, self._b_rep = put(A_rep, Arep_spec), put(b_rep, brep_spec)

        if factors is None:
            prep = jax.jit(shard_map(
                lambda Ar: red._red_mesh_prepare(solver, Ar, prm, ctx),
                mesh=mesh, in_specs=(Arep_spec,), out_specs=fspecs))
            self._frep = prep(A_rep)
        else:
            self._frep = _put_tree(
                solver.red_factors(solver.mesh_factors(factors), assign),
                fspecs, mesh)

        self._init = jax.jit(shard_map(
            lambda f, br, W0: solver.red_init(f, br, prm, W0, ctx),
            mesh=mesh, in_specs=(fspecs, brep_spec, self._W_spec),
            out_specs=sspecs))

        xt = sys.x_true
        self._xt = () if xt is None else (put(xt, P(ctx.n)),)
        in_specs = (A_spec, b_spec, brep_spec, fspecs, sspecs,
                    self._Wseq_spec)
        if xt is not None:
            in_specs += (P(ctx.n),)

        def run_body(A_, b_, br_, f_, s_, Ws_, *rest):
            b_norm = jnp.sqrt(ctx.psum_workers(jnp.sum(b_ * b_)))
            xt_ = rest[0] if rest else None
            xt_norm = (jnp.sqrt(ctx.psum_model(jnp.sum(xt_ * xt_)))
                       if xt_ is not None else None)

            def body(st, Wt):
                st = solver.red_step(f_, br_, st, prm, Wt, ctx)
                x = solver.extract(st)
                res = residual_shard(A_, b_, x, b_norm, ctx)
                if xt_ is not None:
                    dx = x - xt_
                    err = jnp.sqrt(ctx.psum_model(jnp.sum(dx * dx))) / xt_norm
                else:
                    err = res
                return st, (res, err)

            s_, (res, err) = jax.lax.scan(body, s_, Ws_)
            return s_, res, err

        self._run = jax.jit(shard_map(run_body, mesh=mesh, in_specs=in_specs,
                                      out_specs=(sspecs, P(), P())))

    def init_state(self, warm_state, W_all):
        """Fresh ``red_init`` (warm_state None) or a placed ``red_expand``
        of a GLOBAL-shape warm state."""
        if warm_state is None:
            W_all = jax.device_put(W_all,
                                   NamedSharding(self.mesh, self._W_spec))
            return self._init(self._frep, self._b_rep, W_all)
        return _put_tree(self.solver.red_expand(warm_state, self.assign),
                         self._sspecs, self.mesh)

    def run(self, state, W_seq):
        """One segment: re-enters the compiled scan with a new schedule."""
        W_seq = jax.device_put(W_seq,
                               NamedSharding(self.mesh, self._Wseq_spec))
        return self._run(self._A, self._b, self._b_rep, self._frep, state,
                         W_seq, *self._xt)

    def cache_size(self) -> int:
        sizes = [getattr(f, "_cache_size", lambda: -1)()
                 for f in (self._init, self._run)]
        return -1 if any(s < 0 for s in sizes) else sum(sizes)
