"""Core: the paper's contribution — APC and every comparison method.

Public surface:
  partition.BlockSystem / partition.partition   row-block data model
  apc.solve / apc.apc_step                      Algorithm 1
  spectral.*                                    Theorem 1 optimal params, rates
  baselines.*                                   DGD/D-NAG/D-HBM/M-ADMM/Cimmino/
                                                Consensus (Sec 4)
  precond.preconditioned_dhbm                   Sec 6 distributed preconditioning
  distributed.solve_on_mesh                     shard_map production runtime
  coding.solve_redundant                        straggler-tolerant APC
  consensus.run_consensus                       generic combinator
"""
from . import apc, baselines, coding, consensus, distributed, partition  # noqa
from . import precond, spectral  # noqa: F401
from .partition import BlockSystem, partition as split  # noqa: F401
