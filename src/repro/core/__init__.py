"""Core: the paper's contribution — APC and every comparison method.

The canonical solver surface now lives in ``repro.solvers``: a string-keyed
registry of Solver objects sharing one lifecycle (``prepare -> init ->
step``), one jitted ``solve()`` driver, batched multi-RHS ``solve_many``,
warm-start resume, and one unified ``SolveResult``:

    from repro import solvers
    res = solvers.get("apc").solve(sys, iters=500)
    solvers.available()
    # ['apc', 'cimmino', 'consensus', 'dgd', 'dhbm', 'dnag', 'madmm', 'pdhbm']

This package keeps the building blocks and the legacy entry points (now thin
deprecated shims over the registry):

  partition.BlockSystem / partition.partition   row-block data model
  apc.apc_step / apc.prepare                    Algorithm 1 primitives
  apc.solve                                     shim -> solvers.get("apc")
  spectral.*                                    Theorem 1 optimal params, rates
  baselines.*                                   shims -> dgd/dnag/dhbm/madmm/
                                                cimmino/consensus (Sec 4)
  precond.precondition                          Sec 6 block preconditioner
  precond.preconditioned_dhbm                   shim -> solvers.get("pdhbm")
  distributed.solve_on_mesh                     shard_map production runtime
  coding.solve_redundant                        shim -> solve(redundancy=r)
                                                (repro.solvers.redundant)
  consensus.run_consensus                       generic combinator
"""
from . import apc, baselines, coding, consensus, distributed, partition  # noqa
from . import precond, spectral  # noqa: F401
from .partition import BlockSystem, partition as split  # noqa: F401
