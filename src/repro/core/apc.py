"""APC — Accelerated Projection-based Consensus (paper Algorithm 1).

Single-host reference implementation: the m workers are a vmapped leading
axis.  The mesh-distributed production version with identical semantics lives
in ``core/distributed.py`` (shard_map + psum); both share the factor
preparation here.  The per-iteration worker math can optionally run through
the Pallas TPU kernel (``repro.kernels.ops.block_projection``).

Worker update (Eq. 2a):   x_i <- x_i + gamma * P_i (xbar - x_i)
Master update (Eq. 2b):   xbar <- (eta/m) sum_i x_i + (1-eta) xbar

with P_i = I - A_i^T (A_i A_i^T)^{-1} A_i.  We precompute per worker a
Cholesky factor L_i of the Gram matrix G_i = A_i A_i^T, so each iteration is
two matvecs + two triangular solves: P_i v = v - A_i^T G_i^{-1} (A_i v).
Per-iteration complexity 2pn + O(p^2) per worker, matching the paper Sec 3.3.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .partition import BlockSystem
from . import spectral


class APCFactors(NamedTuple):
    """Per-worker precomputation (leading axis = worker)."""
    A: jnp.ndarray        # (m, p, n) row blocks
    chol: jnp.ndarray     # (m, p, p) Cholesky of Gram A_i A_i^T
    x0: jnp.ndarray       # (m, n) min-norm local solutions A_i^+ b_i
    b: jnp.ndarray        # (m, p)


class APCState(NamedTuple):
    """Checkpointable iteration state."""
    x: jnp.ndarray        # (m, n) worker solutions, all satisfy A_i x_i = b_i
    xbar: jnp.ndarray     # (n,)  master estimate
    t: jnp.ndarray        # ()    iteration counter


def _gram_chol(Ai: jnp.ndarray, jitter: float) -> jnp.ndarray:
    G = Ai @ Ai.T
    if jitter:
        G = G + jitter * jnp.trace(G) / G.shape[0] * jnp.eye(
            G.shape[0], dtype=G.dtype)
    return jnp.linalg.cholesky(G)


def _gram_solve(chol: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Solve (L L^T) y = u with the stored Cholesky factor."""
    y = jax.scipy.linalg.solve_triangular(chol, u, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)


def prepare(sys: BlockSystem, *, jitter: float = 0.0) -> APCFactors:
    """One-time O(p^2 n + p^3) per-worker setup (paper 'Initialization').

    x_i(0) = A_i^T (A_i A_i^T)^{-1} b_i is *a* solution of the local
    under-determined system (the minimum-norm one).
    """
    def one(Ai, bi):
        L = _gram_chol(Ai, jitter)
        x0 = Ai.T @ _gram_solve(L, bi)
        return L, x0

    chol, x0 = jax.vmap(one)(sys.A_blocks, sys.b_blocks)
    return APCFactors(A=sys.A_blocks, chol=chol, x0=x0, b=sys.b_blocks)


def init_state(factors: APCFactors) -> APCState:
    x = factors.x0
    xbar = jnp.mean(x, axis=0)
    return APCState(x=x, xbar=xbar, t=jnp.zeros((), jnp.int32))


def project_nullspace(A, chol, v):
    """P_i v = v - A^T G^{-1} A v  — projection onto null(A)."""
    return v - A.T @ _gram_solve(chol, A @ v)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def apc_step(factors: APCFactors, state: APCState, gamma, eta,
             *, use_kernel: bool = False) -> APCState:
    """One full APC iteration (all workers + master)."""
    if use_kernel:
        from repro.kernels import ops as kops

        def worker(Ai, Li, xi):
            # Pallas path needs the explicit pseudoinverse factor; computed
            # on the fly here (production precomputes B, see distributed.py).
            Bi = jax.scipy.linalg.cho_solve((Li, True), Ai).T  # (n, p)
            return kops.block_projection(Ai, Bi, xi, state.xbar, gamma)
    else:
        def worker(Ai, Li, xi):
            d = state.xbar - xi
            return xi + gamma * project_nullspace(Ai, Li, d)

    x_new = jax.vmap(worker)(factors.A, factors.chol, state.x)
    xbar_new = eta * jnp.mean(x_new, axis=0) + (1.0 - eta) * state.xbar
    return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)


@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: jnp.ndarray                 # final estimate xbar(T)
    state: APCState                # full state (checkpointable / resumable)
    residuals: jnp.ndarray         # (T,) ||A xbar - b|| / ||b||
    errors: Optional[jnp.ndarray]  # (T,) ||xbar - x*|| / ||x*|| if x_true given


def _history_scan(step_fn: Callable, state, sys: BlockSystem, iters: int):
    """Run `iters` steps recording relative residual (and error) per step."""
    A = sys.A_blocks
    b = sys.b_blocks
    b_norm = jnp.sqrt(jnp.sum(b * b))
    xt = sys.x_true
    xt_norm = None if xt is None else jnp.linalg.norm(xt)

    def body(state, _):
        state = step_fn(state)
        xbar = state.xbar if hasattr(state, "xbar") else state.x
        r = jnp.einsum("mpn,n->mp", A, xbar) - b
        res = jnp.sqrt(jnp.sum(r * r)) / b_norm
        err = (jnp.linalg.norm(xbar - xt) / xt_norm) if xt is not None else res
        return state, (res, err)

    state, (res, err) = jax.lax.scan(body, state, None, length=iters)
    return state, res, err


def solve(sys: BlockSystem, *, iters: int = 1000,
          gamma: Optional[float] = None, eta: Optional[float] = None,
          use_kernel: bool = False, jitter: float = 0.0) -> SolveResult:
    """End-to-end APC solve.  If (gamma, eta) are omitted, the taskmaster
    computes the Theorem-1 optimal pair from the spectrum of X (analysis done
    once, in float64 on host)."""
    if gamma is None or eta is None:
        X = spectral.x_matrix(sys)
        mu_min, mu_max = spectral.mu_extremes(X)
        params = spectral.apc_optimal(mu_min, mu_max)
        gamma = params.gamma if gamma is None else gamma
        eta = params.eta if eta is None else eta

    factors = prepare(sys, jitter=jitter)
    state = init_state(factors)
    step = lambda s: apc_step(factors, s, gamma, eta, use_kernel=use_kernel)
    state, res, err = _history_scan(step, state, sys, iters)
    return SolveResult(x=state.xbar, state=state, residuals=res,
                       errors=err if sys.x_true is not None else None)
