"""APC — Accelerated Projection-based Consensus (paper Algorithm 1).

Single-host reference implementation: the m workers are a vmapped leading
axis.  The mesh-distributed production version with identical semantics lives
in ``core/distributed.py`` (shard_map + psum); both share the factor
preparation here.  The per-iteration worker math can optionally run through
the Pallas TPU kernel (``repro.kernels.ops.block_projection``).

This module keeps the low-level building blocks (factors, state, apc_step)
used by ``repro.solvers``, ``core/distributed.py`` and ``core/coding.py``;
the end-to-end ``solve`` entry point is a deprecated shim over
``repro.solvers.get("apc")`` — the registry is the canonical surface.

Worker update (Eq. 2a):   x_i <- x_i + gamma * P_i (xbar - x_i)
Master update (Eq. 2b):   xbar <- (eta/m) sum_i x_i + (1-eta) xbar

with P_i = I - A_i^T (A_i A_i^T)^{-1} A_i.  We precompute per worker a
Cholesky factor L_i of the Gram matrix G_i = A_i A_i^T, so each iteration is
two matvecs + two triangular solves: P_i v = v - A_i^T G_i^{-1} (A_i v).
Per-iteration complexity 2pn + O(p^2) per worker, matching the paper Sec 3.3.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .partition import BlockSystem


class APCFactors(NamedTuple):
    """Per-worker precomputation (leading axis = worker)."""
    A: jnp.ndarray        # (m, p, n) row blocks
    chol: jnp.ndarray     # (m, p, p) Cholesky of Gram A_i A_i^T
    x0: jnp.ndarray       # (m, n) min-norm local solutions A_i^+ b_i
    b: jnp.ndarray        # (m, p)


class APCState(NamedTuple):
    """Checkpointable iteration state."""
    x: jnp.ndarray        # (m, n) worker solutions, all satisfy A_i x_i = b_i
    xbar: jnp.ndarray     # (n,)  master estimate
    t: jnp.ndarray        # ()    iteration counter


def _gram_chol(Ai: jnp.ndarray, jitter: float) -> jnp.ndarray:
    G = Ai @ Ai.T
    if jitter:
        G = G + jitter * jnp.trace(G) / G.shape[0] * jnp.eye(
            G.shape[0], dtype=G.dtype)
    return jnp.linalg.cholesky(G)


def _gram_solve(chol: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Solve (L L^T) y = u with the stored Cholesky factor."""
    y = jax.scipy.linalg.solve_triangular(chol, u, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)


def prepare(sys: BlockSystem, *, jitter: float = 0.0) -> APCFactors:
    """One-time O(p^2 n + p^3) per-worker setup (paper 'Initialization').

    x_i(0) = A_i^T (A_i A_i^T)^{-1} b_i is *a* solution of the local
    under-determined system (the minimum-norm one).
    """
    def one(Ai, bi):
        L = _gram_chol(Ai, jitter)
        x0 = Ai.T @ _gram_solve(L, bi)
        return L, x0

    chol, x0 = jax.vmap(one)(sys.A_blocks, sys.b_blocks)
    return APCFactors(A=sys.A_blocks, chol=chol, x0=x0, b=sys.b_blocks)


def init_state(factors: APCFactors) -> APCState:
    x = factors.x0
    xbar = jnp.mean(x, axis=0)
    return APCState(x=x, xbar=xbar, t=jnp.zeros((), jnp.int32))


def project_nullspace(A, chol, v):
    """P_i v = v - A^T G^{-1} A v  — projection onto null(A)."""
    return v - A.T @ _gram_solve(chol, A @ v)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def apc_step(factors: APCFactors, state: APCState, gamma, eta,
             *, use_kernel: bool = False) -> APCState:
    """One full APC iteration (all workers + master)."""
    if use_kernel:
        from repro.kernels import ops as kops

        def worker(Ai, Li, xi):
            # Pallas path needs the explicit pseudoinverse factor; computed
            # on the fly here (production precomputes B, see distributed.py).
            Bi = jax.scipy.linalg.cho_solve((Li, True), Ai).T  # (n, p)
            return kops.block_projection(Ai, Bi, xi, state.xbar, gamma)
    else:
        def worker(Ai, Li, xi):
            d = state.xbar - xi
            return xi + gamma * project_nullspace(Ai, Li, d)

    x_new = jax.vmap(worker)(factors.A, factors.chol, state.x)
    xbar_new = eta * jnp.mean(x_new, axis=0) + (1.0 - eta) * state.xbar
    return APCState(x=x_new, xbar=xbar_new, t=state.t + 1)


def solve(sys: BlockSystem, *, iters: int = 1000,
          gamma: Optional[float] = None, eta: Optional[float] = None,
          use_kernel: bool = False, jitter: float = 0.0):
    """Deprecated shim — delegates to ``repro.solvers.get("apc").solve``.

    Kept so existing callers (and the paper-reproduction tests) continue to
    work; new code should go through the registry, which also provides
    ``solve_many`` (batched multi-RHS) and ``warm_state=`` resume.
    """
    from repro import solvers
    return solvers.get("apc").solve(
        sys, iters=iters, plan=solvers.ExecutionPlan(kernel=use_kernel),
        gamma=gamma, eta=eta, jitter=jitter)


def __getattr__(name):
    # Lazy alias: the unified result type now lives in repro.solvers.api
    # (imported lazily to avoid a circular import at package-init time).
    if name == "SolveResult":
        from repro.solvers.api import SolveResult
        return SolveResult
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
