"""Row-block partitioning of a linear system across workers.

The paper assumes ``m | N`` and a disjoint even split: machine ``i`` receives
``[A_i, b_i]`` with ``A_i in R^{p x n}``, ``p = N/m``.  We keep that layout but
store the blocks stacked as a single ``(m, p, n)`` array so that the whole
worker fleet can be expressed with ``vmap`` (single host) or ``shard_map``
(mesh) without Python-level per-worker loops.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockSystem:
    """A linear system ``Ax = b`` split into ``m`` row blocks.

    Attributes:
      A_blocks: (m, p, n) stacked row blocks.
      b_blocks: (m, p) stacked right-hand sides.
      x_true:   optional (n,) reference solution for error tracking.
    """

    A_blocks: jnp.ndarray
    b_blocks: jnp.ndarray
    x_true: Optional[jnp.ndarray] = None

    @property
    def m(self) -> int:
        return self.A_blocks.shape[0]

    @property
    def p(self) -> int:
        return self.A_blocks.shape[1]

    @property
    def n(self) -> int:
        return self.A_blocks.shape[2]

    @property
    def N(self) -> int:
        return self.m * self.p

    def dense(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Reassemble the global ``(N, n)`` system (for small-n analysis)."""
        return (self.A_blocks.reshape(self.N, self.n),
                self.b_blocks.reshape(self.N))


def partition(A, b, m: int, *, x_true=None) -> BlockSystem:
    """Split ``Ax=b`` into ``m`` even row blocks (paper's Figure 1 layout).

    Raises if ``m`` does not divide ``N`` — mirroring the paper's setup; pad
    upstream if needed (``pad_to_blocks``).
    """
    A = jnp.asarray(A)
    b = jnp.asarray(b)
    N, n = A.shape
    if N % m != 0:
        raise ValueError(f"m={m} must divide N={N}; use pad_to_blocks() first")
    p = N // m
    return BlockSystem(A.reshape(m, p, n), b.reshape(m, p),
                       None if x_true is None else jnp.asarray(x_true))


def pad_to_blocks(A, b, m: int):
    """Pad (A, b) with duplicated rows so that m | N.

    Duplicating an existing row keeps the solution set unchanged (the system
    stays consistent) while making the even split legal.
    """
    A = np.asarray(A)
    b = np.asarray(b)
    N = A.shape[0]
    rem = (-N) % m
    if rem == 0:
        return jnp.asarray(A), jnp.asarray(b)
    # Duplicate the first `rem` rows (scaled by 1.0; projections are invariant
    # to row duplication within a block only up to Gram conditioning, so spread
    # the duplicates across distinct source rows).
    idx = np.arange(rem) % N
    A2 = np.concatenate([A, A[idx]], axis=0)
    b2 = np.concatenate([b, b[idx]], axis=0)
    return jnp.asarray(A2), jnp.asarray(b2)
