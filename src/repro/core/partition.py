"""Row-block partitioning of a linear system across workers.

The paper assumes ``m | N`` and a disjoint even split: machine ``i`` receives
``[A_i, b_i]`` with ``A_i in R^{p x n}``, ``p = N/m``.  We keep that layout but
store the blocks stacked as a single ``(m, p, n)`` array so that the whole
worker fleet can be expressed with ``vmap`` (single host) or ``shard_map``
(mesh) without Python-level per-worker loops.

A system carries two orthogonal tags beyond its blocks:

* ``mode`` — ``"square"`` (an exact solution exists; residuals measure
  ``‖Ax−b‖/‖b‖``) or ``"least_squares"`` (minimize ``‖Ax−b‖``; residuals
  measure the LS optimality ``‖AᵀW(Ax−b)‖``, see ``solvers/api.py``).
  Auto-resolved when not given: ``N == n`` -> square, else least_squares.
  Generators that build CONSISTENT tall systems (``b = A x_true``) tag
  ``mode="square"`` explicitly — an exact solution exists even though
  ``N > n``.
* ``structure`` — ``"dense"`` or ``"sparse"``.  Sparse systems keep the
  dense ``(m, p, n)`` block stack (zeros off-support) PLUS a per-block
  column support ``cols`` (m, w); ``A_op`` exposes the compressed
  :class:`repro.core.blockops.SparseBlocks` operand the solvers consume.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import blockops

MODES = ("square", "least_squares")
STRUCTURES = ("dense", "sparse")


@dataclasses.dataclass(frozen=True)
class BlockSystem:
    """A linear system ``Ax = b`` split into ``m`` row blocks.

    Attributes:
      A_blocks: (m, p, n) stacked row blocks.
      b_blocks: (m, p) stacked right-hand sides.
      x_true:   optional (n,) reference solution for error tracking.
      structure: "dense" | "sparse" (sparse adds the ``cols`` support).
      cols:     (m, w) int32 per-block column support (sparse only);
                padded slots point at all-zero columns so the compressed
                operand is exact.
      mode:     "square" | "least_squares"; auto-resolved from the shape
                when None (N == n -> square).
    """

    A_blocks: jnp.ndarray
    b_blocks: jnp.ndarray
    x_true: Optional[jnp.ndarray] = None
    structure: str = "dense"
    cols: Optional[jnp.ndarray] = None
    mode: Optional[str] = None

    def __post_init__(self):
        if self.structure not in STRUCTURES:
            raise ValueError(f"structure={self.structure!r} not in "
                             f"{STRUCTURES}")
        if self.structure == "sparse" and self.cols is None:
            raise ValueError("sparse systems need a (m, w) cols support; "
                             "build one with partition.as_sparse()")
        if self.mode is None:
            object.__setattr__(
                self, "mode",
                "square" if self.N == self.n else "least_squares")
        elif self.mode not in MODES:
            raise ValueError(f"mode={self.mode!r} not in {MODES}")

    @property
    def m(self) -> int:
        return self.A_blocks.shape[0]

    @property
    def p(self) -> int:
        return self.A_blocks.shape[1]

    @property
    def n(self) -> int:
        return self.A_blocks.shape[2]

    @property
    def N(self) -> int:
        return self.m * self.p

    @property
    def is_sparse(self) -> bool:
        return self.structure == "sparse"

    @property
    def A_op(self):
        """The operand the solvers consume: the dense (m, p, n) stack, or
        the compressed ``SparseBlocks`` support for sparse systems."""
        if not self.is_sparse:
            return self.A_blocks
        vals = jnp.take_along_axis(self.A_blocks, self.cols[:, None, :],
                                   axis=2)
        return blockops.SparseBlocks(
            vals=vals, cols=self.cols,
            span=jnp.zeros((self.n,), self.A_blocks.dtype))

    @property
    def sparsity(self) -> float:
        """Fraction of exactly-zero entries in the block stack."""
        return float((np.asarray(self.A_blocks) == 0).mean())

    def densified(self) -> "BlockSystem":
        """The same system with the dense execution path (parity twin)."""
        return dataclasses.replace(self, structure="dense", cols=None)

    def dense(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Reassemble the global ``(N, n)`` system (for small-n analysis)."""
        return (self.A_blocks.reshape(self.N, self.n),
                self.b_blocks.reshape(self.N))


def partition(A, b, m: int, *, x_true=None, mode=None) -> BlockSystem:
    """Split ``Ax=b`` into ``m`` even row blocks (paper's Figure 1 layout).

    Raises if ``m`` does not divide ``N`` — mirroring the paper's setup; pad
    upstream if needed (``pad_to_blocks``).  ``mode=`` propagates a known
    system mode (e.g. a consistent-by-construction tall system stays
    ``"square"``); left None it resolves from the shape.
    """
    A = jnp.asarray(A)
    b = jnp.asarray(b)
    N, n = A.shape
    if N % m != 0:
        raise ValueError(f"m={m} must divide N={N}; use pad_to_blocks() first")
    p = N // m
    return BlockSystem(A.reshape(m, p, n), b.reshape(m, p),
                       None if x_true is None else jnp.asarray(x_true),
                       mode=mode)


def as_sparse(sys_: BlockSystem) -> BlockSystem:
    """Tag a system sparse, deriving each block's column support from its
    nonzero pattern (padded to the widest block with zero-column indices,
    so the compressed operand stays exact)."""
    A = np.asarray(sys_.A_blocks)
    m, _, n = A.shape
    support = (A != 0).any(axis=1)                       # (m, n)
    w = max(int(support.sum(axis=1).max()), 1)
    cols = np.zeros((m, w), np.int32)
    for i in range(m):
        idx = np.flatnonzero(support[i])
        if idx.size < w:
            # pad with an all-zero column: its gathered values are exact
            # zeros, so duplicates contribute nothing to any contraction
            zero_cols = np.flatnonzero(~support[i])
            idx = np.concatenate(
                [idx, np.full(w - idx.size, zero_cols[0], idx.dtype)])
        cols[i] = idx
    return dataclasses.replace(sys_, structure="sparse",
                               cols=jnp.asarray(cols))


def pad_to_blocks(A, b, m: int):
    """Pad (A, b) with duplicated rows so that m | N.

    Duplicating an existing row keeps the solution set unchanged (the system
    stays consistent) while making the even split legal.
    """
    A = np.asarray(A)
    b = np.asarray(b)
    N = A.shape[0]
    rem = (-N) % m
    if rem == 0:
        return jnp.asarray(A), jnp.asarray(b)
    # Duplicate the first `rem` rows (scaled by 1.0; projections are invariant
    # to row duplication within a block only up to Gram conditioning, so spread
    # the duplicates across distinct source rows).
    idx = np.arange(rem) % N
    A2 = np.concatenate([A, A[idx]], axis=0)
    b2 = np.concatenate([b, b[idx]], axis=0)
    return jnp.asarray(A2), jnp.asarray(b2)
