"""Deprecated shims for the paper's baseline solvers (Section 4).

The implementations moved to the unified ``repro.solvers`` registry — one
lifecycle (prepare/init/step), one result type, ``solve_many`` batched-RHS
and warm-start support for every method.  These wrappers keep the historical
call signatures working:

  dgd        Distributed Gradient Descent                      (Sec 4.1)
  dnag       Distributed Nesterov Accelerated Gradient         (Sec 4.2)
  dhbm       Distributed Heavy-Ball Method                     (Sec 4.3)
  madmm      Modified consensus-ADMM (y_i == 0 speedup)        (Sec 4.4)
  cimmino    Block Cimmino row-projection method               (Sec 4.5)
  consensus  Plain projection consensus of Mou/Liu/Morse [11,14]
  apc        APC via the same uniform record (benchmark drivers)

``History`` is now an alias of ``repro.solvers.SolveResult`` (a strict
superset of the old record: name, x, residuals, errors, params, plus state
and iters_to_tol).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .partition import BlockSystem


def _solve(name: str, sys: BlockSystem, iters: int, **params):
    from repro import solvers
    return solvers.get(name).solve(sys, iters=iters, **params)


def dgd(sys: BlockSystem, *, iters: int = 1000,
        alpha: Optional[float] = None):
    """Distributed gradient descent, Eq. (8)."""
    return _solve("dgd", sys, iters, alpha=alpha)


def dnag(sys: BlockSystem, *, iters: int = 1000,
         alpha: Optional[float] = None, beta: Optional[float] = None):
    """Distributed Nesterov accelerated gradient, Eq. (10)."""
    return _solve("dnag", sys, iters, alpha=alpha, beta=beta)


def dhbm(sys: BlockSystem, *, iters: int = 1000,
         alpha: Optional[float] = None, beta: Optional[float] = None):
    """Distributed heavy-ball method, Eq. (12)."""
    return _solve("dhbm", sys, iters, alpha=alpha, beta=beta)


def madmm(sys: BlockSystem, *, iters: int = 1000, xi: float = 1.0):
    """Modified consensus-ADMM (Sec 4.4)."""
    return _solve("madmm", sys, iters, xi=xi)


def cimmino(sys: BlockSystem, *, iters: int = 1000,
            nu: Optional[float] = None):
    """Block Cimmino: r_i = A_i^+ (b_i - A_i xbar); xbar += nu sum r_i."""
    return _solve("cimmino", sys, iters, nu=nu)


def consensus(sys: BlockSystem, *, iters: int = 1000):
    """Plain projection consensus [11,14]: APC with gamma = eta = 1."""
    return _solve("consensus", sys, iters)


def apc(sys: BlockSystem, *, iters: int = 1000, gamma=None, eta=None):
    """APC through the same uniform record (for benchmark drivers)."""
    return _solve("apc", sys, iters, gamma=gamma, eta=eta)


def _full_grad(sys: BlockSystem, x: jnp.ndarray) -> jnp.ndarray:
    """g = A^T (A x - b), summed over workers (kept for benchmarks/tests)."""
    from repro.solvers.gradient import _grad
    return _grad(sys.A_blocks, sys.b_blocks, x)


ALL_METHODS = {
    "DGD": dgd,
    "D-NAG": dnag,
    "D-HBM": dhbm,
    "M-ADMM": madmm,
    "B-Cimmino": cimmino,
    "Consensus": consensus,
    "APC": apc,
}


def __getattr__(name):
    # Lazy alias (avoids a circular import at package-init time).
    if name == "History":
        from repro.solvers.api import SolveResult
        return SolveResult
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
