"""All baseline distributed solvers from the paper (Section 4).

Each solver mirrors the structure of ``core/apc.py``: a `prepare` step
(one-time per-worker factorization where needed), a jitted per-iteration
update in which the m workers are a vmapped leading axis, and a `solve`
driver recording the relative-error history.  Per-iteration complexity is
O(pn) per worker for every method, matching the paper's claim that iteration
counts are wall-clock-comparable.

Methods:
  dgd        Distributed Gradient Descent                      (Sec 4.1)
  dnag       Distributed Nesterov Accelerated Gradient         (Sec 4.2)
  dhbm       Distributed Heavy-Ball Method                     (Sec 4.3)
  madmm      Modified consensus-ADMM (y_i == 0 speedup)        (Sec 4.4)
  cimmino    Block Cimmino row-projection method               (Sec 4.5)
  consensus  Plain projection consensus of Mou/Liu/Morse [11,14]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .partition import BlockSystem
from . import spectral


@dataclasses.dataclass(frozen=True)
class History:
    """Common result record for every baseline solver."""
    name: str
    x: jnp.ndarray
    residuals: jnp.ndarray            # (T,) ||Ax-b||/||b||
    errors: Optional[jnp.ndarray]     # (T,) ||x-x*||/||x*||
    params: dict


def _run(name: str, sys: BlockSystem, step: Callable, state, extract,
         iters: int, params: dict) -> History:
    """Scan `step` for `iters` iterations recording residual/error of the
    global estimate `extract(state)`."""
    A, b = sys.A_blocks, sys.b_blocks
    b_norm = jnp.sqrt(jnp.sum(b * b))
    xt = sys.x_true
    xt_norm = None if xt is None else jnp.linalg.norm(xt)

    def body(state, _):
        state = step(state)
        x = extract(state)
        r = jnp.einsum("mpn,n->mp", A, x) - b
        res = jnp.sqrt(jnp.sum(r * r)) / b_norm
        err = (jnp.linalg.norm(x - xt) / xt_norm) if xt is not None else res
        return state, (res, err)

    state, (res, err) = jax.lax.scan(jax.jit(body), state, None, length=iters)
    return History(name=name, x=extract(state), residuals=res,
                   errors=err if xt is not None else None, params=params)


# ---------------------------------------------------------------------------
# Gradient family.  Each worker computes its partial gradient
# g_i = A_i^T (A_i x - b_i); the master sums them (psum in the distributed
# runtime, vmap+sum here).
# ---------------------------------------------------------------------------


def _full_grad(sys: BlockSystem, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("mpn,mp->n", sys.A_blocks,
                   jnp.einsum("mpn,n->mp", sys.A_blocks, x) - sys.b_blocks)
    return g


def dgd(sys: BlockSystem, *, iters: int = 1000,
        alpha: Optional[float] = None) -> History:
    """Distributed gradient descent, Eq. (8)."""
    if alpha is None:
        lmin, lmax = spectral.ata_extremes(sys)
        alpha, _ = spectral.dgd_optimal(lmin, lmax)
    x0 = jnp.zeros(sys.n, dtype=sys.A_blocks.dtype)

    def step(x):
        return x - alpha * _full_grad(sys, x)

    return _run("DGD", sys, step, x0, lambda s: s, iters, {"alpha": alpha})


def dnag(sys: BlockSystem, *, iters: int = 1000,
         alpha: Optional[float] = None,
         beta: Optional[float] = None) -> History:
    """Distributed Nesterov accelerated gradient, Eq. (10)."""
    if alpha is None or beta is None:
        lmin, lmax = spectral.ata_extremes(sys)
        a, b_, _ = spectral.dnag_optimal(lmin, lmax)
        alpha = a if alpha is None else alpha
        beta = b_ if beta is None else beta
    n = sys.n
    dt = sys.A_blocks.dtype
    # state: (x, y_prev)
    state0 = (jnp.zeros(n, dt), jnp.zeros(n, dt))

    def step(state):
        x, y_prev = state
        y = x - alpha * _full_grad(sys, x)
        x_new = (1.0 + beta) * y - beta * y_prev
        return (x_new, y)

    return _run("D-NAG", sys, step, state0, lambda s: s[0], iters,
                {"alpha": alpha, "beta": beta})


def dhbm(sys: BlockSystem, *, iters: int = 1000,
         alpha: Optional[float] = None,
         beta: Optional[float] = None) -> History:
    """Distributed heavy-ball method, Eq. (12)."""
    if alpha is None or beta is None:
        lmin, lmax = spectral.ata_extremes(sys)
        a, b_, _ = spectral.dhbm_optimal(lmin, lmax)
        alpha = a if alpha is None else alpha
        beta = b_ if beta is None else beta
    n = sys.n
    dt = sys.A_blocks.dtype
    state0 = (jnp.zeros(n, dt), jnp.zeros(n, dt))   # (x, z)

    def step(state):
        x, z = state
        z_new = beta * z + _full_grad(sys, x)
        return (x - alpha * z_new, z_new)

    return _run("D-HBM", sys, step, state0, lambda s: s[0], iters,
                {"alpha": alpha, "beta": beta})


# ---------------------------------------------------------------------------
# Modified ADMM (Sec 4.4).  Native consensus-ADMM with the y_i-update
# disabled (y_i == 0), which the paper reports as a significant speedup for
# consistent systems.  Each worker solves the p x p (not n x n!) system via
# the matrix inversion lemma:
#   (A^T A + xi I)^{-1} v = (v - A^T (G + xi I)^{-1} A v) / xi.
# ---------------------------------------------------------------------------


def madmm(sys: BlockSystem, *, iters: int = 1000, xi: float = 1.0) -> History:
    A, b = sys.A_blocks, sys.b_blocks
    m, p, n = A.shape
    dt = A.dtype
    eye = jnp.eye(p, dtype=dt)
    # per-worker Cholesky of (G + xi I)
    G = jnp.einsum("mpn,mqn->mpq", A, A)
    chol = jnp.linalg.cholesky(G + xi * eye)

    def inv_apply(Ai, Li, v):
        """(A_i^T A_i + xi I)^{-1} v via matrix inversion lemma."""
        u = Ai @ v
        w = jax.scipy.linalg.cho_solve((Li, True), u)
        return (v - Ai.T @ w) / xi

    Atb = jnp.einsum("mpn,mp->mn", A, b)
    xbar0 = jnp.zeros(n, dt)

    def step(xbar):
        def worker(Ai, Li, Atbi):
            return inv_apply(Ai, Li, Atbi + xi * xbar)
        xi_new = jax.vmap(worker)(A, chol, Atb)
        return jnp.mean(xi_new, axis=0)

    return _run("M-ADMM", sys, step, xbar0, lambda s: s, iters, {"xi": xi})


# ---------------------------------------------------------------------------
# Block Cimmino (Sec 4.5): r_i = A_i^+ (b_i - A_i xbar); xbar += nu sum r_i.
# ---------------------------------------------------------------------------


def cimmino(sys: BlockSystem, *, iters: int = 1000,
            nu: Optional[float] = None) -> History:
    A, b = sys.A_blocks, sys.b_blocks
    m, p, n = A.shape
    dt = A.dtype
    G = jnp.einsum("mpn,mqn->mpq", A, A)
    chol = jnp.linalg.cholesky(G)
    if nu is None:
        X = spectral.x_matrix(sys)
        mu_min, mu_max = spectral.mu_extremes(X)
        nu_m, _ = spectral.cimmino_optimal(mu_min, mu_max)
        nu = nu_m / m
    xbar0 = jnp.zeros(n, dt)

    def step(xbar):
        def worker(Ai, Li, bi):
            return Ai.T @ jax.scipy.linalg.cho_solve((Li, True), bi - Ai @ xbar)
        r = jax.vmap(worker)(A, chol, b)
        return xbar + nu * jnp.sum(r, axis=0)

    return _run("B-Cimmino", sys, step, xbar0, lambda s: s, iters, {"nu": nu})


# ---------------------------------------------------------------------------
# Plain projection consensus [11,14]: APC with gamma = eta = 1 --
# x_i <- x_i + P_i(xbar - x_i); xbar <- mean(x_i).   Rate 1 - mu_min(X).
# ---------------------------------------------------------------------------


def consensus(sys: BlockSystem, *, iters: int = 1000) -> History:
    from . import apc as apc_mod
    factors = apc_mod.prepare(sys)
    state = apc_mod.init_state(factors)

    def step(state):
        return apc_mod.apc_step(factors, state, 1.0, 1.0)

    return _run("Consensus", sys, step, state, lambda s: s.xbar, iters, {})


def apc(sys: BlockSystem, *, iters: int = 1000, gamma=None, eta=None) -> History:
    """APC wrapped in the common History record (for benchmark drivers)."""
    from . import apc as apc_mod
    res = apc_mod.solve(sys, iters=iters, gamma=gamma, eta=eta)
    return History(name="APC", x=res.x, residuals=res.residuals,
                   errors=res.errors, params={})


ALL_METHODS = {
    "DGD": dgd,
    "D-NAG": dnag,
    "D-HBM": dhbm,
    "M-ADMM": madmm,
    "B-Cimmino": cimmino,
    "Consensus": consensus,
    "APC": apc,
}
