"""Spectral analysis and optimal hyper-parameters for APC and all baselines.

Everything in this module is *analysis-time* (taskmaster-side, done once):
forming X = (1/m) sum_i A_i^T (A_i A_i^T)^{-1} A_i, extracting mu_min/mu_max,
and solving the optimality conditions of Theorem 1 for (gamma*, eta*).

The iteration-time code never calls into here; production users may also pass
hand-tuned (gamma, eta).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .partition import BlockSystem

# ---------------------------------------------------------------------------
# The X matrix and its spectrum (paper Eq. (3)-(4))
# ---------------------------------------------------------------------------


def x_matrix(sys: BlockSystem) -> np.ndarray:
    """X = (1/m) sum_i A_i^T (A_i A_i^T)^{-1} A_i   (n x n, symmetric PSD)."""
    A = np.asarray(sys.A_blocks, dtype=np.float64)
    m, p, n = A.shape
    X = np.zeros((n, n), dtype=np.float64)
    for i in range(m):
        Ai = A[i]
        G = Ai @ Ai.T                      # (p, p) Gram
        X += Ai.T @ np.linalg.solve(G, Ai)
    return X / m


def mu_extremes(X: np.ndarray) -> tuple[float, float]:
    """(mu_min, mu_max) of X. Eigenvalues lie in [0, 1] (sum of projections)."""
    w = np.linalg.eigvalsh(X)
    return float(w[0]), float(w[-1])


def kappa(X: np.ndarray) -> float:
    mu_min, mu_max = mu_extremes(X)
    return mu_max / mu_min


def ata_extremes(sys: BlockSystem) -> tuple[float, float]:
    """(lambda_min, lambda_max) of A^T A — drives the gradient-family rates."""
    A, _ = sys.dense()
    A = np.asarray(A, dtype=np.float64)
    w = np.linalg.eigvalsh(A.T @ A)
    return float(w[0]), float(w[-1])


# ---------------------------------------------------------------------------
# Optimal parameters (Theorem 1 and Section 4 closed forms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class APCParams:
    gamma: float
    eta: float
    rho: float  # optimal spectral radius (convergence rate)


def apc_optimal(mu_min: float, mu_max: float) -> APCParams:
    """Solve Theorem 1's optimality system.

      mu_max * eta * gamma = (1 + rho)^2
      mu_min * eta * gamma = (1 - rho)^2,   rho = sqrt((gamma-1)(eta-1))

    Dividing gives rho = (sqrt(kappa)-1)/(sqrt(kappa)+1).  Then with
    s = eta*gamma = (1+rho)^2/mu_max and (gamma-1)(eta-1) = rho^2 we get
    gamma + eta = s + 1 - rho^2, so gamma, eta are the two roots of
    z^2 - (s + 1 - rho^2) z + s = 0.  The discriminant is >= 0 whenever
    mu_max <= 1, which always holds (X is an average of projections).
    """
    if mu_min <= 0:
        raise ValueError("mu_min must be > 0 (system must be solvable)")
    k = mu_max / mu_min
    rho = (math.sqrt(k) - 1.0) / (math.sqrt(k) + 1.0)
    s = (1.0 + rho) ** 2 / mu_max           # eta * gamma
    q = s + 1.0 - rho ** 2                  # eta + gamma
    disc = q * q - 4.0 * s
    disc = max(disc, 0.0)                   # numeric guard (disc==0 @ mu_max=1)
    r = math.sqrt(disc)
    z2 = (q + r) / 2.0                      # large root: no cancellation
    z1 = s / z2 if z2 > 0 else 0.0          # small root via product z1*z2 = s
    #  ((q - r)/2 cancels catastrophically when s >> 1, i.e. tiny mu_max)
    # gamma must lie in [0, 2] (set S definition); the smaller root does.
    gamma, eta = (z1, z2) if z1 <= 2.0 else (z2, z1)
    return APCParams(gamma=gamma, eta=eta, rho=rho)


def apc_rate(mu_min: float, mu_max: float) -> float:
    return apc_optimal(mu_min, mu_max).rho


def dgd_optimal(lmin: float, lmax: float) -> tuple[float, float]:
    """(alpha*, rho*) for distributed gradient descent on ||Ax-b||^2.

    Gradient iteration matrix I - alpha A^T A; optimal alpha = 2/(lmin+lmax),
    rho = (kappa-1)/(kappa+1).
    """
    alpha = 2.0 / (lmin + lmax)
    rho = (lmax - lmin) / (lmax + lmin)
    return alpha, rho


def dnag_optimal(lmin: float, lmax: float) -> tuple[float, float, float]:
    """(alpha*, beta*, rho*) for Nesterov on a quadratic (Lessard et al. [9]).

    alpha = 4/(3 lmax + lmin), beta = (sqrt(3 kappa + 1) - 2)/(sqrt(3 kappa+1)+2),
    rho = 1 - 2/sqrt(3 kappa + 1).
    """
    k = lmax / lmin
    alpha = 4.0 / (3.0 * lmax + lmin)
    s = math.sqrt(3.0 * k + 1.0)
    beta = (s - 2.0) / (s + 2.0)
    rho = 1.0 - 2.0 / s
    return alpha, beta, rho


def dhbm_optimal(lmin: float, lmax: float) -> tuple[float, float, float]:
    """(alpha*, beta*, rho*) for heavy-ball on a quadratic (Polyak [16]).

    alpha = (2/(sqrt(lmax)+sqrt(lmin)))^2, beta = rho^2,
    rho = (sqrt(kappa)-1)/(sqrt(kappa)+1).
    """
    sl, sm = math.sqrt(lmax), math.sqrt(lmin)
    alpha = (2.0 / (sl + sm)) ** 2
    rho = (sl - sm) / (sl + sm)
    beta = rho ** 2
    return alpha, beta, rho


def cimmino_optimal(mu_min: float, mu_max: float) -> tuple[float, float]:
    """(nu*, rho*) for the block Cimmino method.

    Error iteration: e(t+1) = (I - nu m X) e(t); optimal nu = 2/(m(mu_min+mu_max))
    gives rho = (kappa-1)/(kappa+1).  We return nu*m (caller divides by m).
    """
    nu_m = 2.0 / (mu_min + mu_max)
    rho = (mu_max - mu_min) / (mu_max + mu_min)
    return nu_m, rho


def consensus_rate(mu_min: float) -> float:
    """Plain projection-consensus [11,14]: rho = 1 - mu_min(X)."""
    return 1.0 - mu_min


def convergence_time(rho: float) -> float:
    """T = 1 / (-log rho)   (paper Section 5; ~ 1/(1-rho))."""
    if rho >= 1.0:
        return float("inf")
    if rho <= 0.0:
        return 0.0
    return 1.0 / (-math.log(rho))


# ---------------------------------------------------------------------------
# One-call summary used by benchmarks (Table 1 / Table 2 reproduction)
# ---------------------------------------------------------------------------


def rates_summary(sys: BlockSystem) -> dict[str, float]:
    """Optimal convergence rates of every method in the paper for `sys`."""
    X = x_matrix(sys)
    mu_min, mu_max = mu_extremes(X)
    lmin, lmax = ata_extremes(sys)
    _, rho_dgd = dgd_optimal(lmin, lmax)
    _, _, rho_nag = dnag_optimal(lmin, lmax)
    _, _, rho_hbm = dhbm_optimal(lmin, lmax)
    _, rho_cim = cimmino_optimal(mu_min, mu_max)
    apc = apc_optimal(mu_min, mu_max)
    return {
        "mu_min": mu_min,
        "mu_max": mu_max,
        "kappa_X": mu_max / mu_min,
        "kappa_AtA": lmax / lmin,
        "DGD": rho_dgd,
        "D-NAG": rho_nag,
        "D-HBM": rho_hbm,
        "Consensus": consensus_rate(mu_min),
        "B-Cimmino": rho_cim,
        "APC": apc.rho,
    }
