"""Mesh-distributed APC via shard_map (the production runtime).

Mapping of the paper's roles onto a TPU mesh (see DESIGN.md §2):

  * worker i            -> a slice of the ``data`` mesh axis (m workers).
  * taskmaster          -> no physical node; the master update (Eq. 2b) is a
                           ``psum`` over the ``data`` axis.
  * each worker's block -> optionally column-sharded along ``model`` so that
                           A_i (p x n) with n ~ 10^6+ fits per-device memory.

Data layout (global shapes; P = PartitionSpec):
  A_blocks (m, p, n)  sharded P("data", None, "model")
  b_blocks (m, p)     sharded P("data", None)
  chol     (m, p, p)  sharded P("data", None, None)   (replicated over model)
  x        (m, n)     sharded P("data", "model")
  xbar     (n,)       sharded P("model")              (replicated over data)

Per iteration, the collectives are exactly:
  1. psum over ``model`` of the p-vector A_i d        (worker-local GEMV glue)
  2. psum over ``data`` of the n-shard of x_i          (master averaging)
Both are latency-friendly: (1) moves m*p floats, (2) moves n floats, per
iteration, versus the 2pn FLOPs of the matvecs — arithmetic intensity grows
linearly in n/m.

Multi-pod: the ``pod`` axis (when present) is folded into worker parallelism —
blocks shard over ("pod","data") jointly and the Eq. 2b psum runs over both
axes.  This is DP-style scaling of m with no code change (see launch/mesh.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax.shard_map is the stable spelling on newer releases
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from .partition import BlockSystem
from . import spectral


@dataclasses.dataclass(frozen=True)
class ShardedAPC:
    """Compiled distributed APC solver bound to a mesh."""
    mesh: Mesh
    worker_axes: Tuple[str, ...]   # axes the m workers shard over
    model_axis: Optional[str]      # axis the n dimension shards over
    gamma: float
    eta: float

    # ----- shardings ------------------------------------------------------
    def specs(self):
        wa = self.worker_axes if len(self.worker_axes) > 1 else self.worker_axes[0]
        ma = self.model_axis
        return {
            "A": P(wa, None, ma),
            "b": P(wa, None),
            "chol": P(wa, None, None),
            "x": P(wa, ma),
            "xbar": P(ma),
        }

    # ----- one APC iteration, shard_map body ------------------------------
    def _step_body(self, A, chol, x, xbar):
        """Executes on one device: local shard of every array.

        A    (m_loc, p, n_loc)   chol (m_loc, p, p)
        x    (m_loc, n_loc)      xbar (n_loc,)
        """
        gamma, eta = self.gamma, self.eta
        m_axes = self.worker_axes

        d = xbar[None, :] - x                             # (m_loc, n_loc)
        u = jnp.einsum("mpn,mn->mp", A, d)                # partial A_i d
        if self.model_axis is not None:
            u = jax.lax.psum(u, self.model_axis)          # full A_i d
        w = jax.vmap(lambda L, ui: jax.scipy.linalg.cho_solve((L, True), ui))(
            chol, u)                                      # G^{-1} A_i d
        proj = d - jnp.einsum("mpn,mp->mn", A, w)         # P_i d (n_loc shard)
        x_new = x + gamma * proj                          # Eq. 2a

        # Eq. 2b: master averaging == psum over every worker axis.
        m_total = x.shape[0]
        for ax in m_axes:
            m_total = m_total * self.mesh.shape[ax]
        s = jnp.sum(x_new, axis=0)
        s = jax.lax.psum(s, m_axes)
        xbar_new = (eta / m_total) * s + (1.0 - eta) * xbar
        return x_new, xbar_new

    def step_fn(self):
        sp = self.specs()
        return jax.jit(_shard_map(
            self._step_body, mesh=self.mesh,
            in_specs=(sp["A"], sp["chol"], sp["x"], sp["xbar"]),
            out_specs=(sp["x"], sp["xbar"]),
        ))

    # ----- residual (for convergence monitoring / fault recovery) ---------
    def _residual_body(self, A, b, xbar):
        r = jnp.einsum("mpn,n->mp", A, xbar)
        if self.model_axis is not None:
            r = jax.lax.psum(r, self.model_axis)
        r = r - b
        ss = jnp.sum(r * r)
        ss = jax.lax.psum(ss, self.worker_axes)
        bs = jnp.sum(b * b)
        bs = jax.lax.psum(bs, self.worker_axes)
        return jnp.sqrt(ss) / jnp.sqrt(bs)

    def residual_fn(self):
        sp = self.specs()
        return jax.jit(_shard_map(
            self._residual_body, mesh=self.mesh,
            in_specs=(sp["A"], sp["b"], sp["xbar"]),
            out_specs=P(),
        ))


def make_sharded_apc(mesh: Mesh, *, worker_axes: Sequence[str] = ("data",),
                     model_axis: Optional[str] = "model",
                     gamma: float, eta: float) -> ShardedAPC:
    if model_axis is not None and model_axis not in mesh.axis_names:
        model_axis = None
    worker_axes = tuple(a for a in worker_axes if a in mesh.axis_names)
    return ShardedAPC(mesh=mesh, worker_axes=worker_axes,
                      model_axis=model_axis, gamma=gamma, eta=eta)


# ---------------------------------------------------------------------------
# Host-side driver: place a BlockSystem on the mesh and run APC.
# ---------------------------------------------------------------------------


def prepare_on_mesh(solver: ShardedAPC, sys: BlockSystem):
    """Factorize Gram matrices and build the initial state, all on-mesh.

    The Gram/Cholesky/x0 computation runs as a shard_mapped setup step so no
    single host ever materializes the full A.
    """
    sp = solver.specs()
    mesh = solver.mesh

    def setup(A, b):
        # A (m_loc, p, n_loc), b (m_loc, p)
        G = jnp.einsum("mpn,mqn->mpq", A, A)
        if solver.model_axis is not None:
            G = jax.lax.psum(G, solver.model_axis)
        L = jnp.linalg.cholesky(G)
        w = jax.vmap(lambda Li, bi: jax.scipy.linalg.cho_solve((Li, True), bi))(
            L, b)
        x0 = jnp.einsum("mpn,mp->mn", A, w)              # min-norm local sol
        m_total = A.shape[0]
        for ax in solver.worker_axes:
            m_total = m_total * solver.mesh.shape[ax]
        xbar0 = jax.lax.psum(jnp.sum(x0, axis=0), solver.worker_axes) / m_total
        return L, x0, xbar0

    setup_fn = jax.jit(_shard_map(
        setup, mesh=mesh, in_specs=(sp["A"], sp["b"]),
        out_specs=(sp["chol"], sp["x"], sp["xbar"])))

    A = jax.device_put(sys.A_blocks, NamedSharding(mesh, sp["A"]))
    b = jax.device_put(sys.b_blocks, NamedSharding(mesh, sp["b"]))
    chol, x0, xbar0 = setup_fn(A, b)
    return A, b, chol, x0, xbar0


def solve_on_mesh(mesh: Mesh, sys: BlockSystem, *, iters: int = 500,
                  gamma: Optional[float] = None, eta: Optional[float] = None,
                  worker_axes: Sequence[str] = ("data",),
                  model_axis: Optional[str] = "model"):
    """End-to-end distributed solve (used by launch/solve.py and tests)."""
    if gamma is None or eta is None:
        X = spectral.x_matrix(sys)
        mu_min, mu_max = spectral.mu_extremes(X)
        prm = spectral.apc_optimal(mu_min, mu_max)
        gamma = prm.gamma if gamma is None else gamma
        eta = prm.eta if eta is None else eta
    solver = make_sharded_apc(mesh, worker_axes=worker_axes,
                              model_axis=model_axis, gamma=gamma, eta=eta)
    A, b, chol, x, xbar = prepare_on_mesh(solver, sys)
    step = solver.step_fn()
    res_fn = solver.residual_fn()

    @jax.jit
    def run(A, chol, x, xbar):
        def body(carry, _):
            x, xbar = carry
            x, xbar = step(A, chol, x, xbar)
            return (x, xbar), None
        (x, xbar), _ = jax.lax.scan(body, (x, xbar), None, length=iters)
        return x, xbar

    x, xbar = run(A, chol, x, xbar)
    return xbar, float(res_fn(A, b, xbar))
