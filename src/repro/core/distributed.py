"""Mesh-distributed APC — now a thin shim over ``repro.solvers.mesh``.

The general mesh execution backend lives in ``repro.solvers.mesh``: ANY
registered solver runs sharded via ``solvers.get(name).solve(sys,
backend="mesh", mesh=...)``, with the worker blocks on the ``data`` axis
(the Eq. 2b master update is a psum — the taskmaster has no physical node)
and the n dimension optionally cut along ``model``.  See that module for
the data layout and collective structure.

This module keeps the APC-specialized surface the fault-tolerance runtime
and older callers use — ``ShardedAPC`` (a compiled per-iteration step +
residual monitor over raw (A, chol, x, xbar) arrays, e.g. for the elastic
remesh cycle in ``runtime/fault.py``) and the ``solve_on_mesh`` one-call
driver — all delegating to the backend's APC hooks so the iteration math
exists in exactly one place (``solvers/projection.py``).

Imports of ``repro.solvers`` are deferred into the methods: ``repro.core``
loads this module eagerly while the solver registry is itself importing
``repro.core`` building blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax.shard_map is the stable spelling on newer releases
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from .partition import BlockSystem


@dataclasses.dataclass(frozen=True)
class ShardedAPC:
    """Compiled distributed APC solver bound to a mesh."""
    mesh: Mesh
    worker_axes: Tuple[str, ...]   # axes the m workers shard over
    model_axis: Optional[str]      # axis the n dimension shards over
    gamma: float
    eta: float

    # ----- backend plumbing ----------------------------------------------
    def _ctx(self):
        from repro.solvers.mesh import MeshContext
        return MeshContext(mesh=self.mesh, worker_axes=self.worker_axes,
                           model_axis=self.model_axis)

    def _solver(self):
        from repro import solvers
        return solvers.get("apc")

    def _params(self):
        return {"gamma": self.gamma, "eta": self.eta}

    # ----- shardings ------------------------------------------------------
    def specs(self):
        wa = self.worker_axes if len(self.worker_axes) > 1 else self.worker_axes[0]
        ma = self.model_axis
        return {
            "A": P(wa, None, ma),
            "b": P(wa, None),
            "chol": P(wa, None, None),
            "x": P(wa, ma),
            "xbar": P(ma),
        }

    # ----- one APC iteration over raw arrays ------------------------------
    def step_fn(self):
        """jit(shard_map) of (A, chol, x, xbar) -> (x, xbar), one Eq. 2a/2b
        iteration — the raw-array surface the elastic runtime drives."""
        from repro.core.apc import APCState
        from repro.solvers.projection import ProjFactors
        ctx, solver, prm = self._ctx(), self._solver(), self._params()

        def body(A, chol, x, xbar):
            st = solver.mesh_step(
                ProjFactors(A=A, chol=chol), None,
                APCState(x=x, xbar=xbar, t=jnp.zeros((), jnp.int32)),
                prm, ctx)
            return st.x, st.xbar

        sp = self.specs()
        return jax.jit(_shard_map(
            body, mesh=self.mesh,
            in_specs=(sp["A"], sp["chol"], sp["x"], sp["xbar"]),
            out_specs=(sp["x"], sp["xbar"]),
        ))

    # ----- residual (for convergence monitoring / fault recovery) ---------
    def residual_fn(self):
        from repro.solvers.mesh import residual_shard
        ctx = self._ctx()

        def body(A, b, xbar):
            b_norm = jnp.sqrt(ctx.psum_workers(jnp.sum(b * b)))
            return residual_shard(A, b, xbar, b_norm, ctx)

        sp = self.specs()
        return jax.jit(_shard_map(
            body, mesh=self.mesh,
            in_specs=(sp["A"], sp["b"], sp["xbar"]),
            out_specs=P(),
        ))


def make_sharded_apc(mesh: Mesh, *, worker_axes: Sequence[str] = ("data",),
                     model_axis: Optional[str] = "model",
                     gamma: float, eta: float) -> ShardedAPC:
    if model_axis is not None and model_axis not in mesh.axis_names:
        model_axis = None
    worker_axes = tuple(a for a in worker_axes if a in mesh.axis_names)
    return ShardedAPC(mesh=mesh, worker_axes=worker_axes,
                      model_axis=model_axis, gamma=gamma, eta=eta)


# ---------------------------------------------------------------------------
# Host-side driver: place a BlockSystem on the mesh and run APC.
# ---------------------------------------------------------------------------


def prepare_on_mesh(solver: ShardedAPC, sys: BlockSystem):
    """Factorize Gram matrices and build the initial state, all on-mesh.

    The Gram/Cholesky/x0 computation runs as a shard_mapped setup step so no
    single host ever materializes the full A.
    """
    ctx, apc, prm = solver._ctx(), solver._solver(), solver._params()
    sp = solver.specs()
    mesh = solver.mesh

    def setup(A, b):
        factors = apc.mesh_prepare(A, prm, ctx)
        st = apc.mesh_init(factors, b, prm, ctx)
        return factors.chol, st.x, st.xbar

    setup_fn = jax.jit(_shard_map(
        setup, mesh=mesh, in_specs=(sp["A"], sp["b"]),
        out_specs=(sp["chol"], sp["x"], sp["xbar"])))

    A = jax.device_put(sys.A_blocks, NamedSharding(mesh, sp["A"]))
    b = jax.device_put(sys.b_blocks, NamedSharding(mesh, sp["b"]))
    chol, x0, xbar0 = setup_fn(A, b)
    return A, b, chol, x0, xbar0


def solve_on_mesh(mesh: Mesh, sys: BlockSystem, *, iters: int = 500,
                  gamma: Optional[float] = None, eta: Optional[float] = None,
                  worker_axes: Sequence[str] = ("data",),
                  model_axis: Optional[str] = "model"):
    """End-to-end distributed APC (legacy surface; returns (xbar, residual)).

    New code should call the backend directly for the full ``SolveResult``:
    ``solvers.get(name).solve(sys, backend="mesh", mesh=mesh)``.
    """
    from repro import solvers
    from repro.solvers.mesh import solve_mesh
    res = solve_mesh(solvers.get("apc"), sys, mesh=mesh, iters=iters,
                     worker_axes=worker_axes, model_axis=model_axis,
                     gamma=gamma, eta=eta)
    return res.x, float(res.residuals[-1])
