"""Distributed preconditioning (paper Section 6).

Each worker premultiplies its local system by (A_i A_i^T)^{-1/2}, locally and
in parallel (O(p^2 n) one-time work).  The transformed global system
C x = d has kappa(C^T C) = kappa(X), so distributed heavy-ball on it attains
the APC rate (sqrt(kappa(X))-1)/(sqrt(kappa(X))+1).

This is the paper's 'further implication': the preconditioner ports APC's
conditioning advantage to *any* gradient-based distributed method.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from .partition import BlockSystem
from . import spectral  # noqa: F401  (re-exported for analysis callers)


def _inv_sqrt_psd(G: np.ndarray) -> np.ndarray:
    """G^{-1/2} for symmetric PD G via eigendecomposition (float64 host)."""
    w, V = np.linalg.eigh(G)
    w = np.maximum(w, 1e-300)
    return (V / np.sqrt(w)) @ V.T


def precondition(sys: BlockSystem) -> BlockSystem:
    """Return the transformed system C x = d (same solution set)."""
    A = np.asarray(sys.A_blocks, dtype=np.float64)
    b = np.asarray(sys.b_blocks, dtype=np.float64)
    m = A.shape[0]
    C = np.empty_like(A)
    d = np.empty_like(b)
    for i in range(m):
        S = _inv_sqrt_psd(A[i] @ A[i].T)
        C[i] = S @ A[i]
        d[i] = S @ b[i]
    dt = sys.A_blocks.dtype
    return BlockSystem(jnp.asarray(C, dt), jnp.asarray(d, dt), sys.x_true)


def preconditioned_dhbm(sys: BlockSystem, *, iters: int = 1000,
                        alpha: Optional[float] = None,
                        beta: Optional[float] = None):
    """Deprecated shim — delegates to ``repro.solvers.get("pdhbm")``.

    Note C^T C = m X exactly, so the optimal (alpha, beta) are derived from
    the spectrum of X without re-running an eigensolve on C.
    """
    from repro import solvers
    return solvers.get("pdhbm").solve(sys, iters=iters, alpha=alpha, beta=beta)
