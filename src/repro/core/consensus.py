"""Generic accelerated-consensus combinator (beyond-paper).

APC's structure — (local contraction toward a global estimate) + (master
averaging with one-step memory) — is not specific to linear systems.  This
module exposes it as a reusable template:

    x_i(t+1) = local_step_i(x_i(t), xbar(t))            # any per-shard map
    xbar(t+1) = (eta/m) sum_i x_i(t+1) + (1-eta) xbar(t)

Instantiations in this repo:
  * APC itself: local_step = x + gamma * P_i(xbar - x)        (core/apc.py)
  * local-SGD style training: local_step = k optimizer steps on shard-local
    data; the eta-momentum average replaces naive parameter averaging
    (examples/local_sgd.py).

The combinator is pytree-generic: x_i may be an arbitrary parameter pytree.
"""
from __future__ import annotations

from typing import TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")


def master_average(x_stack: T, xbar: T, eta: float) -> T:
    """Eq. (2b) on pytrees: leaves of x_stack have a leading worker axis."""
    return jax.tree.map(
        lambda xs, xb: eta * jnp.mean(xs, axis=0) + (1.0 - eta) * xb,
        x_stack, xbar)


def consensus_round(local_step, x_stack: T, xbar: T, eta: float,
                    context=None) -> tuple[T, T]:
    """One full round: vmapped local steps then momentum-averaged master.

    context: optional per-worker pytree (leading worker axis) passed to
    ``local_step(context_i, x_i, xbar)`` but NOT averaged — factorizations,
    local data shards, optimizer state, etc.
    """
    if context is None:
        x_new = jax.vmap(lambda x, xb: local_step(None, x, xb),
                         in_axes=(0, None))(x_stack, xbar)
    else:
        x_new = jax.vmap(local_step, in_axes=(0, 0, None))(
            context, x_stack, xbar)
    return x_new, master_average(x_new, xbar, eta)


def run_consensus(local_step, x_stack: T, xbar: T, *, eta: float,
                  rounds: int, context=None) -> tuple[T, T]:
    """lax.scan-driven consensus loop (jit-friendly)."""
    def body(carry, _):
        xs, xb = carry
        xs, xb = consensus_round(local_step, xs, xb, eta, context)
        return (xs, xb), None
    (x_stack, xbar), _ = jax.lax.scan(body, (x_stack, xbar), None,
                                      length=rounds)
    return x_stack, xbar
