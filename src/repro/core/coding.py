"""DEPRECATED shim — straggler-tolerant execution moved to the registry.

The r-redundant cyclic assignment, selection weights, and the redundant
solve driver now live in ``repro.solvers.redundant`` as a first-class
option of the unified solver API:

    from repro import solvers
    res = solvers.get("apc").solve(sys, redundancy=r,
                                   alive_schedule=lambda t: mask_t)

which runs the whole projection family (``apc``, ``consensus``,
``cimmino``) on BOTH backends (local jitted scan / shard_map mesh) with
warm starts and checkpoints, replacing this module's APC-only host-loop
reference driver.  The exactness invariant (an iteration under any
covering alive-mask equals the no-failure iteration) is documented and
enforced there.

Kept here: the legacy entry points as thin delegations so existing
callers keep working.  The previously documented ``seed`` parameter of
``solve_redundant`` was dead (initialization is the deterministic
min-norm solution — there is nothing to seed) and has been REMOVED
rather than silently ignored.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .partition import BlockSystem


@dataclasses.dataclass(frozen=True)
class RedundantSystem:
    """Cyclic r-redundant replication of a BlockSystem.

    A_rep[i, k] = A_blocks[(i + k) % m]  for k in [0, r).
    """
    base: BlockSystem
    r: int
    A_rep: jnp.ndarray    # (m, r, p, n)
    b_rep: jnp.ndarray    # (m, r, p)

    @property
    def holder_of(self) -> np.ndarray:
        """(m, r) holder_of[i, k] = block id held in slot k of worker i."""
        from repro.solvers.redundant import Assignment
        return Assignment(m=self.base.m, r=self.r).holder


def replicate(sys: BlockSystem, r: int) -> RedundantSystem:
    from repro.solvers.redundant import Assignment, replicate_system
    if not (1 <= r <= sys.m):
        raise ValueError(f"redundancy r={r} must be in [1, m={sys.m}]")
    A_rep, b_rep = replicate_system(sys, Assignment(m=sys.m, r=r))
    return RedundantSystem(base=sys, r=r, A_rep=A_rep, b_rep=b_rep)


def selection_weights(alive: np.ndarray, m: int, r: int) -> np.ndarray:
    """Deprecated alias of ``repro.solvers.redundant.selection_weights``."""
    from repro.solvers.redundant import selection_weights as sw
    return sw(alive, m, r)


def solve_redundant(sys: BlockSystem, r: int, *, iters: int = 500,
                    gamma=None, eta=None, alive_schedule=None):
    """Deprecated shim over ``solvers.get("apc").solve(redundancy=r, ...)``.

    Returns the legacy ``(xbar, residuals)`` tuple; new code should call
    the registry API directly and use the full ``SolveResult``.
    """
    from repro import solvers
    res = solvers.get("apc").solve(
        sys, iters=iters,
        plan=solvers.ExecutionPlan(redundancy=r,
                                   alive_schedule=alive_schedule),
        gamma=gamma, eta=eta)
    return res.x, np.asarray(res.residuals)
