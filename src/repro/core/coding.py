"""Straggler-tolerant block assignment (beyond-paper; refs [10, 20]).

The paper's taskmaster must wait for *all* m machines each iteration — one
straggler stalls the fleet.  We add an r-redundant cyclic assignment in the
style of gradient coding [20]: worker i holds blocks {i, i+1, ..., i+r-1 mod
m}.  Any iteration can then be completed from the responses of workers whose
union of blocks covers {0..m-1}; with r-redundancy, ANY m - r + 1 workers
suffice.

The master's Eq. (2b) average needs each block's x_j exactly once.  Given the
alive-mask a ∈ {0,1}^m, we pick for each block j its lowest-index alive
holder (deterministic, no communication needed — the mask is broadcast with
the heartbeat, see runtime/fault.py), expressed as a weight matrix
W(a) ∈ {0,1}^{m x r} so the masked mean stays a single psum.

Semantics are EXACT, not approximate: an iteration with stragglers computes
the same x̄(t+1) as a non-redundant iteration over all m blocks, because each
block's update x_j(t+1) only depends on (x_j(t), x̄(t)) — every replica of
block j holds an identical copy of x_j(t).  (Replicas apply identical,
deterministic updates from identical inputs, so they never diverge while
alive; a worker that *rejoins* must refresh its replicas from a live holder —
runtime/fault.py handles that resync.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .partition import BlockSystem
from . import apc as apc_mod
from . import spectral


@dataclasses.dataclass(frozen=True)
class RedundantSystem:
    """Cyclic r-redundant replication of a BlockSystem.

    A_rep[i, k] = A_blocks[(i + k) % m]  for k in [0, r).
    """
    base: BlockSystem
    r: int
    A_rep: jnp.ndarray    # (m, r, p, n)
    b_rep: jnp.ndarray    # (m, r, p)

    @property
    def holder_of(self) -> np.ndarray:
        """(m, r) holder_of[i, k] = block id held in slot k of worker i."""
        m = self.base.m
        return (np.arange(m)[:, None] + np.arange(self.r)[None, :]) % m


def replicate(sys: BlockSystem, r: int) -> RedundantSystem:
    m = sys.m
    if not (1 <= r <= m):
        raise ValueError(f"redundancy r={r} must be in [1, m={m}]")
    idx = (np.arange(m)[:, None] + np.arange(r)[None, :]) % m
    return RedundantSystem(base=sys, r=r,
                           A_rep=sys.A_blocks[idx], b_rep=sys.b_blocks[idx])


def selection_weights(alive: np.ndarray, m: int, r: int) -> np.ndarray:
    """W ∈ {0,1}^{m x r}: W[i,k]=1 iff worker i is the designated provider of
    the block in its slot k.  Provider = lowest-index alive holder.

    Raises if some block has no alive holder (fleet lost > r-1 'adjacent'
    workers); the runtime then falls back to a full re-partition (fault.py).
    """
    alive = np.asarray(alive, dtype=bool)
    holder = (np.arange(m)[:, None] + np.arange(r)[None, :]) % m
    W = np.zeros((m, r))
    for blk in range(m):
        # workers holding blk: i = (blk - k) mod m  at slot k
        providers = [((blk - k) % m, k) for k in range(r)]
        providers = [(i, k) for (i, k) in providers if alive[i]]
        if not providers:
            raise RuntimeError(
                f"block {blk} unrecoverable: no alive holder (r={r})")
        i, k = min(providers)
        W[i, k] = 1.0
    return W


def apc_step_redundant(rsys: RedundantSystem, chol_rep, x_rep, xbar,
                       gamma: float, eta: float, W: jnp.ndarray):
    """One APC iteration under an alive-mask selection matrix W.

    x_rep (m, r, n): slot k of worker i carries x_{(i+k)%m}.  Dead workers'
    entries are simply ignored by W; their local state is stale but unused.
    """
    m = rsys.base.m

    def worker(A_i, L_i, x_i):
        # A_i (r, p, n), x_i (r, n): update every held replica.
        def slot(Ak, Lk, xk):
            d = xbar - xk
            u = jax.scipy.linalg.cho_solve((Lk, True), Ak @ d)
            return xk + gamma * (d - Ak.T @ u)
        return jax.vmap(slot)(A_i, L_i, x_i)

    x_new = jax.vmap(worker)(rsys.A_rep, chol_rep, x_rep)     # (m, r, n)
    # masked mean: each block contributes exactly once via W.
    s = jnp.einsum("mk,mkn->n", W, x_new)
    xbar_new = (eta / m) * s + (1.0 - eta) * xbar
    return x_new, xbar_new


def solve_redundant(sys: BlockSystem, r: int, *, iters: int = 500,
                    gamma: Optional[float] = None, eta: Optional[float] = None,
                    alive_schedule=None, seed: int = 0):
    """Reference driver: run redundant APC under a (possibly time-varying)
    alive schedule.  alive_schedule: callable t -> bool mask (m,), or None
    for all-alive."""
    if gamma is None or eta is None:
        X = spectral.x_matrix(sys)
        prm = spectral.apc_optimal(*spectral.mu_extremes(X))
        gamma = prm.gamma if gamma is None else gamma
        eta = prm.eta if eta is None else eta

    rsys = replicate(sys, r)
    m, r_, p, n = rsys.A_rep.shape
    G = jnp.einsum("mrpn,mrqn->mrpq", rsys.A_rep, rsys.A_rep)
    chol = jnp.linalg.cholesky(G)
    w0 = jax.vmap(jax.vmap(
        lambda L, b: jax.scipy.linalg.cho_solve((L, True), b)))(chol, rsys.b_rep)
    x0 = jnp.einsum("mrpn,mrp->mrn", rsys.A_rep, w0)
    # init xbar from block-unique average (all alive at t=0)
    W_all = jnp.asarray(selection_weights(np.ones(m, bool), m, r))
    xbar = jnp.einsum("mk,mkn->n", W_all, x0) / m

    x_rep = x0
    residuals = []
    A, b = sys.A_blocks, sys.b_blocks
    b_norm = float(jnp.sqrt(jnp.sum(b * b)))
    step = jax.jit(lambda xr, xb, W: apc_step_redundant(
        rsys, chol, xr, xb, gamma, eta, W))
    for t in range(iters):
        alive = (np.ones(m, bool) if alive_schedule is None
                 else np.asarray(alive_schedule(t), dtype=bool))
        W = jnp.asarray(selection_weights(alive, m, r))
        x_rep, xbar = step(x_rep, xbar, W)
        res = jnp.einsum("mpn,n->mp", A, xbar) - b
        residuals.append(float(jnp.sqrt(jnp.sum(res * res))) / b_norm)
    return xbar, np.asarray(residuals)
