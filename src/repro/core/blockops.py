"""Block-matrix operations over dense or block-sparse worker blocks.

Every solver expresses its per-iteration linear algebra through the
small operator set below instead of hard-coding ``jnp.einsum`` on a
dense ``(m, p, n)`` stack.  The dense branches use the *identical*
einsum contractions the solvers always used, so routing a dense system
through these helpers is bit-exact; the sparse branches act on a
:class:`SparseBlocks` operand — a BSR-style per-block column support —
and touch only each block's nonzero columns.

Representation.  Block ``i`` of a sparse system stores its ``w``
supported column indices ``cols[i]`` and the ``(p, w)`` values on that
support.  Blocks with smaller support are padded up to the common ``w``
with indices of all-zero columns, so padded entries carry exact zeros
and every contraction below (including the Gram products) is exact —
no masking needed.  ``cols`` always indexes the GLOBAL ``n`` axis,
which is why the mesh backend shards sparse systems over worker axes
only (see ``solvers/mesh.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SparseBlocks(NamedTuple):
    """Block-sparse operand: per-block column support + values.

    Attributes:
      vals: (m, p, w) values of each block on its column support.
      cols: (m, w) int32 global column indices; padded slots point at
        all-zero columns so their values are exact zeros.
      span: (n,) zeros — a static-shape carrier for the global column
        count, which no other field records (``cols.max()+1`` would
        under-estimate it and is traced anyway).  Replicated on meshes.
    """

    vals: jnp.ndarray
    cols: jnp.ndarray
    span: jnp.ndarray


def is_sparse(A) -> bool:
    return isinstance(A, SparseBlocks)


def ncols(A) -> int:
    """Global column count ``n`` of either operand kind (trace-static)."""
    if is_sparse(A):
        return A.span.shape[0]
    return A.shape[2]


def bmatvec(A, x):
    """Per-block matvec ``A_i x`` -> (m, p) for a shared ``(n,)`` x."""
    if is_sparse(A):
        return jnp.einsum("mpw,mw->mp", A.vals, x[A.cols])
    return jnp.einsum("mpn,n->mp", A, x)


def bmatvec_each(A, D):
    """Per-block matvec ``A_i d_i`` -> (m, p) for per-block ``(m, n)`` D."""
    if is_sparse(A):
        d = jnp.take_along_axis(D, A.cols, axis=1)
        return jnp.einsum("mpw,mw->mp", A.vals, d)
    return jnp.einsum("mpn,mn->mp", A, D)


def bmatvec_many(A, X):
    """Batched ``A_i x_k`` -> (k, m, p) for a ``(k, n)`` RHS batch."""
    if is_sparse(A):
        return jnp.einsum("mpw,kmw->kmp", A.vals, X[:, A.cols])
    return jnp.einsum("mpn,kn->kmp", A, X)


def brmatvec(A, u):
    """Per-block transpose matvec ``A_i^T u_i`` -> (m, n)."""
    if is_sparse(A):
        contr = jnp.einsum("mpw,mp->mw", A.vals, u)
        rows = jnp.arange(A.cols.shape[0])[:, None]
        return jnp.zeros((A.cols.shape[0], ncols(A)), contr.dtype).at[
            rows, A.cols].add(contr)
    return jnp.einsum("mpn,mp->mn", A, u)


def brmatvec_sum(A, u):
    """Summed transpose matvec ``sum_i A_i^T u_i`` -> (n,)."""
    if is_sparse(A):
        contr = jnp.einsum("mpw,mp->mw", A.vals, u)
        return jnp.zeros((ncols(A),), contr.dtype).at[
            A.cols.reshape(-1)].add(contr.reshape(-1))
    return jnp.einsum("mpn,mp->n", A, u)


def brmatvec_sum_many(A, U):
    """Batched summed transpose matvec -> (k, n) for ``(k, m, p)`` U."""
    if is_sparse(A):
        contr = jnp.einsum("mpw,kmp->kmw", A.vals, U)
        k = U.shape[0]
        return jnp.zeros((k, ncols(A)), contr.dtype).at[
            :, A.cols.reshape(-1)].add(contr.reshape(k, -1))
    return jnp.einsum("mpn,kmp->kn", A, U)


def bgram(A):
    """Per-block Gram ``A_i A_i^T`` -> (m, p, p).

    Exact for sparse operands: padded columns hold zero values, so the
    support contraction equals the full-row contraction.
    """
    if is_sparse(A):
        return jnp.einsum("mpw,mqw->mpq", A.vals, A.vals)
    return jnp.einsum("mpn,mqn->mpq", A, A)


def densify(A):
    """Materialize a ``SparseBlocks`` operand as a dense (m, p, n) stack."""
    if not is_sparse(A):
        return A
    m, p, _ = A.vals.shape
    rows = jnp.arange(m)[:, None]
    # advanced indices (m, w) around the p slice -> update shape (m, w, p)
    return jnp.zeros((m, p, ncols(A)), A.vals.dtype).at[rows, :, A.cols].add(
        A.vals.transpose(0, 2, 1))


def block_shape(A) -> tuple[int, int]:
    """(m, p) of either operand kind."""
    if is_sparse(A):
        return A.vals.shape[0], A.vals.shape[1]
    return A.shape[0], A.shape[1]


def block_dtype(A):
    """Element dtype of either operand kind."""
    return A.vals.dtype if is_sparse(A) else A.dtype
