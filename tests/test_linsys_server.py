"""LinsysServer: coalescing, padding accounting, compile-once executors,
warm-start gating, and cross-backend parity."""
import numpy as np
import pytest

from repro import solvers
from repro.analysis import tracecheck
from repro.data import linsys
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore

PRM = {"gamma": 1.0, "eta": 1.0}     # shared explicit params (consensus
                                     # point of APC) so executors can be
                                     # shared across systems in tests


@pytest.fixture(scope="module")
def sys_a():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=0)


@pytest.fixture(scope="module")
def sys_b():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=1)


def _submit_rhs(srv, fp, n, seed):
    rng = np.random.default_rng(seed)
    rhs = rng.standard_normal(n)
    return srv.submit(fp, rhs), rhs


# ---------------------------------------------------------------------------
# queue semantics
# ---------------------------------------------------------------------------


def test_fifo_coalescing_and_padding(sys_a, sys_b):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=2, **PRM)
    fa, fb = srv.register(sys_a), srv.register(sys_b)
    rng = np.random.default_rng(0)
    # arrival order: a0 a1 b2 a3 — coalescing groups [a0,a1], then the
    # OLDEST pending (b2, padded), then [a3, pad]; a3 must NOT jump b2
    for fp in (fa, fa, fb, fa):
        srv.submit(fp, rng.standard_normal(48))
    batches = []
    while True:
        served = srv.step()
        if not served:
            break
        batches.append([r.rid for r in served])
    assert batches == [[0, 1], [2], [3]]
    assert srv.stats.served == 4                 # padding is NOT traffic
    assert srv.stats.padded == 2
    assert srv.stats.batches == 3


def test_same_system_requests_coalesce_past_arrival_gaps(sys_a, sys_b):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=3, **PRM)
    fa, fb = srv.register(sys_a), srv.register(sys_b)
    rng = np.random.default_rng(0)
    # a0 b1 a2 a3: batch 1 serves a0 AND coalesces a2, a3 into the group
    # even though b1 arrived earlier than both
    for fp in (fa, fb, fa, fa):
        srv.submit(fp, rng.standard_normal(48))
    assert [r.rid for r in srv.step()] == [0, 2, 3]
    assert [r.rid for r in srv.step()] == [1]


def test_step_and_drain_with_zero_pending_are_true_noops(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=2, **PRM)
    srv.register(sys_a)
    cache0 = srv.jit_cache_size()
    # nothing pending: no empty-batch compile, no executor build, no
    # store traffic — a TRUE no-op, not a zero-sized solve
    assert srv.step() == []
    assert srv.drain() == []
    assert srv.stats.executor_builds == 0
    assert srv.stats.batches == 0
    assert srv.jit_cache_size() == cache0
    # and again AFTER real traffic: drained server stays quiescent
    srv.submit(srv.register(sys_a), np.zeros(48))
    srv.drain()
    builds, cache1 = srv.stats.executor_builds, srv.jit_cache_size()
    assert srv.step() == [] and srv.drain() == []
    assert srv.stats.executor_builds == builds
    assert srv.jit_cache_size() == cache1


def test_submit_unknown_fingerprint_names_it(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=2, **PRM)
    srv.register(sys_a)
    bogus = "cafe" * 16
    with pytest.raises(KeyError, match=bogus):
        srv.submit(bogus, np.zeros(48))


def test_submit_validation(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=2, **PRM)
    fp = srv.register(sys_a)
    with pytest.raises(KeyError, match="register"):
        srv.submit("deadbeef", np.zeros(48))
    with pytest.raises(ValueError, match="shape"):
        srv.submit(fp, np.zeros(7))
    with pytest.raises(ValueError, match="backend"):
        LinsysServer(FactorStore(), backend="pod")
    with pytest.raises(ValueError, match="batch"):
        LinsysServer(FactorStore(), batch=0)


# ---------------------------------------------------------------------------
# correctness: served results match the unified drivers
# ---------------------------------------------------------------------------


def test_served_results_match_solve_many(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=60, batch=2, **PRM)
    fp = srv.register(sys_a)
    rng = np.random.default_rng(3)
    B = rng.standard_normal((2, sys_a.N))
    for b in B:
        srv.submit(fp, b)
    served = srv.drain()
    ref = solvers.get("apc").solve_many(sys_a, B, iters=60, **PRM)
    for i, r in enumerate(served):
        assert np.array_equal(r.x, np.asarray(ref.x[i]))
        assert r.residual == pytest.approx(float(ref.residuals[i, -1]))


def test_residuals_converge_and_store_amortizes(sys_a, sys_b):
    store = FactorStore()
    # auto-tuned APC params (resolved per system at register time)
    srv = LinsysServer(store, solver="apc", iters=300, tol=1e-6, batch=1)
    fps = [srv.register(sys_a), srv.register(sys_b)]
    rng = np.random.default_rng(0)
    n_req = 6
    for i in range(n_req):
        srv.submit(fps[i % 2], rng.standard_normal(48))
    out = srv.drain()
    assert all(r.residual < 1e-6 for r in out)
    assert all(r.iters_to_tol != -1 for r in out)
    assert store.stats.misses == 2                       # one per system
    assert store.stats.hits == n_req - 2


# ---------------------------------------------------------------------------
# compile-once executors
# ---------------------------------------------------------------------------


def test_executor_shared_across_same_shape_systems(sys_a, sys_b):
    srv = LinsysServer(FactorStore(), solver="apc", iters=10, batch=2, **PRM)
    fps = [srv.register(sys_a), srv.register(sys_b)]
    rng = np.random.default_rng(0)
    for i in range(8):
        srv.submit(fps[i % 2], rng.standard_normal(48))
    srv.drain()
    assert srv.stats.executor_builds == 1        # same (shapes, params) key


def test_steady_state_never_retraces(sys_a, sys_b):
    srv = LinsysServer(FactorStore(), solver="apc", iters=10, batch=2, **PRM)
    fps = [srv.register(sys_a), srv.register(sys_b)]
    rng = np.random.default_rng(0)
    # warmup: first batch per system compiles the shared executor
    for fp in fps:
        srv.submit(fp, rng.standard_normal(48))
        srv.submit(fp, rng.standard_normal(48))
        srv.step()
    # steady state: tracecheck fails NAMING the call site if anything
    # retraces (attributed upgrade of the old jit_cache_size counting)
    with tracecheck(steady_state=True):
        for i in range(5):
            srv.submit(fps[i % 2], rng.standard_normal(48))
            srv.submit(fps[i % 2], rng.standard_normal(48))
            srv.step()


def test_distinct_params_get_distinct_executors(sys_a, sys_b):
    # auto-tuned params differ per system -> separate compile-once entries
    srv = LinsysServer(FactorStore(), solver="apc", iters=10, batch=2)
    fps = [srv.register(sys_a), srv.register(sys_b)]
    rng = np.random.default_rng(0)
    for fp in fps:
        srv.submit(fp, rng.standard_normal(48))
    srv.drain()
    assert srv.stats.executor_builds == 2


# ---------------------------------------------------------------------------
# warm starts
# ---------------------------------------------------------------------------


def test_warm_start_repeated_rhs_resumes(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=40, batch=1,
                       warm_start=True, **PRM)
    fp = srv.register(sys_a)
    b = np.random.default_rng(5).standard_normal(48)
    srv.submit(fp, b)
    cold = srv.drain()[0]
    srv.submit(fp, b)                            # identical RHS: resume
    warm = srv.drain()[0]
    assert not cold.warm and warm.warm
    assert warm.residual < cold.residual         # kept iterating
    assert srv.stats.warm_batches == 1


def test_warm_start_perturbed_rhs_gated_by_solver(sys_a):
    rng = np.random.default_rng(6)
    b = rng.standard_normal(48)
    db = 1e-3 * rng.standard_normal(48)
    # APC iterates stay feasible for the OLD b -> must fall back to cold
    srv = LinsysServer(FactorStore(), solver="apc", iters=40, batch=1,
                       warm_start=True, **PRM)
    fp = srv.register(sys_a)
    srv.submit(fp, b)
    srv.drain()
    srv.submit(fp, b + db)
    assert not srv.drain()[0].warm
    # D-HBM re-reads b every step -> perturbed warm start allowed AND
    # converges to the NEW system's solution
    srvg = LinsysServer(FactorStore(), solver="dhbm", iters=250, batch=1,
                        warm_start=True)
    fpg = srvg.register(sys_a)
    srvg.submit(fpg, b)
    srvg.drain()
    srvg.submit(fpg, b + db)
    warm = srvg.drain()[0]
    assert warm.warm and warm.residual < 1e-6


def test_warm_mixed_traffic_apc_cold_solves_bit_equal(sys_a):
    """Interleaved repeated/perturbed RHS for ONE system across steps:
    APC (warm_rhs_ok=False) must serve every perturbed request through
    the cold path, bit-equal to a fresh cold solve."""
    rng = np.random.default_rng(8)
    b0 = rng.standard_normal(48)
    b1 = b0 + 1e-3 * rng.standard_normal(48)
    srv = LinsysServer(FactorStore(), solver="apc", iters=30, batch=1,
                       warm_start=True, **PRM)
    fp = srv.register(sys_a)
    out = []
    for b in [b0, b0, b1, b1, b0]:
        srv.submit(fp, b)
        out.append(srv.drain()[0])
    assert [r.warm for r in out] == [False, True, False, True, False]
    # the cold-gated results must be BIT-equal to a server that never
    # warm-starts (same executor computation, cold state every batch)
    cold = LinsysServer(FactorStore(), solver="apc", iters=30, batch=1,
                        warm_start=False, **PRM)
    fpc = cold.register(sys_a)
    for b, r in [(b0, out[0]), (b1, out[2]), (b0, out[4])]:
        cold.submit(fpc, b)
        c = cold.drain()[0]
        assert np.array_equal(r.x, c.x)
        assert r.residual == c.residual


def test_warm_mixed_traffic_cimmino_perturbed_stays_warm(sys_a):
    """Cimmino re-reads b every step (warm_rhs_ok=True): the perturbed
    request is served WARM and still converges to the new RHS's
    solution; the repeated request resumes bit-equal state."""
    rng = np.random.default_rng(9)
    b0 = rng.standard_normal(48)
    b1 = b0 + 1e-3 * rng.standard_normal(48)
    srv = LinsysServer(FactorStore(), solver="cimmino", iters=400, batch=1,
                       warm_start=True, tol=1e-8)
    fp = srv.register(sys_a)
    out = []
    for b in [b0, b0, b1]:
        srv.submit(fp, b)
        out.append(srv.drain()[0])
    assert [r.warm for r in out] == [False, True, True]
    assert out[2].residual < 1e-8                    # converged on NEW b
    A_dense, _ = sys_a.dense()
    x_direct = np.linalg.solve(np.asarray(A_dense), b1)
    assert np.allclose(out[2].x, x_direct, rtol=1e-5, atol=1e-7)


def test_register_merges_server_level_params(sys_a):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5, batch=1,
                       gamma=1.25, eta=1.5)
    fp = srv.register(sys_a, eta=1.1)        # override eta, KEEP gamma
    prm = srv._systems[fp].prm
    assert prm["gamma"] == 1.25 and prm["eta"] == 1.1


def test_warm_rhs_ok_flags():
    expected = {"apc": False, "consensus": False, "cimmino": True,
                "dgd": True, "dnag": True, "dhbm": True, "pdhbm": False,
                "madmm": False}
    for name, flag in expected.items():
        assert solvers.get(name).warm_rhs_ok is flag, name


# ---------------------------------------------------------------------------
# mesh backend
# ---------------------------------------------------------------------------


def test_mesh_server_matches_local(sys_a):
    rng = np.random.default_rng(7)
    B = rng.standard_normal((2, sys_a.N))
    out = {}
    for backend in ("local", "mesh"):
        srv = LinsysServer(FactorStore(), solver="apc", iters=80, batch=2,
                           backend=backend, **PRM)
        fp = srv.register(sys_a)
        for b in B:
            srv.submit(fp, b)
        out[backend] = srv.drain()
    for rl, rm in zip(out["local"], out["mesh"]):
        assert np.allclose(rl.x, rm.x, rtol=1e-8, atol=1e-10)
        assert rm.residual == pytest.approx(rl.residual, rel=1e-6)
