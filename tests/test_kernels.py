"""Pallas block-projection kernels vs the pure-jnp oracle.

Sweeps shapes/dtypes (deliverable c) and property-tests the projection
semantics with hypothesis.  All kernels run in interpret mode on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional property-testing dep not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import block_projection as bp
from repro.kernels import ops, ref


def _mk(p, n, dtype, seed=0, jitter=0.0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((p, n)), dtype)
    G = (A @ A.T).astype(jnp.float64) + jitter * np.eye(p)
    B = jnp.asarray(np.linalg.solve(np.asarray(G), np.asarray(
        A, np.float64)), dtype).T
    x = jnp.asarray(rng.standard_normal(n), dtype)
    xb = jnp.asarray(rng.standard_normal(n), dtype)
    return A, B, x, xb


TOL = {jnp.float32: 2e-5, jnp.float64: 1e-12, jnp.bfloat16: 8e-2}


@pytest.mark.parametrize("p,n", [(8, 128), (16, 512), (7, 130), (32, 1024),
                                 (24, 896), (1, 128), (64, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_block_projection_matches_ref(p, n, dtype):
    A, B, x, xb = _mk(p, n, dtype)
    y = ops.block_projection(A, B, x, xb, 1.37)
    yr = ref.block_projection_ref(A, B, x, xb, 1.37)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float64) -
                                yr.astype(jnp.float64))))
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float64)))) + 1.0
    assert err / scale < TOL[dtype], (p, n, dtype, err)


@pytest.mark.parametrize("bn", [128, 256, 512])
def test_gather_blocked_invariance(bn):
    """u must not depend on the BN tile size."""
    A, B, x, xb = _mk(16, 1024, jnp.float32)
    u1 = bp.apc_gather(A, x[None], xb[None], bn=bn)
    u2 = jnp.asarray((A @ (xb - x))[None])
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), atol=2e-4)


def test_batched_matches_loop():
    m, p, n = 3, 8, 256
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((m, p, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    xb = jnp.asarray(rng.standard_normal(n), jnp.float32)
    out = ops.block_projection_batched(A, B, x, xb, 0.9)
    for i in range(m):
        yi = ops.block_projection(A[i], B[i], x[i], xb, 0.9)
        # vmap fuses differently than the per-worker call: f32 tolerance
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(yi),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 24), nb=st.integers(1, 6),
       gamma=st.floats(0.1, 1.9), seed=st.integers(0, 99))
def test_projection_properties(p, nb, gamma, seed):
    """P = I - B A is a projection: the kernel output satisfies
    A y = A x + gamma * 0 ... i.e. A(y - x - gamma(d - BAd)) == 0, and with
    gamma=1 the result lands on the affine subspace {A z = A xbar_proj}."""
    n = 128 * nb
    A, B, x, xb = _mk(p, n, jnp.float64, seed)
    y = ops.block_projection(A, B, x, xb, gamma)
    yr = ref.block_projection_ref(A, B, x, xb, gamma)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-10, atol=1e-10)
    # exact-projection identity: A B == I (B = A^+), so
    # A y == (1-gamma) A x + gamma A x = A x  when d projected to null(A).
    lhs = np.asarray(A @ y)
    rhs = np.asarray(A @ x)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-8, atol=1e-8)
