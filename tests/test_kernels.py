"""Pallas projection-family kernels vs the pure-jnp oracles.

Sweeps shapes/dtypes (deliverable c), covers the multi-RHS batched layout
and the dedicated Cimmino kernel pair, and property-tests the projection
semantics with hypothesis.  All kernels run in interpret mode on CPU.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional property-testing dep: only the @given test needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

from repro.kernels import block_projection as bp
from repro.kernels import ops, ref


def _mk(p, n, dtype, seed=0, jitter=0.0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((p, n)), dtype)
    G = (A @ A.T).astype(jnp.float64) + jitter * np.eye(p)
    B = jnp.asarray(np.linalg.solve(np.asarray(G), np.asarray(
        A, np.float64)), dtype).T
    x = jnp.asarray(rng.standard_normal(n), dtype)
    xb = jnp.asarray(rng.standard_normal(n), dtype)
    return A, B, x, xb


TOL = {jnp.float32: 2e-5, jnp.float64: 1e-12, jnp.bfloat16: 8e-2}


@pytest.mark.parametrize("p,n", [(8, 128), (16, 512), (7, 130), (32, 1024),
                                 (24, 896), (1, 128), (64, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_block_projection_matches_ref(p, n, dtype):
    A, B, x, xb = _mk(p, n, dtype)
    y = ops.block_projection(A, B, x, xb, 1.37)
    yr = ref.block_projection_ref(A, B, x, xb, 1.37)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float64) -
                                yr.astype(jnp.float64))))
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float64)))) + 1.0
    assert err / scale < TOL[dtype], (p, n, dtype, err)


@pytest.mark.parametrize("bn", [128, 256, 512])
def test_gather_blocked_invariance(bn):
    """u must not depend on the BN tile size."""
    A, B, x, xb = _mk(16, 1024, jnp.float32)
    u1 = bp.apc_gather(A, x[None], xb[None], bn=bn)
    u2 = jnp.asarray((A @ (xb - x))[None])
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), atol=2e-4)


def test_batched_matches_loop():
    m, p, n = 3, 8, 256
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((m, p, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    xb = jnp.asarray(rng.standard_normal(n), jnp.float32)
    out = ops.block_projection_batched(A, B, x, xb, 0.9)
    for i in range(m):
        yi = ops.block_projection(A[i], B[i], x[i], xb, 0.9)
        # vmap fuses differently than the per-worker call: f32 tolerance
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(yi),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Multi-RHS batched layout: k rows stream through one A/B tile residency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,n,k", [(8, 128, 2), (16, 512, 16), (7, 130, 5),
                                   (1, 128, 16), (24, 896, 3), (32, 1024, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_block_projection_batched_rhs_matches_ref(p, n, k, dtype):
    """The (k, n) multi-RHS path == the ref on every row, including
    non-multiple-of-128 n, p=1 edge blocks, and non-multiple-of-8 k."""
    rng = np.random.default_rng(7)
    A, B, _, _ = _mk(p, n, dtype)
    X = jnp.asarray(rng.standard_normal((k, n)), dtype)
    Xb = jnp.asarray(rng.standard_normal((k, n)), dtype)
    y = ops.block_projection(A, B, X, Xb, 0.83)
    yr = ref.block_projection_ref(A, B, X, Xb, 0.83)
    assert y.shape == (k, n)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float64) -
                                yr.astype(jnp.float64))))
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float64)))) + 1.0
    assert err / scale < TOL[dtype], (p, n, k, dtype, err)


@pytest.mark.parametrize("k", [1, 4, 16])
def test_batched_rhs_matches_row_loop(k):
    """Each batch row equals the single-RHS kernel run on that row."""
    A, B, _, _ = _mk(16, 384, jnp.float64)
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((k, 384)), jnp.float64)
    Xb = jnp.asarray(rng.standard_normal((k, 384)), jnp.float64)
    y = ops.block_projection(A, B, X, Xb, 1.1)
    for i in range(k):
        yi = ops.block_projection(A, B, X[i], Xb[i], 1.1)
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("p,n,k", [(8, 256, 1), (7, 130, 6), (1, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_split_gather_scatter_match_ref(p, n, k, dtype):
    """The split ops the mesh backend composes (gather / psum / scatter)
    agree with the refs at every batch size."""
    rng = np.random.default_rng(11)
    A, B, _, _ = _mk(p, n, dtype)
    shape = (n,) if k == 1 else (k, n)
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    xb = jnp.asarray(rng.standard_normal(shape), dtype)
    tol = TOL[dtype]
    u = ops.proj_gather(A, x, xb)
    ur = ref.apc_gather_ref(A, x, xb)
    assert u.shape == ur.shape
    np.testing.assert_allclose(np.asarray(u), np.asarray(ur),
                               rtol=tol, atol=tol)
    y = ops.proj_scatter(B, x, xb, u, 0.7)
    yr = ref.apc_scatter_ref(B, x, xb, ur, 0.7)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# Dedicated Cimmino kernel pair (r = B (b − A x̄))
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,n,k", [(8, 128, 1), (16, 512, 16), (7, 130, 5),
                                   (1, 128, 4), (24, 896, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_cimmino_kernels_match_ref(p, n, k, dtype):
    rng = np.random.default_rng(5)
    A, B, _, _ = _mk(p, n, dtype)
    xb = jnp.asarray(rng.standard_normal((n,) if k == 1 else (k, n)), dtype)
    b = jnp.asarray(rng.standard_normal((p,) if k == 1 else (k, p)), dtype)
    tol = TOL[dtype]
    u = ops.cimmino_gather(A, xb)
    np.testing.assert_allclose(np.asarray(u),
                               np.asarray(ref.cimmino_gather_ref(A, xb)),
                               rtol=tol, atol=tol)
    v = b - u
    r = ops.cimmino_scatter(B, v)
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(ref.cimmino_scatter_ref(B, v)),
                               rtol=tol, atol=tol)
    full = ops.cimmino_update(A, B, b, xb)
    fullr = ref.cimmino_update_ref(A, B, b, xb)
    assert full.shape == fullr.shape
    np.testing.assert_allclose(np.asarray(full), np.asarray(fullr),
                               rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# BN autotune (measured choice, cache, env overrides)
# ---------------------------------------------------------------------------


def test_pick_bn_env_pin_and_validation(monkeypatch):
    monkeypatch.setenv(ops.BN_ENV, "256")
    assert ops.pick_bn(1024, 8, jnp.float32, interpret=True) == 256
    monkeypatch.setenv(ops.BN_ENV, "384")    # not a divisor of padded n
    with pytest.raises(ValueError, match="REPRO_KERNEL_BN"):
        ops.pick_bn(1024, 8, jnp.float32, interpret=True)


def test_pick_bn_heuristic_and_cache(monkeypatch):
    monkeypatch.delenv(ops.BN_ENV, raising=False)
    monkeypatch.setenv(ops.AUTOTUNE_ENV, "0")      # heuristic only
    ops.bn_cache_clear()
    try:
        # heuristic = first candidate dividing n_pad (512 preferred)
        assert ops.pick_bn(1024, 8, jnp.float32, interpret=True) == 512
        assert ops.pick_bn(256, 8, jnp.float32, interpret=True) == 256
        assert ops.pick_bn(128, 8, jnp.float32, interpret=True) == 128
        assert (8, 1024, "float32") in ops.bn_cache()
    finally:
        ops.bn_cache_clear()


def test_pick_bn_measured_is_cached(monkeypatch):
    """REPRO_KERNEL_AUTOTUNE=1 forces measurement (even in interpret
    mode); the winner must be a valid candidate and must be cached."""
    monkeypatch.delenv(ops.BN_ENV, raising=False)
    monkeypatch.setenv(ops.AUTOTUNE_ENV, "1")
    ops.bn_cache_clear()
    try:
        bn = ops.pick_bn(512, 8, jnp.float32, interpret=True)
        assert bn in (512, 256, 128) and 512 % bn == 0
        assert ops.bn_cache()[(8, 512, "float32")] == bn
        # second call is a pure cache hit (no re-measurement): same answer
        assert ops.pick_bn(512, 8, jnp.float32, interpret=True) == bn
    finally:
        ops.bn_cache_clear()


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(p=st.integers(2, 24), nb=st.integers(1, 6),
           gamma=st.floats(0.1, 1.9), seed=st.integers(0, 99))
    def test_projection_properties(p, nb, gamma, seed):
        """P = I - B A is a projection: the kernel output satisfies
        A y = A x + gamma * 0 ... i.e. A(y - x - gamma(d - BAd)) == 0, and
        with gamma=1 the result lands on {A z = A xbar_proj}."""
        n = 128 * nb
        A, B, x, xb = _mk(p, n, jnp.float64, seed)
        y = ops.block_projection(A, B, x, xb, gamma)
        yr = ref.block_projection_ref(A, B, x, xb, gamma)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-10, atol=1e-10)
        # exact-projection identity: A B == I (B = A^+), so
        # A y == (1-gamma) A x + gamma A x = A x  (d projected to null(A)).
        lhs = np.asarray(A @ y)
        rhs = np.asarray(A @ x)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-8, atol=1e-8)
else:  # keep the skip visible in reports instead of silently absent
    @pytest.mark.skip(reason="optional property-testing dep not installed")
    def test_projection_properties():
        pass
