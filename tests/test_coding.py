"""Straggler-tolerant r-redundant APC (core/coding.py, runtime/fault.py)."""
import numpy as np
import pytest

from repro.core import coding, spectral
from repro.data import linsys
from repro.runtime import fault


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=96, m=6, cond=10.0, seed=11)


def test_selection_weights_cover_each_block_once():
    m, r = 6, 3
    for trial in range(20):
        rng = np.random.default_rng(trial)
        alive = rng.random(m) > 0.3
        if not fault.covering_ok(alive, r):
            continue
        W = coding.selection_weights(alive, m, r)
        # column-sum per block: holder (i, k) holds block (i+k)%m
        per_block = np.zeros(m)
        for i in range(m):
            for k in range(r):
                per_block[(i + k) % m] += W[i, k]
        np.testing.assert_allclose(per_block, 1.0)
        # dead workers contribute nothing
        assert W[~alive].sum() == 0.0


def test_unrecoverable_raises():
    m, r = 4, 2
    alive = np.array([False, False, True, True])  # blocks of 0,1 both lost?
    # workers 0 and 1 adjacent -> block 1 held by workers 1 (slot 0) and 0
    # (slot 1): both dead -> unrecoverable.
    assert not fault.covering_ok(alive, r)
    with pytest.raises(RuntimeError):
        coding.selection_weights(alive, m, r)


def test_straggler_run_matches_no_straggler(sys_):
    """Exactness: dropping covered workers does not change the iterates."""
    rng = np.random.default_rng(2)

    def sched(t):
        a = np.ones(6, bool)
        if t % 2 == 0:
            a[rng.integers(0, 6)] = False
        return a

    x1, res1 = coding.solve_redundant(sys_, r=2, iters=150)
    rng = np.random.default_rng(2)
    x2, res2 = coding.solve_redundant(sys_, r=2, iters=150,
                                      alive_schedule=sched)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-10)
    assert res2[-1] < 1e-8


def test_heartbeat_monitor():
    mon = fault.HeartbeatMonitor(n_workers=4, timeout=5.0)
    for w in range(4):
        mon.beat(w, now=100.0, duration=1.0)
    assert mon.alive_mask(now=102.0).all()
    mask = mon.alive_mask(now=106.0)
    assert not mask.any()
    with pytest.raises(RuntimeError):
        mon.rejoin(1, resynced=False)
    mon.rejoin(1, resynced=True)
    assert mon.alive_mask()[1]


def test_straggler_detection():
    mon = fault.HeartbeatMonitor(n_workers=4, straggler_factor=2.0)
    for w in range(4):
        mon.beat(w, duration=1.0 if w else 10.0)   # worker 0 is 10x median
    s = mon.stragglers()
    assert s[0] and not s[1:].any()


def test_elastic_plan():
    p = fault.ElasticPlan.shrink(n_devices_left=200, model=16)
    assert p.data == 12 and p.model == 16
    with pytest.raises(RuntimeError):
        fault.ElasticPlan.shrink(n_devices_left=8, model=16)
