"""Straggler-tolerant r-redundant APC (core/coding.py, runtime/fault.py).

core/coding.py is now a deprecated shim over repro.solvers.redundant; the
tests here pin the shim's legacy surface and the fault runtime.  The full
redundant-execution contract is covered in tests/test_redundant.py.
"""
import inspect

import numpy as np
import pytest

from repro.core import coding
from repro.data import linsys
from repro.runtime import fault


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=96, m=6, cond=10.0, seed=11)


def test_selection_weights_cover_each_block_once():
    m, r = 6, 3
    for trial in range(20):
        rng = np.random.default_rng(trial)
        alive = rng.random(m) > 0.3
        if not fault.covering_ok(alive, r):
            continue
        W = coding.selection_weights(alive, m, r)
        # column-sum per block: holder (i, k) holds block (i+k)%m
        per_block = np.zeros(m)
        for i in range(m):
            for k in range(r):
                per_block[(i + k) % m] += W[i, k]
        np.testing.assert_allclose(per_block, 1.0)
        # dead workers contribute nothing
        assert W[~alive].sum() == 0.0


def test_unrecoverable_raises():
    m, r = 4, 2
    alive = np.array([False, False, True, True])  # blocks of 0,1 both lost?
    # workers 0 and 1 adjacent -> block 1 held by workers 1 (slot 0) and 0
    # (slot 1): both dead -> unrecoverable.
    assert not fault.covering_ok(alive, r)
    with pytest.raises(RuntimeError):
        coding.selection_weights(alive, m, r)


def test_straggler_run_matches_no_straggler(sys_):
    """Exactness: dropping covered workers does not change the iterates."""
    rng = np.random.default_rng(2)

    def sched(t):
        a = np.ones(6, bool)
        if t % 2 == 0:
            a[rng.integers(0, 6)] = False
        return a

    x1, res1 = coding.solve_redundant(sys_, r=2, iters=150)
    rng = np.random.default_rng(2)
    x2, res2 = coding.solve_redundant(sys_, r=2, iters=150,
                                      alive_schedule=sched)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-10)
    assert res2[-1] < 1e-8


def test_solve_redundant_seed_param_removed(sys_):
    """Regression: the old ``seed`` parameter was accepted and documented
    but never used (init is the deterministic min-norm solution); it is
    gone rather than silently ignored."""
    assert "seed" not in inspect.signature(coding.solve_redundant).parameters
    with pytest.raises(TypeError):
        coding.solve_redundant(sys_, 2, iters=1, seed=0)


def test_heartbeat_monitor():
    mon = fault.HeartbeatMonitor(n_workers=4, timeout=5.0)
    for w in range(4):
        mon.beat(w, now=100.0, duration=1.0)
    assert mon.alive_mask(now=102.0).all()
    mask = mon.alive_mask(now=106.0)
    assert not mask.any()
    with pytest.raises(RuntimeError):
        mon.rejoin(1, resynced=False)
    mon.rejoin(1, resynced=True)
    assert mon.alive_mask()[1]


def test_straggler_detection():
    mon = fault.HeartbeatMonitor(n_workers=4, straggler_factor=2.0)
    for w in range(4):
        mon.beat(w, duration=1.0 if w else 10.0)   # worker 0 is 10x median
    s = mon.stragglers()
    assert s[0] and not s[1:].any()


def test_dead_worker_excluded_from_straggler_median():
    """A dead-slow worker's stale duration must not inflate the median and
    mask a live straggler."""
    mon = fault.HeartbeatMonitor(n_workers=4, timeout=5.0,
                                 straggler_factor=3.0)
    mon.beat(0, now=100.0, duration=100.0)   # slow worker, then dies
    mon.beat(1, now=108.0, duration=5.0)     # live straggler
    mon.beat(2, now=108.0, duration=1.0)
    mon.beat(3, now=108.0, duration=1.0)
    s = mon.stragglers(now=110.0)            # worker 0 timed out by now
    # live median is 1.0 -> worker 1 (5x) is flagged; with the dead
    # worker's 100.0 left in, the median was 3.0 and 5.0 slipped under
    # the 3x threshold.  The dead worker itself is never flagged.
    assert s[1] and not s[0] and not s[2:].any()
    assert mon.drop_set(now=110.0).tolist() == [True, True, False, False]


def test_straggler_quorum_counts_live_workers():
    """Detection must stay active in a heavily degraded fleet: the quorum
    is over LIVE workers, not the full fleet size."""
    mon = fault.HeartbeatMonitor(n_workers=8, timeout=5.0,
                                 straggler_factor=3.0)
    for w in range(5):                       # 5 workers die
        mon.beat(w, now=0.0, duration=1.0)
    mon.beat(5, now=100.0, duration=1.0)
    mon.beat(6, now=100.0, duration=1.0)
    mon.beat(7, now=100.0, duration=50.0)    # live straggler
    s = mon.stragglers(now=101.0)            # 3 live < 8 // 2 = 4: with a
    assert s[7] and not s[:7].any()          # fleet-size quorum this is off


def test_alive_mask_reads_are_pure():
    """Reads never mutate _dead: a timed-out worker that resumes beating
    is alive again, while an explicit sweep() makes death sticky until the
    rejoin resync handshake."""
    mon = fault.HeartbeatMonitor(n_workers=2, timeout=5.0)
    mon.beat(0, now=0.0)
    mon.beat(1, now=8.0)
    m1 = mon.alive_mask(now=10.0)
    m2 = mon.alive_mask(now=10.0)            # consecutive reads agree
    assert m1.tolist() == m2.tolist() == [False, True]
    mon.beat(0, now=11.0)                    # the read had no side effect,
    assert mon.alive_mask(now=12.0)[0]       # so a fresh beat readmits
    mon.sweep(now=20.0)                      # both silent > timeout: sticky
    mon.beat(0, now=21.0)
    mon.beat(1, now=21.0)
    assert not mon.alive_mask(now=22.0).any()   # beats do not resurrect
    mon.rejoin(0, resynced=True)
    assert mon.alive_mask()[0] and not mon.alive_mask()[1]


def test_mark_dead_is_explicit_and_sticky():
    mon = fault.HeartbeatMonitor(n_workers=3, timeout=5.0)
    for w in range(3):
        mon.beat(w, now=0.0)
    mon.mark_dead(2)
    assert mon.alive_mask(now=1.0).tolist() == [True, True, False]
    mon.beat(2, now=2.0)                     # heartbeat alone: still dead
    assert not mon.alive_mask(now=2.5)[2]
    mon.rejoin(2, resynced=True)
    assert mon.alive_mask()[2]


def test_covering_ok_accepts_plain_lists():
    """Regression: the r >= m branch crashed with AttributeError on a
    plain-list mask (``alive.any()`` before np.asarray)."""
    assert fault.covering_ok([True, False, False], r=3) is True
    assert fault.covering_ok([False, False, False], r=3) is False
    assert fault.covering_ok([True, False, True, True], r=2) is True
    assert fault.covering_ok([False, False, True, True], r=2) is False


def test_elastic_plan():
    p = fault.ElasticPlan.shrink(n_devices_left=200, model=16)
    assert p.data == 12 and p.model == 16
    with pytest.raises(RuntimeError):
        fault.ElasticPlan.shrink(n_devices_left=8, model=16)
