"""Engine autotune: ``kops.use_fused`` picks fused vs unfused per
(family, p, n, k, dtype) — env pin > cache > measurement > heuristic —
and the projection-family dispatch honors it bit-exactly at trace time
(the BENCH_PR5 cimmino batch-1 regression, fixed by falling back)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.kernels import ops as kops
from repro.solvers.store import FactorStore

PRM_APC = {"gamma": 1.0, "eta": 1.0}


@pytest.fixture(autouse=True)
def _clean_engine_cache(monkeypatch):
    # heuristic-only resolution by default: deterministic on any host
    monkeypatch.setenv(kops.AUTOTUNE_ENV, "0")
    monkeypatch.delenv(kops.ENGINE_ENV, raising=False)
    kops.engine_cache_clear()
    yield
    kops.engine_cache_clear()


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=64, m=2, cond=10.0, seed=0)


# ---------------------------------------------------------------------------
# resolution order
# ---------------------------------------------------------------------------


def test_env_pin_wins_and_skips_the_cache(monkeypatch):
    monkeypatch.setenv(kops.ENGINE_ENV, "fused")
    assert kops.use_fused("cimmino", 32, 128, 1) is True
    monkeypatch.setenv(kops.ENGINE_ENV, "unfused")
    assert kops.use_fused("apc", 32, 128, 16) is False
    assert kops.engine_cache() == {}             # pins are never cached
    monkeypatch.setenv(kops.ENGINE_ENV, "both")
    with pytest.raises(ValueError, match="fused"):
        kops.use_fused("apc", 32, 128, 1)


def test_heuristic_cimmino_subbatch_falls_back():
    # the measured BENCH trend: fused loses ONLY at the single-RHS
    # cimmino corner (k=1 stays unpadded); any real batch pads onto the
    # 8-sublane tile and keeps the fused engine
    assert kops.use_fused("cimmino", 32, 128, 1) is False
    assert kops.use_fused("cimmino", 32, 128, 4) is True
    assert kops.use_fused("cimmino", 32, 128, 16) is True
    assert kops.use_fused("apc", 32, 128, 1) is True
    assert kops.use_fused("apc", 32, 128, 16) is True


def test_choice_is_cached_per_padded_shape():
    kops.use_fused("cimmino", 30, 100, 1, jnp.float32)
    key = ("cimmino", 32, 128, 1, "float32")     # (8, 128)-padded, k=1
    assert kops.engine_cache() == {key: False}
    # k pads to the 8-sublane tile: 9 and 16 share one cache entry
    kops.use_fused("apc", 32, 128, 9, jnp.float32)
    kops.use_fused("apc", 32, 128, 16, jnp.float32)
    assert ("apc", 32, 128, 16, "float32") in kops.engine_cache()
    assert len(kops.engine_cache()) == 2


def test_measured_autotune_runs_and_caches(monkeypatch):
    monkeypatch.setenv(kops.AUTOTUNE_ENV, "1")
    got = kops.use_fused("cimmino", 16, 128, 1, jnp.float32,
                         interpret=True)
    assert isinstance(got, bool)                 # whichever engine WON
    assert ("cimmino", 16, 128, 1, "float32") in kops.engine_cache()
    # second call is a cache hit (same answer, no re-measurement)
    assert kops.use_fused("cimmino", 16, 128, 1, jnp.float32,
                          interpret=True) is got


def test_unknown_family_rejected():
    with pytest.raises(ValueError, match="family"):
        kops.use_fused("dgd", 32, 128, 1)


# ---------------------------------------------------------------------------
# dispatch regression: the serving path must not lose to unfused
# ---------------------------------------------------------------------------


def test_cimmino_batch1_dispatch_bit_equals_unfused(sys_):
    """The BENCH_PR5 regression corner (0.88x): with the autotune saying
    'unfused', use_kernel=True at k=1 must trace the IDENTICAL unfused
    step — bit-equal results, not just close."""
    s = solvers.get("cimmino")
    b = np.random.default_rng(0).standard_normal(sys_.N)
    kern = s.solve_many(sys_, b[None], iters=25, use_kernel=True,
                        store=FactorStore())
    ref = s.solve_many(sys_, b[None], iters=25, use_kernel=False,
                       store=FactorStore())
    assert np.array_equal(np.asarray(kern.x), np.asarray(ref.x))


def test_cimmino_batch1_pin_forces_the_fused_kernels(monkeypatch, sys_):
    s = solvers.get("cimmino")
    b = np.random.default_rng(0).standard_normal(sys_.N)
    monkeypatch.setenv(kops.ENGINE_ENV, "fused")
    kern = s.solve_many(sys_, b[None], iters=25, use_kernel=True,
                        store=FactorStore())
    ref = s.solve_many(sys_, b[None], iters=25, use_kernel=False,
                       store=FactorStore())
    # genuinely a different engine (different rounding), same solve
    assert not np.array_equal(np.asarray(kern.x), np.asarray(ref.x))
    assert np.allclose(np.asarray(kern.x), np.asarray(ref.x),
                       rtol=1e-10, atol=1e-12)


def test_apc_dispatch_keeps_fused_at_batch_16(sys_):
    """APC stays on the fused engine (heuristic) — and the fused batch-16
    path agrees with unfused to fp tolerance."""
    s = solvers.get("apc")
    B = np.random.default_rng(1).standard_normal((16, sys_.N))
    kern = s.solve_many(sys_, B, iters=25, use_kernel=True,
                        store=FactorStore(), **PRM_APC)
    ref = s.solve_many(sys_, B, iters=25, use_kernel=False,
                       store=FactorStore(), **PRM_APC)
    assert np.allclose(np.asarray(kern.x), np.asarray(ref.x),
                       rtol=1e-8, atol=1e-10)
