"""MoE dispatch semantics: sort-based capacity dispatch vs a naive loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe, sharding
from repro.models.config import ModelConfig, MoEConfig


def _cfg(E=8, K=2, D=16, F=32, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=D, n_heads=2,
        n_kv_heads=2, head_dim=8, d_ff=F, vocab_size=64,
        moe=MoEConfig(num_experts=E, top_k=K, d_expert=F,
                      capacity_factor=cf))


def _params(cfg, seed=0):
    return sharding.init_tree(moe.moe_abstract(cfg), jax.random.PRNGKey(seed),
                              jnp.float32)


def _naive(cfg, p, x):
    """Reference: every token runs its top-k experts exactly (no capacity)."""
    mo = cfg.moe
    B, S, D = x.shape
    xf = np.asarray(x.reshape(-1, D), np.float64)
    logits = xf @ np.asarray(p["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[:mo.top_k]
        w = probs[t, top] / probs[t, top].sum()
        for e, wi in zip(top, w):
            wg = np.asarray(p["w_gate"][e], np.float64)
            wu = np.asarray(p["w_up"][e], np.float64)
            wd = np.asarray(p["w_down"][e], np.float64)
            h = (xf[t] @ wg)
            h = h / (1 + np.exp(-h)) * (xf[t] @ wu)
            out[t] += wi * (h @ wd)
    return out.reshape(B, S, D)


def test_matches_naive_when_capacity_unbounded():
    cfg = _cfg(cf=32.0)
    p = _params(cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y = moe.moe_apply(cfg, p, x)
    yref = _naive(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_partial_not_corrupt():
    """With a tight capacity, outputs are a subset of expert contributions —
    never NaN, and tokens with all slots dropped return ~0 (residual only)."""
    cfg = _cfg(cf=0.25)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y = moe.moe_apply(cfg, p, x)
    assert bool(jnp.isfinite(y).all())


def test_shared_expert_always_on():
    cfg = _cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_shared=1, capacity_factor=8.0))
    p = _params(cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (1, 4, cfg.d_model))
    y_full = moe.moe_apply(cfg, p, x)
    # zero the routed experts: only the shared path remains
    p0 = dict(p)
    p0["w_down"] = jnp.zeros_like(p["w_down"])
    y_shared = moe.moe_apply(cfg, p0, x)
    from repro.models import layers
    np.testing.assert_allclose(
        np.asarray(y_shared),
        np.asarray(layers.swiglu_apply(p["shared"], x.reshape(4, -1)).reshape(
            1, 4, -1)), rtol=1e-5, atol=1e-6)
    assert float(jnp.max(jnp.abs(y_full - y_shared))) > 0.0


def test_gate_weights_normalized():
    """Combine weights per token sum to 1 over the kept slots (cf high)."""
    cfg = _cfg(cf=32.0)
    p = _params(cfg)
    # uniform expert outputs: set all expert weights equal => output equals
    # the single-expert output regardless of routing.
    pe = dict(p)
    w_g = jnp.broadcast_to(p["w_gate"][:1], p["w_gate"].shape)
    w_u = jnp.broadcast_to(p["w_up"][:1], p["w_up"].shape)
    w_d = jnp.broadcast_to(p["w_down"][:1], p["w_down"].shape)
    pe.update(w_gate=w_g, w_up=w_u, w_down=w_d)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    y = moe.moe_apply(cfg, pe, x)
    xf = x.reshape(-1, cfg.d_model)
    h = jax.nn.silu(xf @ w_g[0]) * (xf @ w_u[0])
    y1 = (h @ w_d[0]).reshape(1, 8, -1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1), rtol=1e-4,
                               atol=1e-5)
