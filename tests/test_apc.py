"""Algorithm 1 (APC): convergence, Theorem 1 rate, Proposition 2."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apc, baselines, partition, spectral
from repro.data import linsys


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=96, m=4, cond=25.0, seed=7)


def test_converges_to_true_solution(sys_):
    res = apc.solve(sys_, iters=600)
    assert float(res.errors[-1]) < 1e-10


def test_local_constraints_invariant(sys_):
    """Every worker iterate satisfies A_i x_i = b_i at all times (the
    projection-consensus invariant)."""
    res = apc.solve(sys_, iters=50)
    viol = jnp.einsum("mpn,mn->mp", sys_.A_blocks, res.state.x) - sys_.b_blocks
    assert float(jnp.max(jnp.abs(viol))) < 1e-8


def test_rate_matches_theorem1(sys_):
    X = spectral.x_matrix(sys_)
    mu_min, mu_max = spectral.mu_extremes(X)
    prm = spectral.apc_optimal(mu_min, mu_max)
    res = apc.solve(sys_, iters=400)
    e = np.asarray(res.errors)
    # empirical contraction between iterations 100 and 300 (past transient,
    # before the float64 floor)
    emp = (e[300] / e[100]) ** (1.0 / 200.0)
    assert emp <= prm.rho * 1.05 + 0.02


def test_theorem1_optimality_equations(sys_):
    X = spectral.x_matrix(sys_)
    mu_min, mu_max = spectral.mu_extremes(X)
    p = spectral.apc_optimal(mu_min, mu_max)
    lhs1 = mu_max * p.eta * p.gamma
    lhs2 = mu_min * p.eta * p.gamma
    rho = np.sqrt((p.gamma - 1.0) * (p.eta - 1.0))
    assert lhs1 == pytest.approx((1.0 + rho) ** 2, rel=1e-8)
    assert lhs2 == pytest.approx((1.0 - rho) ** 2, rel=1e-8)
    assert p.rho == pytest.approx(rho, rel=1e-8)
    assert 0.0 <= p.gamma <= 2.0            # set S constraint


def test_cimmino_is_apc_gamma1(sys_):
    """Proposition 2: block Cimmino == APC with gamma = 1, eta = m nu."""
    m = sys_.m
    nu = 0.3 / m
    hist_c = baselines.cimmino(sys_, iters=40, nu=nu)
    factors = apc.prepare(sys_)
    state = apc.init_state(factors)
    # match Cimmino's x̄(0) = 0 start: x_i(0) arbitrary (x_i(1) ignores it
    # when gamma=1), x̄(0) = 0.
    state = apc.APCState(x=state.x, xbar=jnp.zeros_like(state.xbar),
                         t=state.t)
    for _ in range(40):
        state = apc.apc_step(factors, state, 1.0, m * nu)
    assert float(jnp.linalg.norm(state.xbar - hist_c.x)) < 1e-9


def test_kernel_path_equals_reference(sys_):
    r1 = apc.solve(sys_, iters=60)
    r2 = apc.solve(sys_, iters=60, use_kernel=True)
    assert float(jnp.linalg.norm(r1.x - r2.x)) < 1e-8


def test_partition_roundtrip(rng):
    A = rng.standard_normal((24, 10))
    b = rng.standard_normal(24)
    sys_ = partition.partition(A, b, 4)
    A2, b2 = sys_.dense()
    np.testing.assert_allclose(np.asarray(A2), A)
    np.testing.assert_allclose(np.asarray(b2), b)
    with pytest.raises(ValueError):
        partition.partition(A, b, 5)
    Ap, bp = partition.pad_to_blocks(A, b, 5)
    assert Ap.shape[0] % 5 == 0


def test_solve_resumable(sys_):
    """APCState checkpoint/restart mid-solve is exact."""
    factors = apc.prepare(sys_)
    s = apc.init_state(factors)
    for _ in range(20):
        s = apc.apc_step(factors, s, 1.2, 1.1)
    # "restart" from a deep copy of the state
    s2 = apc.APCState(*[jnp.array(v) for v in s])
    for _ in range(20):
        s = apc.apc_step(factors, s, 1.2, 1.1)
        s2 = apc.apc_step(factors, s2, 1.2, 1.1)
    assert float(jnp.linalg.norm(s.xbar - s2.xbar)) == 0.0
