import jax
import pytest

# Solver tests need float64 (the paper's setting); model tests force f32
# configs explicitly.  NOTE: do not set XLA_FLAGS here — smoke tests and
# benches must see 1 device (the 512-device meshes live only in dryrun.py).
jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    import numpy as np
    return np.random.default_rng(0)
