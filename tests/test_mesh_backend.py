"""Mesh execution backend: every registered solver through shard_map.

In-process tests run on a (1, 1) mesh — the full backend path (specs,
on-mesh prepare/init, shard_mapped scan, collectives) executes, the axes
just have size 1.  The true multi-device parity check (2 x 2 data x model
mesh, forced host devices) runs as a slow subprocess test, mirrored by the
tier-1 smoke in scripts/ci.sh.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers import mesh as mesh_backend

ALL = ["apc", "cimmino", "consensus", "dgd", "dhbm", "dnag", "madmm",
       "pdhbm"]
ITERS = 150

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


def _assert_history_match(r_mesh, r_loc):
    np.testing.assert_allclose(np.asarray(r_mesh.x), np.asarray(r_loc.x),
                               rtol=1e-8, atol=1e-10)
    # rtol 1e-6 is the contract; atol covers the converged noise floor
    # where both histories sit at machine epsilon.
    np.testing.assert_allclose(np.asarray(r_mesh.residuals),
                               np.asarray(r_loc.residuals),
                               rtol=1e-6, atol=1e-12)


@pytest.mark.parametrize("name", ALL)
def test_mesh_matches_local(sys_, mesh, name):
    """backend='mesh' returns the same SolveResult as the local driver."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_loc = s.solve(sys_, iters=ITERS, **prm)
    r_mesh = s.solve(sys_, iters=ITERS, backend="mesh", mesh=mesh, **prm)
    assert r_mesh.name == name
    assert r_mesh.residuals.shape == (ITERS,)
    assert r_mesh.errors is not None          # x_true given -> error history
    assert r_mesh.params == prm
    _assert_history_match(r_mesh, r_loc)
    np.testing.assert_allclose(np.asarray(r_mesh.errors),
                               np.asarray(r_loc.errors),
                               rtol=1e-6, atol=1e-12)
    assert r_mesh.iters_to_tol == r_loc.iters_to_tol


@pytest.mark.parametrize("name", ALL)
def test_mesh_state_roundtrips_with_local(sys_, mesh, name):
    """Warm starts cross backends both ways: mesh -> local and local ->
    mesh resume exactly like an uninterrupted run."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    full = s.solve(sys_, iters=100, **prm)

    half_m = s.solve(sys_, iters=50, backend="mesh", mesh=mesh, **prm)
    res_l = s.solve(sys_, iters=50, warm_state=jax.device_get(half_m.state),
                    **prm)
    np.testing.assert_allclose(np.asarray(res_l.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)
    assert int(res_l.state.t) == 100

    half_l = s.solve(sys_, iters=50, **prm)
    res_m = s.solve(sys_, iters=50, backend="mesh", mesh=mesh,
                    warm_state=half_l.state, **prm)
    np.testing.assert_allclose(np.asarray(res_m.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)
    assert int(res_m.state.t) == 100


def test_mesh_state_roundtrips_through_checkpoint(sys_, mesh, tmp_path):
    from repro.checkpoint import ckpt
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r1 = s.solve(sys_, iters=40, backend="mesh", mesh=mesh, **prm)
    ckpt.save(str(tmp_path), 40, r1.state)
    restored = ckpt.restore(str(tmp_path), r1.state)
    r2 = s.solve(sys_, iters=40, backend="mesh", mesh=mesh,
                 warm_state=restored, **prm)
    full = s.solve(sys_, iters=80, **prm)
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("name", ["apc", "dhbm", "madmm"])
def test_mesh_solve_many_matches_local(sys_, mesh, name):
    s = solvers.get(name)
    B = np.random.default_rng(4).standard_normal((3, sys_.N))
    rm = s.solve_many(sys_, B, iters=100, backend="mesh", mesh=mesh)
    rl = s.solve_many(sys_, B, iters=100)
    assert rm.x.shape == (3, sys_.n)
    assert rm.residuals.shape == (3, 100)
    assert rm.errors is None
    _assert_history_match(rm, rl)
    np.testing.assert_array_equal(np.asarray(rm.iters_to_tol),
                                  np.asarray(rl.iters_to_tol))


def test_mesh_rejects_kernel_and_unknown_backend(sys_, mesh):
    # use_kernel now COMPOSES with backend="mesh" for the projection
    # family (see test_kernel_engine.py); it must still be rejected for
    # solvers without a kernel path, same as on the local backend.
    s = solvers.get("dgd")
    with pytest.raises(ValueError, match="use_kernel"):
        s.solve(sys_, iters=5, backend="mesh", mesh=mesh, use_kernel=True)
    s = solvers.get("apc")
    with pytest.raises(ValueError, match="backend"):
        s.solve(sys_, iters=5, backend="bogus")
    with pytest.raises(ValueError, match="backend='mesh'"):
        s.solve(sys_, iters=5, mesh=mesh)      # mesh given, backend local
    with pytest.raises(ValueError, match="backend='mesh'"):
        s.solve_many(sys_, np.ones((2, sys_.N)), iters=5, mesh=mesh)


def test_mesh_context_validates_axes(sys_):
    mesh1 = mesh_lib.make_compat_mesh((1,), ("data",))
    ctx = mesh_backend.make_context(mesh1, sys_)   # model axis: absent -> None
    assert ctx.model_axis is None and ctx.worker_axes == ("data",)
    with pytest.raises(ValueError, match="worker axes"):
        mesh_backend.make_context(mesh1, sys_, worker_axes=("pod",))


def test_unimplemented_solver_raises(sys_, mesh):
    class Bare(solvers.Solver):
        name = "bare"

    with pytest.raises(NotImplementedError, match="mesh backend"):
        mesh_backend.solve_mesh(Bare(), sys_, mesh=mesh, iters=2)


@pytest.mark.slow
def test_all_solvers_mesh_parity_2x2_subprocess():
    """Acceptance check: every registered solver on a 4-device 2 x 2
    (data x model) host mesh matches its single-host residual history."""
    code = """
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh

assert len(jax.devices()) == 4
sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ('data', 'model'))
for name in solvers.available():
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    rl = s.solve(sys_, iters=150, **prm)
    rm = s.solve(sys_, iters=150, backend='mesh', mesh=mesh, **prm)
    assert np.allclose(np.asarray(rm.residuals), np.asarray(rl.residuals),
                       rtol=1e-6, atol=1e-12), name
    assert np.allclose(np.asarray(rm.x), np.asarray(rl.x),
                       rtol=1e-8, atol=1e-10), name
print('OK')
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4"),
        capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout[-1000:] + r.stderr[-2000:]
