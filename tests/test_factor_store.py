"""FactorStore: content addressing, LRU, disk persistence, drift rejection.

The serving contract under test: a store hit must be indistinguishable —
BIT-exact — from re-running ``prepare``, across processes (disk tier) and
across backends (local and mesh), and any manifest drift must fail loudly
instead of silently casting.
"""
import json
import os

import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.solvers.store import FactorStore, fingerprint


@pytest.fixture(scope="module")
def sys_a():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=0)


@pytest.fixture(scope="module")
def sys_b():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=1)


def _tree_equal(t1, t2):
    import jax
    l1, d1 = jax.tree.flatten(t1)
    l2, d2 = jax.tree.flatten(t2)
    return d1 == d2 and all(
        np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(l1, l2))


# ---------------------------------------------------------------------------
# fingerprinting
# ---------------------------------------------------------------------------


def test_fingerprint_is_content_addressed(sys_a, sys_b):
    prm = {"gamma": 1.0, "eta": 1.0}
    k = fingerprint("apc", sys_a, prm)
    assert k == fingerprint("apc", sys_a, prm)            # deterministic
    assert k != fingerprint("apc", sys_b, prm)            # different A
    assert k != fingerprint("cimmino", sys_a, prm)        # different solver
    assert k != fingerprint("apc", sys_a, {"gamma": 1.5, "eta": 1.0})


def test_fingerprint_normalizes_numeric_param_types(sys_a):
    # auto-tuned params arrive as numpy scalars, hand-passed ones as
    # Python floats — they must hash identically or disk entries written
    # by one call path are never hit by the other
    k_py = fingerprint("apc", sys_a, {"gamma": 1.25, "eta": 1.5})
    k_np = fingerprint("apc", sys_a, {"gamma": np.float64(1.25),
                                      "eta": np.float64(1.5)})
    assert k_py == k_np


def test_fingerprint_sees_partition_not_just_content(sys_a):
    from repro.core.partition import partition
    A, b = sys_a.dense()
    re2 = partition(A, b, 2, x_true=sys_a.x_true)         # same A, m=2
    prm = {"gamma": 1.0, "eta": 1.0}
    assert fingerprint("apc", sys_a, prm) != fingerprint("apc", re2, prm)


def test_fingerprint_separates_sparse_from_densified():
    # a sparse system and its parity twin share the SAME A_blocks bytes —
    # only the structure tag differs — and must never collide, or a
    # dense-prepared factorization gets served to the sparse path
    sp = linsys.banded_system(n=96, m=4, bandwidth=6, seed=0)
    prm = {"gamma": 1.0, "eta": 1.0}
    assert sp.is_sparse
    assert fingerprint("apc", sp, prm) != fingerprint("apc",
                                                      sp.densified(), prm)


def test_dense_fingerprint_ignores_sparse_fields():
    # the sparse tokens are appended ONLY for sparse systems: a dense
    # system built any way (densified twin vs fresh partition of the same
    # arrays) digests identically, so pre-refactor disk entries stay hot
    from repro.core.partition import BlockSystem
    sp = linsys.banded_system(n=96, m=4, bandwidth=6, seed=0)
    dn = sp.densified()
    rebuilt = BlockSystem(sp.A_blocks, sp.b_blocks, x_true=sp.x_true)
    prm = {"gamma": 1.0, "eta": 1.0}
    assert fingerprint("apc", dn, prm) == fingerprint("apc", rebuilt, prm)


def test_fingerprint_sees_sparse_support_pattern():
    # same values on the diagonal band, different declared support widths
    # -> different compressed operands -> different keys
    sp1 = linsys.banded_system(n=96, m=4, bandwidth=6, seed=0)
    sp2 = linsys.banded_system(n=96, m=4, bandwidth=8, seed=0)
    prm = {"gamma": 1.0, "eta": 1.0}
    assert fingerprint("apc", sp1, prm) != fingerprint("apc", sp2, prm)


# ---------------------------------------------------------------------------
# memory tier
# ---------------------------------------------------------------------------


def test_memory_hit_returns_same_object(sys_a):
    store = FactorStore()
    s = solvers.get("apc")
    f1 = store.factors(s, sys_a, gamma=1.0, eta=1.0)
    f2 = store.factors(s, sys_a, gamma=1.0, eta=1.0)
    assert f2 is f1
    assert store.stats.misses == 1 and store.stats.hits == 1


def test_lru_eviction(sys_a, sys_b):
    store = FactorStore(capacity=1)
    s = solvers.get("apc")
    store.factors(s, sys_a, gamma=1.0, eta=1.0)
    store.factors(s, sys_b, gamma=1.0, eta=1.0)           # evicts sys_a
    assert len(store) == 1 and store.stats.evictions == 1
    store.factors(s, sys_a, gamma=1.0, eta=1.0)           # miss again
    assert store.stats.misses == 3 and store.stats.hits == 0


def test_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        FactorStore(capacity=0)


# ---------------------------------------------------------------------------
# kernel-path augmentation is idempotent and cached
# ---------------------------------------------------------------------------


def test_kernel_factors_idempotent(sys_a):
    s = solvers.get("apc")
    prm = {"gamma": 1.0, "eta": 1.0}
    f = s.prepare(sys_a.A_blocks, prm)
    aug = s.kernel_factors(f)
    assert aug.B is not None
    assert s.kernel_factors(aug) is aug                   # detect, no re-run


def test_store_augments_entry_once(sys_a):
    store = FactorStore()
    s = solvers.get("apc")
    f1 = store.factors(s, sys_a, use_kernel=True, gamma=1.0, eta=1.0)
    assert f1.B is not None
    # the augmented factors were written back: a second kernel hit gets the
    # SAME object (no pinv recomputation), and a plain hit sees it too
    f2 = store.factors(s, sys_a, use_kernel=True, gamma=1.0, eta=1.0)
    f3 = store.factors(s, sys_a, gamma=1.0, eta=1.0)
    assert f2 is f1 and f3 is f1
    assert store.stats.misses == 1 and store.stats.hits == 2


# ---------------------------------------------------------------------------
# solve(store=) wiring
# ---------------------------------------------------------------------------


def test_solve_through_store_is_bit_exact(sys_a):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_a)
    fresh = s.solve(sys_a, iters=40, **prm)
    store = FactorStore()
    r1 = s.solve(sys_a, iters=40, store=store, **prm)
    r2 = s.solve(sys_a, iters=40, store=store, **prm)
    for r in (r1, r2):
        assert np.array_equal(np.asarray(r.residuals),
                              np.asarray(fresh.residuals))
        assert np.array_equal(np.asarray(r.x), np.asarray(fresh.x))
    assert store.stats.misses == 1 and store.stats.hits == 1


def test_solve_many_through_store(sys_a):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_a)
    B = np.random.default_rng(0).standard_normal((3, sys_a.N))
    fresh = s.solve_many(sys_a, B, iters=40, **prm)
    store = FactorStore()
    r1 = s.solve_many(sys_a, B, iters=40, store=store, **prm)
    r2 = s.solve_many(sys_a, B, iters=40, store=store, **prm)
    assert store.stats.misses == 1 and store.stats.hits == 1
    for r in (r1, r2):
        assert np.array_equal(np.asarray(r.residuals),
                              np.asarray(fresh.residuals))


def test_redundant_solve_through_store(sys_a):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_a)
    store = FactorStore()
    r0 = s.solve(sys_a, iters=40, **prm)
    r1 = s.solve(sys_a, iters=40, redundancy=2, store=store, **prm)
    assert store.stats.misses == 1
    assert np.allclose(np.asarray(r1.residuals), np.asarray(r0.residuals),
                       rtol=1e-6, atol=1e-12)


def test_mesh_solve_prepares_on_mesh_and_shares_the_entry(sys_a):
    # a mesh-backend miss must NOT fall back to a host prepare: the
    # on-mesh mesh_prepare runs and its result is inserted, after which
    # BOTH backends hit the same entry
    s = solvers.get("apc")
    prm = s.resolve_params(sys_a)
    store = FactorStore()
    r1 = s.solve(sys_a, iters=40, backend="mesh", store=store, **prm)
    assert store.stats.misses == 1
    r2 = s.solve(sys_a, iters=40, backend="mesh", store=store, **prm)
    assert store.stats.hits == 1
    r3 = s.solve(sys_a, iters=40, store=store, **prm)          # local hit
    assert store.stats.hits == 2 and store.stats.misses == 1
    for r in (r2, r3):
        assert np.allclose(np.asarray(r.residuals),
                           np.asarray(r1.residuals), rtol=1e-6, atol=1e-12)


def test_resume_without_cached_factors_counts_as_miss(sys_a):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_a)
    prior = s.solve(sys_a, iters=10, **prm)
    store = FactorStore()                       # cold store: resume re-pays
    s.solve(sys_a, iters=10, warm_state=prior.state, store=store, **prm)
    assert store.stats.resume_misses == 1 and store.stats.misses == 1
    # resuming again is a hit — no resume miss recorded
    s.solve(sys_a, iters=10, warm_state=prior.state, store=store, **prm)
    assert store.stats.resume_misses == 1 and store.stats.hits == 1


# ---------------------------------------------------------------------------
# disk tier: persistence across "processes", both backends, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["apc", "pdhbm"])   # projection + gradient
@pytest.mark.parametrize("backend", ["local", "mesh"])
def test_disk_round_trip_bit_exact(tmp_path, sys_a, name, backend):
    s = solvers.get(name)
    prm = s.resolve_params(sys_a)
    store1 = FactorStore(directory=str(tmp_path))
    f_fresh = store1.factors(s, sys_a, **prm)             # miss + disk write
    assert store1.stats.disk_writes == 1

    # a COLD store over the same directory models a restarted process: the
    # factorization must come back from disk, structure included, with no
    # prepare template available
    store2 = FactorStore(directory=str(tmp_path))
    f_restored = store2.factors(s, sys_a, **prm)
    assert store2.stats.disk_hits == 1 and store2.stats.misses == 0
    assert _tree_equal(f_fresh, f_restored)

    kw = {"backend": backend} if backend == "mesh" else {}
    r_fresh = s.solve(sys_a, iters=40, factors=f_fresh, **prm, **kw)
    r_rest = s.solve(sys_a, iters=40, factors=f_restored, **prm, **kw)
    assert np.array_equal(np.asarray(r_fresh.residuals),
                          np.asarray(r_rest.residuals))
    assert np.array_equal(np.asarray(r_fresh.x), np.asarray(r_rest.x))


@pytest.mark.parametrize("name", ["apc", "cimmino"])
def test_sparse_disk_round_trip_bit_exact(tmp_path, name):
    # sparse factors (SparseBlocks leaves included) survive the disk tier
    # and drive a bit-equal solve after a cold restart
    sp = linsys.banded_system(n=96, m=4, bandwidth=6, seed=0)
    s = solvers.get(name)
    prm = s.resolve_params(sp)
    store1 = FactorStore(directory=str(tmp_path))
    f_fresh = store1.factors(s, sp, **prm)
    assert store1.stats.disk_writes == 1

    store2 = FactorStore(directory=str(tmp_path))
    f_restored = store2.factors(s, sp, **prm)
    assert store2.stats.disk_hits == 1 and store2.stats.misses == 0
    assert _tree_equal(f_fresh, f_restored)

    r_fresh = s.solve(sp, iters=60, factors=f_fresh, **prm)
    r_rest = s.solve(sp, iters=60, factors=f_restored, **prm)
    assert np.array_equal(np.asarray(r_fresh.residuals),
                          np.asarray(r_rest.residuals))
    assert np.array_equal(np.asarray(r_fresh.x), np.asarray(r_rest.x))


def test_sparse_manifest_records_structure_and_rejects_drift(tmp_path):
    sp = linsys.banded_system(n=96, m=4, bandwidth=6, seed=0)
    s = solvers.get("apc")
    prm = s.resolve_params(sp)
    store = FactorStore(directory=str(tmp_path))
    store.factors(s, sp, **prm)
    key = store.key(s, sp, **prm)
    manifest = json.loads((tmp_path / key / "manifest.json").read_text())
    assert manifest["system_structure"] == "sparse"
    _tamper(tmp_path, key, "system_structure", "dense")
    store2 = FactorStore(directory=str(tmp_path))
    with pytest.raises(ValueError, match="holds 'dense' factors"):
        store2.factors(s, sp, **prm)


def test_disk_entry_layout_matches_checkpoint_contract(tmp_path, sys_a):
    from repro.checkpoint.ckpt import COMMIT
    s = solvers.get("apc")
    store = FactorStore(directory=str(tmp_path))
    store.factors(s, sys_a, gamma=1.0, eta=1.0)
    key = store.key(s, sys_a, gamma=1.0, eta=1.0)
    entry = tmp_path / key
    assert (entry / COMMIT).exists()                      # sealed
    assert (entry / "manifest.json").exists()
    manifest = json.loads((entry / "manifest.json").read_text())
    assert manifest["solver"] == "apc"
    assert manifest["partition"] == [sys_a.m, sys_a.p, sys_a.n]
    n_leaves = len(manifest["leaves"])
    assert all((entry / f"leaf_{i:05d}.npy").exists()
               for i in range(n_leaves))


def test_uncommitted_entry_is_ignored(tmp_path, sys_a):
    from repro.checkpoint.ckpt import COMMIT
    s = solvers.get("apc")
    store = FactorStore(directory=str(tmp_path))
    store.factors(s, sys_a, gamma=1.0, eta=1.0)
    key = store.key(s, sys_a, gamma=1.0, eta=1.0)
    os.remove(tmp_path / key / COMMIT)                    # crashed mid-write
    store2 = FactorStore(directory=str(tmp_path))
    store2.factors(s, sys_a, gamma=1.0, eta=1.0)
    assert store2.stats.misses == 1 and store2.stats.disk_hits == 0


def _tamper(tmp_path, key, field, value):
    path = tmp_path / key / "manifest.json"
    manifest = json.loads(path.read_text())
    manifest[field] = value
    path.write_text(json.dumps(manifest))


@pytest.mark.parametrize("field,value,match", [
    ("dtype", "float32", "dtype"),
    ("partition", [8, 6, 48], "partition"),
    ("solver", "cimmino", "solver"),
])
def test_manifest_drift_fails_loudly(tmp_path, sys_a, field, value, match):
    s = solvers.get("apc")
    store = FactorStore(directory=str(tmp_path))
    store.factors(s, sys_a, gamma=1.0, eta=1.0)
    key = store.key(s, sys_a, gamma=1.0, eta=1.0)
    _tamper(tmp_path, key, field, value)
    store2 = FactorStore(directory=str(tmp_path))
    with pytest.raises(ValueError, match=match):
        store2.factors(s, sys_a, gamma=1.0, eta=1.0)


def test_corrupt_leaf_fails_loudly(tmp_path, sys_a):
    s = solvers.get("apc")
    store = FactorStore(directory=str(tmp_path))
    store.factors(s, sys_a, gamma=1.0, eta=1.0)
    key = store.key(s, sys_a, gamma=1.0, eta=1.0)
    np.save(tmp_path / key / "leaf_00000.npy", np.zeros((2, 2)))
    store2 = FactorStore(directory=str(tmp_path))
    with pytest.raises(ValueError, match="corrupt"):
        store2.factors(s, sys_a, gamma=1.0, eta=1.0)
