"""HLO cost walker: trip-count scaling, dot FLOPs, collective attribution."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import analysis


def _compiled(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_scan_flops_scale_with_trip_count():
    w_s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x_s = jax.ShapeDtypeStruct((128, 256), jnp.float32)

    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    c_scan = analysis.hlo_cost(_compiled(f_scan, x_s, w_s).as_text())
    c_unr = analysis.hlo_cost(_compiled(f_unroll, x_s, w_s).as_text())
    expected = 2 * 128 * 256 * 256 * 10
    assert c_scan.flops == pytest.approx(expected, rel=0.05)
    assert c_unr.flops == pytest.approx(expected, rel=0.05)
    # the stock cost_analysis undercounts the scan (regression guard for
    # why this module exists):
    ca = _compiled(f_scan, x_s, w_s).cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] < expected / 5


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)

    def f(x, y):
        return jnp.einsum("bij,bjk->bik", x, y)

    c = analysis.hlo_cost(_compiled(f, a, b).as_text())
    assert c.flops == pytest.approx(2 * 4 * 64 * 32 * 16, rel=0.05)


def test_dynamic_slice_bytes_not_full_operand():
    big = jax.ShapeDtypeStruct((1000, 256), jnp.float32)

    def f(w):
        def body(acc, i):
            sl = jax.lax.dynamic_slice(w, (i, 0), (1, 256))
            return acc + sl[0], None
        return jax.lax.scan(body, jnp.zeros(256), jnp.arange(100))[0]

    c = analysis.hlo_cost(_compiled(f, big).as_text())
    # 100 iterations x ~KBs per step, NOT 100 x 1MB
    assert c.bytes < 5e6


def test_collective_parse_synthetic():
    hlo = """
HloModule test

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128]{0} get-tuple-element(%p), index=1
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[128])) -> pred[] {
  %p = (s32[], f32[128]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  %ag = f32[512]{0} all-gather(%a), dimensions={0}
  %zero = s32[] constant(0)
  %tup = (s32[], f32[128]) tuple(%zero, %a)
  %w = (s32[], f32[128]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    c = analysis.hlo_cost(hlo)
    assert c.coll["all-gather"] == 512 * 4
    assert c.coll["all-reduce"] == 7 * 128 * 4


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(
        name="x", mesh_shape=(16, 16), flops_per_device=1.97e12,
        hbm_bytes_per_device=819e9, collective_bytes_per_device=5e9,
        model_flops=1.97e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(0.01)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.1)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.005)
