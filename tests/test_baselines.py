"""Section 4 baselines: convergence + Table 1 rate ordering."""
import pytest

from repro.core import baselines, precond, spectral
from repro.data import linsys


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=80, m=4, cond=15.0, seed=3)


@pytest.mark.parametrize("method,tol", [
    ("dgd", 1e-3), ("dnag", 1e-6), ("dhbm", 1e-6),
    # M-ADMM is the slowest method in the paper (Table 2, orders of
    # magnitude behind) — only a loose decrease is asserted.
    ("madmm", 5e-2), ("cimmino", 1e-3), ("consensus", 1e-3)])
def test_method_converges(sys_, method, tol):
    hist = getattr(baselines, method)(sys_, iters=2500)
    assert float(hist.errors[-1]) < tol, hist.name


def test_table1_rate_ordering(sys_):
    """APC <= D-HBM <= D-NAG <= DGD and APC <= Cimmino (Table 1)."""
    s = spectral.rates_summary(sys_)
    assert s["APC"] <= s["D-HBM"] + 1e-12
    assert s["D-HBM"] <= s["D-NAG"] + 1e-12
    assert s["D-NAG"] <= s["DGD"] + 1e-12
    assert s["APC"] <= s["B-Cimmino"] + 1e-12
    assert s["APC"] <= s["Consensus"] + 1e-12


def test_empirical_ordering(sys_):
    """After a fixed budget, APC's error <= the gradient-family errors."""
    iters = 400
    from repro.core import apc as apc_mod
    e_apc = float(apc_mod.solve(sys_, iters=iters).errors[-1])
    for fn in (baselines.dgd, baselines.dnag, baselines.dhbm,
               baselines.cimmino, baselines.consensus):
        e = float(fn(sys_, iters=iters).errors[-1])
        assert e_apc <= e * 1.5 + 1e-12


def test_preconditioned_dhbm_matches_apc_rate(sys_):
    """Section 6: P-DHBM achieves the APC rate (kappa(C^T C) == kappa(X))."""
    pre = precond.precondition(sys_)
    lmin, lmax = spectral.ata_extremes(pre)
    X = spectral.x_matrix(sys_)
    mu_min, mu_max = spectral.mu_extremes(X)
    # C^T C = m X exactly
    assert lmax / lmin == pytest.approx(mu_max / mu_min, rel=1e-6)
    hist = precond.preconditioned_dhbm(sys_, iters=500)
    assert float(hist.errors[-1]) < 1e-8


def test_nonzero_mean_gap():
    """Paper Table 2 row 5: for nonzero-mean Gaussians kappa(A^T A) blows up
    while kappa(X) stays moderate -> APC's advantage grows."""
    s0 = spectral.rates_summary(linsys.standard_gaussian(n=120, m=4, seed=5))
    s1 = spectral.rates_summary(
        linsys.nonzero_mean_gaussian(n=120, m=4, seed=5))
    t = spectral.convergence_time
    gap0 = t(s0["D-HBM"]) / t(s0["APC"])
    gap1 = t(s1["D-HBM"]) / t(s1["APC"])
    assert s1["kappa_AtA"] > 10 * s0["kappa_AtA"]
    assert gap1 > gap0
