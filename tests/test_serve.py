"""Serving driver queue semantics: FIFO order, padding never counted."""
from collections import deque

import pytest

from repro.launch.serve import take_group


def test_take_group_fifo_and_padding_accounting():
    queue = deque(range(5))
    served, reals = [], []
    while queue:
        group, n_real = take_group(queue, 2)
        assert len(group) == 2                 # compiled batch shape stable
        served.extend(group[:n_real])
        reals.append(n_real)
    assert served == [0, 1, 2, 3, 4]           # FIFO, not LIFO
    assert reals == [2, 2, 1]                  # last group is padded...
    assert sum(reals) == 5                     # ...but padding is not traffic


def test_take_group_pads_by_repeating_last():
    queue = deque([7])
    group, n_real = take_group(queue, 3)
    assert group == [7, 7, 7] and n_real == 1
    assert not queue


def test_take_group_exact_batch_no_padding():
    queue = deque([1, 2, 3])
    group, n_real = take_group(queue, 3)
    assert group == [1, 2, 3] and n_real == 3


def test_take_group_rejects_nonpositive_batch():
    # batch=0 would otherwise never drain the queue (infinite serve loop)
    with pytest.raises(ValueError, match="batch"):
        take_group(deque([1]), 0)
