"""ExecutionPlan consolidation (repro.solvers.capability).

PR 10's api_redesign contract: the loose execution-surface kwargs of
``solve`` / ``solve_many`` (``backend=``, ``mesh=``, ``use_kernel=``,
``precision=``, ``redundancy=``, ``alive_schedule=``, ``warm_state=``,
``factors=``, ``store=``, ``worker_axes=``, ``model_axis=``) survive
only as a deprecation shim that builds the SAME plan —

  * the plan path is BIT-IDENTICAL to the legacy-kwarg path for every
    combination of solver x backend x kernel x redundancy exercised
    here (same jit cache keys, same numerics, no epsilon);
  * a legacy call emits exactly ONE DeprecationWarning, however many
    loose kwargs it passes; the plan path emits none;
  * mixing ``plan=`` with loose kwargs is an error, never a silent
    merge (the plan must not lie about what runs).

Internal call sites are held to the plan surface by lint rule R009.
"""
import warnings

import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers.capability import ExecutionPlan

PROJ = ["apc", "consensus", "cimmino"]
ITERS = 80


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


def _legacy(call, **kw):
    """Run a legacy-kwarg call asserting the one-warning contract."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = call(**kw)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "ExecutionPlan" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    return out


def _combos(sys_, mesh):
    sched = np.stack([np.array([i != (t % sys_.m) for i in range(sys_.m)])
                      for t in range(ITERS)])
    return {
        "local": {},
        "kernel": {"use_kernel": True},
        "mesh": {"backend": "mesh", "mesh": mesh},
        "mesh_kernel": {"backend": "mesh", "mesh": mesh,
                        "use_kernel": True},
        "redundant": {"redundancy": 2, "alive_schedule": sched},
    }


_KEYMAP = {"use_kernel": "kernel"}


def _plan_of(legacy_kw):
    return ExecutionPlan(**{_KEYMAP.get(k, k): v
                            for k, v in legacy_kw.items()})


@pytest.mark.parametrize("combo", ["local", "kernel", "mesh",
                                   "mesh_kernel", "redundant"])
@pytest.mark.parametrize("name", PROJ)
def test_plan_bit_identical_to_legacy_kwargs(sys_, mesh, name, combo):
    legacy_kw = _combos(sys_, mesh)[combo]
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_old = _legacy(s.solve, sys=sys_, iters=ITERS, **legacy_kw, **prm) \
        if legacy_kw else s.solve(sys_, iters=ITERS, **prm)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        r_new = s.solve(sys_, iters=ITERS, plan=_plan_of(legacy_kw), **prm)
    assert np.array_equal(np.asarray(r_new.x), np.asarray(r_old.x))
    assert np.array_equal(np.asarray(r_new.residuals),
                          np.asarray(r_old.residuals))


def test_solve_many_plan_bit_identical(sys_):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    B = np.linspace(-1.0, 1.0, 3 * sys_.N).reshape(3, sys_.N)
    r_old = _legacy(s.solve_many, sys=sys_, B=B, iters=ITERS,
                    use_kernel=True, **prm)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        r_new = s.solve_many(sys_, B, iters=ITERS,
                             plan=ExecutionPlan(kernel=True), **prm)
    assert np.array_equal(np.asarray(r_new.x), np.asarray(r_old.x))
    assert np.array_equal(np.asarray(r_new.residuals),
                          np.asarray(r_old.residuals))


def test_warm_start_kwarg_shim_matches_plan(sys_):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    half = s.solve(sys_, iters=40, **prm)
    r_old = _legacy(s.solve, sys=sys_, iters=40, warm_state=half.state,
                    **prm)
    r_new = s.solve(sys_, iters=40,
                    plan=ExecutionPlan(warm_state=half.state), **prm)
    assert np.array_equal(np.asarray(r_new.x), np.asarray(r_old.x))


def test_one_warning_however_many_kwargs(sys_, mesh):
    """Three loose kwargs, one warning — the shim warns per CALL."""
    s = solvers.get("apc")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        s.solve(sys_, iters=5, backend="mesh", mesh=mesh, use_kernel=True,
                precision="default")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    msg = str(dep[0].message)
    assert "ExecutionPlan" in msg and "plan=" in msg


def test_plan_plus_legacy_kwargs_is_an_error(sys_, mesh):
    s = solvers.get("apc")
    with pytest.raises(ValueError, match="both plan="):
        s.solve(sys_, iters=5, plan=ExecutionPlan(), backend="mesh",
                mesh=mesh)
    with pytest.raises(ValueError, match="both plan="):
        s.solve_many(sys_, np.ones((2, sys_.N)), iters=5,
                     plan=ExecutionPlan(), use_kernel=True)


def test_plan_type_checked(sys_):
    with pytest.raises(TypeError, match="ExecutionPlan"):
        solvers.get("apc").solve(sys_, iters=5, plan={"kernel": True})
