"""Property tests (hypothesis) for the spectral analysis layer."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional property-testing dep not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import spectral
from repro.data import linsys


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([32, 48, 64]), m=st.sampled_from([2, 4]),
       cond=st.floats(1.5, 1e4), seed=st.integers(0, 1000))
def test_X_eigenvalues_in_unit_interval(n, m, cond, seed):
    sys_ = linsys.conditioned_gaussian(n=n, m=m, cond=cond, seed=seed)
    X = spectral.x_matrix(sys_)
    w = np.linalg.eigvalsh(X)
    assert w[0] > -1e-10
    assert w[-1] < 1.0 + 1e-10


@settings(max_examples=50, deadline=None)
@given(mu_min=st.floats(1e-8, 0.99), ratio=st.floats(1.0001, 1e6))
def test_apc_optimal_properties(mu_min, ratio):
    mu_max = min(mu_min * ratio, 1.0)
    if mu_max <= mu_min:
        mu_max = min(mu_min * 1.001, 1.0)
    p = spectral.apc_optimal(mu_min, mu_max)
    assert 0.0 <= p.rho < 1.0
    assert 0.0 <= p.gamma <= 2.0
    # optimality system holds — compare on the sqrt scale (dodges the
    # cancellation of (1 - rho)^2 at large kappa) and against the
    # closed-form rho: recomputing rho from (gamma-1)(eta-1) hits the f64
    # representation floor of gamma-1 ~ rho^2/eta when eta is huge.
    s = p.eta * p.gamma
    np.testing.assert_allclose(np.sqrt(mu_max * s), 1.0 + p.rho, rtol=1e-5)
    np.testing.assert_allclose(np.sqrt(mu_min * s), 1.0 - p.rho, rtol=1e-4,
                               atol=1e-7)
    rho_re = np.sqrt(max((p.gamma - 1) * (p.eta - 1), 0.0))
    tol = 1e-7 + np.sqrt(2.3e-16 * max(p.eta, 1.0))
    assert abs(rho_re - p.rho) <= tol


@settings(max_examples=30, deadline=None)
@given(k=st.floats(1.0001, 1e8))
def test_rate_formulas_ordering(k):
    """Table 1 closed forms: rho_APC(kappa) <= rho_HBM(kappa) etc."""
    lmin, lmax = 1.0, k
    _, rho_dgd = spectral.dgd_optimal(lmin, lmax)
    _, _, rho_nag = spectral.dnag_optimal(lmin, lmax)
    _, _, rho_hbm = spectral.dhbm_optimal(lmin, lmax)
    assert rho_hbm <= rho_nag + 1e-12 <= rho_dgd + 2e-12
    t = spectral.convergence_time
    assert t(rho_hbm) <= t(rho_nag) <= t(rho_dgd) or k < 1.01


def test_convergence_time_edges():
    assert spectral.convergence_time(1.0) == float("inf")
    assert spectral.convergence_time(0.0) == 0.0
    assert spectral.convergence_time(np.exp(-1.0)) == pytest.approx(1.0)
