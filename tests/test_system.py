"""End-to-end system tests: drivers, distributed equivalence (subprocess,
multi-device), consensus combinator, APC probe head."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(code, extra_env=None, timeout=600):
    env = dict(ENV, **(extra_env or {}))
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_distributed_apc_equals_reference_subprocess():
    """shard_map APC on an 8-device (4 data x 2 model) mesh == vmap APC."""
    code = """
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np
from repro.data import linsys
from repro.core import apc, distributed
from repro.launch.mesh import make_compat_mesh
mesh = make_compat_mesh((4, 2), ('data', 'model'))
sys_ = linsys.conditioned_gaussian(n=128, m=4, cond=20.0, seed=1)
xbar, res = distributed.solve_on_mesh(mesh, sys_, iters=200)
ref = apc.solve(sys_, iters=200)
d = float(np.linalg.norm(np.asarray(xbar) - np.asarray(ref.x)))
assert d < 1e-10, d
assert res < 1e-9, res
print('OK')
"""
    r = _run(code, {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert "OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run entrypoint lowers+compiles a cell on the 512-device
    multi-pod mesh (the minimal multi-pod contract check in CI)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k", "--multi-pod"],
        env=ENV, capture_output=True, text=True, timeout=900)
    assert "0 FAILED" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_train_driver_checkpoints_and_resumes(tmp_path):
    d = str(tmp_path / "ck")
    args = ["-m", "repro.launch.train", "--arch", "mamba2-130m", "--smoke",
            "--steps", "6", "--batch", "2", "--seq", "32",
            "--ckpt-dir", d, "--ckpt-every", "3"]
    r1 = subprocess.run([sys.executable] + args, env=ENV,
                        capture_output=True, text=True, timeout=900)
    assert "checkpoint" in r1.stdout, r1.stderr[-2000:]
    args[args.index("6")] = "8"
    r2 = subprocess.run([sys.executable] + args, env=ENV,
                        capture_output=True, text=True, timeout=900)
    assert "resumed from step 6" in r2.stdout, r2.stdout


@pytest.mark.slow
def test_elastic_remesh_resume_subprocess(tmp_path):
    """Full fault-tolerance cycle: solve on a 4-worker-shard mesh,
    checkpoint, 'lose' half the devices, resume the SAME solver state on a
    2-shard mesh — final iterate matches an uninterrupted run."""
    code = f"""
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np
import jax.numpy as jnp
from repro.checkpoint import ckpt
from repro.core import distributed, spectral
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh
from repro.runtime import fault

sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=5)
prm = spectral.apc_optimal(*spectral.mu_extremes(spectral.x_matrix(sys_)))

def run(mesh_shape, x, xbar, iters):
    mesh = make_compat_mesh(mesh_shape, ('data', 'model'))
    s = distributed.make_sharded_apc(mesh, gamma=prm.gamma, eta=prm.eta)
    A_, b, chol, x0, xb0 = distributed.prepare_on_mesh(s, sys_)
    step = s.step_fn()
    if x is None:
        x, xbar = x0, xb0
    else:
        x, xbar = jnp.asarray(x), jnp.asarray(xbar)

    @jax.jit
    def many(A_, chol, x, xbar):
        def body(carry, _):
            x, xbar = carry
            return step(A_, chol, x, xbar), None
        (x, xbar), _ = jax.lax.scan(body, (x, xbar), None, length=iters)
        return x, xbar

    x, xbar = many(A_, chol, x, xbar)
    return np.asarray(x), np.asarray(xbar)

# uninterrupted reference: 100 iters on the big mesh
xr, xbr = run((4, 1), None, None, 100)
# interrupted: 50 iters, checkpoint, device loss -> plan -> resume on (2,1)
x1, xb1 = run((4, 1), None, None, 50)
ckpt.save('{tmp_path}', 50, {{'x': x1, 'xbar': xb1}})
plan = fault.ElasticPlan.shrink(n_devices_left=2, model=1)
assert (plan.data, plan.model) == (2, 1)
st = ckpt.restore('{tmp_path}', {{'x': x1 * 0, 'xbar': xb1 * 0}})
x2, xb2 = run((plan.data, plan.model), st['x'], st['xbar'], 50)
d = float(np.abs(xb2 - xbr).max())
assert d < 1e-9, d
print('OK', d)
"""
    r = _run(code, {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert "OK" in r.stdout, r.stdout[-1000:] + r.stderr[-2000:]


def test_solve_driver_inline():
    from repro.launch import solve
    assert solve.main(["--problem", "ash608", "--workers", "4",
                       "--iters", "200"]) == 0


def test_consensus_combinator_reproduces_apc():
    """core/consensus.py with the APC local step == core/apc.py."""
    from repro.core import apc, consensus
    from repro.data import linsys
    sys_ = linsys.conditioned_gaussian(n=48, m=4, cond=8.0, seed=2)
    factors = apc.prepare(sys_)
    state = apc.init_state(factors)
    gamma, eta = 1.3, 1.2

    def local_step(ctx, xi, xbar):
        A, L = ctx
        d = xbar - xi
        return xi + gamma * apc.project_nullspace(A, L, d)

    xs = factors.x0
    xbar = jnp.mean(factors.x0, axis=0)
    xs, xbar = consensus.run_consensus(local_step, xs, xbar, eta=eta,
                                       rounds=50,
                                       context=(factors.A, factors.chol))
    s = state
    for _ in range(50):
        s = apc.apc_step(factors, s, gamma, eta)
    np.testing.assert_allclose(np.asarray(xbar), np.asarray(s.xbar),
                               rtol=1e-10, atol=1e-12)


def test_apc_probe_head_fits_ridge():
    """optim/apc_head: APC solves the normal equations of a ridge probe to
    the same solution as the closed form."""
    from repro.optim import apc_head
    rng = np.random.default_rng(0)
    T, n = 256, 32
    H = jnp.asarray(rng.standard_normal((T, n)))
    w_true = jnp.asarray(rng.standard_normal(n))
    y = H @ w_true + 0.01 * jnp.asarray(rng.standard_normal(T))
    w, res = apc_head.fit_probe(H, y, m=4, lam=1e-2, iters=400)
    A, b = apc_head.normal_system(H, y, 1e-2)
    w_ref = jnp.linalg.solve(A, b)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=1e-6,
                               atol=1e-8)
