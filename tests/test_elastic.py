"""Elastic runtime (repro.solvers.elastic).

Contract under test (ISSUE 10 / ROADMAP "Elastic runtime"):
``ElasticRuntime`` keeps a solve making progress across membership
events from the ``HeartbeatMonitor`` stream —

  * permanent DEATH re-lowers the selection-weight schedule over the
    survivors and continues from the live state, matching the
    uninterrupted oracle run (and bit-matching the fixed-schedule
    redundant path) on the local backend and a forced 2x2 mesh;
  * a JOIN that grows the fleet repartitions the global system, lifts
    the iterate into the new layout, and reuses per-block factors
    through the FactorStore block tier (reuse vs refactorization counts
    are part of the contract); a returnee to the current fleet size is
    a pure reassignment — state and compiled scan untouched;
  * TASKMASTER LOSS recovers from the store's disk tier plus the
    checkpointed iterate, counting the factor rebuild as block reuse;
  * an uncoverable survivor set fails LOUDLY with a RuntimeError;
  * membership changes never cost a steady-state retrace: one engine
    per fleet size, cache sizes flat across segments.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import solvers
from repro.checkpoint import ckpt
from repro.data import linsys
from repro.runtime.fault import HeartbeatMonitor
from repro.solvers.capability import CapabilityError, ExecutionPlan
from repro.solvers.store import FactorStore

PROJ = ["apc", "consensus", "cimmino"]
ITERS = 150

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)


def _runtime(solver, sys_, *, redundancy=2, segment=25, monitor=None,
             plan=None, **kw):
    monitor = HeartbeatMonitor(n_workers=sys_.m) if monitor is None \
        else monitor
    plan = ExecutionPlan(redundancy=redundancy) if plan is None else plan
    prm = solver.resolve_params(sys_)
    return solvers.ElasticRuntime(solver, sys_, plan=plan, monitor=monitor,
                                  segment=segment, **prm, **kw), monitor


# ----------------------------------------------------------------- death
@pytest.mark.parametrize("name", PROJ)
def test_death_relower_continues_exactly(sys_, name):
    """Death mid-run: the schedule re-lowers over the survivors and the
    residual history equals the uninterrupted oracle's."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=ITERS, plan=ExecutionPlan(), **prm)
    rt, mon = _runtime(s, sys_)
    rep1 = rt.run(iters=50)
    assert rep1.relowerings == 0 and rep1.segments == 2
    mon.mark_dead(2)
    rep2 = rt.run(iters=ITERS - 50)
    assert rep2.relowerings == 1
    assert rep2.iters == ITERS
    assert [e.kind for e in rep2.events] == ["died"]
    res = np.concatenate([np.asarray(rep1.residuals),
                          np.asarray(rep2.residuals)])
    np.testing.assert_allclose(res, np.asarray(oracle.residuals),
                               rtol=1e-6, atol=1e-12)
    np.testing.assert_allclose(np.asarray(rep2.x), np.asarray(oracle.x),
                               rtol=1e-8, atol=1e-10)


def test_death_bit_matches_fixed_schedule_path(sys_):
    """The elastic death path and the one-shot solve(redundancy=2,
    alive_schedule=...) lower IDENTICAL weight schedules — bit-equal x."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    mask = np.array([True, True, False, True])
    sched = np.stack([np.ones(4, bool)] * 50 + [mask] * 100)
    ref = s.solve(sys_, iters=ITERS,
                  plan=ExecutionPlan(redundancy=2, alive_schedule=sched),
                  **prm)
    rt, mon = _runtime(s, sys_)
    rt.run(iters=50)
    mon.mark_dead(2)
    rep = rt.run(iters=100)
    assert np.array_equal(np.asarray(rep.x), np.asarray(ref.x))


def test_rejoin_same_size_is_pure_reassignment(sys_):
    """A returnee to the current fleet size changes holders only: no
    repartition, no state perturbation, oracle parity still holds."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=ITERS, plan=ExecutionPlan(), **prm)
    rt, mon = _runtime(s, sys_)
    rt.run(iters=50)
    mon.mark_dead(1)
    rt.run(iters=50)
    mon.rejoin(1, resynced=True)
    rep = rt.run(iters=50)
    assert rep.repartitions == 0 and rep.relowerings == 1
    assert rep.fleet == (0, 1, 2, 3)
    np.testing.assert_allclose(np.asarray(rep.x), np.asarray(oracle.x),
                               rtol=1e-8, atol=1e-10)
    # the same engine served all three runs: exactly one per fleet size
    assert list(rt.engine_cache_sizes()) == [4]


# ------------------------------------------------------------------ join
def test_join_repartitions_lifts_and_counts_factor_work(sys_):
    """Fleet growth repartitions the rows, warm-starts via lift_state,
    and reports factor reuse vs refactorization exactly."""
    s = solvers.get("apc")
    rt, mon = _runtime(s, sys_)
    assert rt.prepared_blocks == sys_.m and rt.reused_blocks == 0
    rt.run(iters=100)
    w = mon.join(resynced=True)
    assert w == sys_.m
    rep = rt.run(iters=200)
    assert rep.repartitions == 1
    assert rep.fleet == (0, 1, 2, 3, 4)
    assert rt.sys.m == 5
    # 4 blocks prepared at construction + 5 for the new layout (padded
    # rows -> new fingerprints, so zero block reuse on a fresh store)
    assert rep.prepared_blocks == 9 and rep.reused_blocks == 0
    x = np.asarray(rep.x)
    xt = np.asarray(sys_.x_true)
    assert np.linalg.norm(x - xt) / np.linalg.norm(xt) <= 1e-6
    # revisiting a fleet size reuses its cached engine: sizes stay flat
    sizes = dict(rt.engine_cache_sizes())
    mon.mark_dead(4)
    rt.run(iters=25)
    mon.rejoin(4, resynced=True)
    rep2 = rt.run(iters=25)
    assert rep2.repartitions == 1          # cumulative: no new repartition
    assert dict(rt.engine_cache_sizes()) == sizes


# ------------------------------------------------- taskmaster loss
def test_taskmaster_recovery_from_disk_tier(sys_, tmp_path):
    """A fresh process rebuilds the runtime from the store's disk tier
    (all blocks come back as reuse) plus the checkpointed iterate."""
    s = solvers.get("apc")
    store_dir, ck_dir = str(tmp_path / "store"), str(tmp_path / "ck")
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=300, plan=ExecutionPlan(), **prm)

    rt, _ = _runtime(s, sys_,
                     plan=ExecutionPlan(redundancy=2,
                                        store=FactorStore(directory=store_dir)),
                     checkpoint_dir=ck_dir)
    rt.run(iters=150)
    del rt                                          # the taskmaster dies

    rt2 = solvers.ElasticRuntime.recover(
        s, sys_, ck_dir,
        plan=ExecutionPlan(redundancy=2,
                           store=FactorStore(directory=store_dir)),
        monitor=HeartbeatMonitor(n_workers=sys_.m), **prm)
    assert rt2.reused_blocks == sys_.m and rt2.prepared_blocks == 0
    rep = rt2.run(iters=150)
    assert rep.iters == 300                         # cumulative across loss
    x = np.asarray(rep.x)
    np.testing.assert_allclose(x, np.asarray(oracle.x),
                               rtol=1e-6, atol=1e-10)
    assert float(rep.residuals[-1]) <= 1e-6


def test_checkpoint_roundtrips_across_membership_change(sys_, tmp_path):
    """checkpoint() after a join still restores onto a FRESH base-size
    fleet: the iterate is global-shaped, so the partition lifts it."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    d = str(tmp_path)
    rt, mon = _runtime(s, sys_, checkpoint_dir=d)
    rt.run(iters=50)
    mon.join(resynced=True)
    rep = rt.run(iters=50)
    assert rep.repartitions == 1 and rt.sys.m == 5
    assert ckpt.latest_step(d) == 100

    rt2 = solvers.ElasticRuntime.recover(
        s, sys_, d, plan=ExecutionPlan(redundancy=2),
        monitor=HeartbeatMonitor(n_workers=sys_.m), **prm)
    assert rt2.sys.m == sys_.m                      # fresh 4-worker fleet
    rep2 = rt2.run(iters=200)
    assert rep2.iters == 300
    x, xt = np.asarray(rep2.x), np.asarray(sys_.x_true)
    assert np.linalg.norm(x - xt) / np.linalg.norm(xt) <= 1e-6


# ------------------------------------------------------- loud failures
def test_uncoverable_survivors_raise(sys_):
    s = solvers.get("apc")
    rt, mon = _runtime(s, sys_)
    rt.run(iters=25)
    mon.mark_dead(0)
    mon.mark_dead(1)                   # r=2: adjacent pair -> block lost
    with pytest.raises(RuntimeError, match="uncoverable"):
        rt.run(iters=25)


def test_validation(sys_):
    s = solvers.get("apc")
    mon = HeartbeatMonitor(n_workers=sys_.m)
    with pytest.raises(TypeError, match="ExecutionPlan"):
        solvers.ElasticRuntime(s, sys_, plan={"redundancy": 2}, monitor=mon)
    with pytest.raises(ValueError, match="alive_schedule"):
        solvers.ElasticRuntime(
            s, sys_, monitor=mon,
            plan=ExecutionPlan(redundancy=2,
                               alive_schedule=np.ones(4, bool)))
    with pytest.raises(CapabilityError, match="kernel"):
        solvers.ElasticRuntime(
            s, sys_, monitor=mon,
            plan=ExecutionPlan(redundancy=2, kernel=True))
    with pytest.raises(ValueError, match="monitor|workers"):
        solvers.ElasticRuntime(
            s, sys_, monitor=HeartbeatMonitor(n_workers=sys_.m + 1),
            plan=ExecutionPlan(redundancy=2))


# ---------------------------------------------------------------- mesh
@pytest.mark.slow
def test_elastic_death_parity_2x2_subprocess():
    """Acceptance: death -> re-lower -> continue on a forced 4-device
    2 x 2 (data x model) mesh matches the uninterrupted local oracle."""
    code = """
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh
from repro.runtime.fault import HeartbeatMonitor

assert len(jax.devices()) == 4
sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ('data', 'model'))
for name in ['apc', 'consensus', 'cimmino']:
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=150, plan=solvers.ExecutionPlan(), **prm)
    mon = HeartbeatMonitor(n_workers=4)
    rt = solvers.ElasticRuntime(
        s, sys_, monitor=mon, segment=25,
        plan=solvers.ExecutionPlan(redundancy=2, backend='mesh', mesh=mesh),
        **prm)
    r1 = rt.run(iters=50)
    mon.mark_dead(2)
    r2 = rt.run(iters=100)
    assert r2.relowerings == 1, name
    res = np.concatenate([np.asarray(r1.residuals), np.asarray(r2.residuals)])
    assert np.allclose(res, np.asarray(oracle.residuals),
                       rtol=1e-6, atol=1e-12), name
    assert np.allclose(np.asarray(r2.x), np.asarray(oracle.x),
                       rtol=1e-8, atol=1e-10), name
print('OK')
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4"),
        capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout[-1000:] + r.stderr[-2000:]
