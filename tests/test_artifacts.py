"""Deliverable artifacts stay coherent: the dry-run JSONs parse, cover the
full (arch × shape × mesh) grid with zero failures, and the roofline table
regenerates from them."""
import json
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARTIFACTS = ["dryrun_baseline.json", "dryrun_optimized.json"]


def _load(name):
    path = os.path.join(REPO, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not generated (run repro.launch.dryrun)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", ARTIFACTS)
def test_dryrun_grid_complete_and_green(name):
    recs = _load(name)
    from repro import configs
    from repro.launch import cells
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    for mesh in ("16x16", "2x16x16"):
        for arch in configs.ARCHS:
            for shape in cells.SHAPES:
                assert (arch, shape, mesh) in seen, (arch, shape, mesh)
    assert not [r for r in recs if r["status"] == "FAILED"]
    # skips are exactly the documented long_500k inapplicabilities
    for r in recs:
        if r["status"] == "skipped":
            assert r["shape"] == "long_500k"
            cfg = configs.get(r["arch"])
            assert not cfg.supports_long_decode


def test_roofline_rows_sane():
    recs = _load("dryrun_optimized.json")
    for r in recs:
        if r["status"] != "ok":
            continue
        f = r["roofline"]
        assert f["t_compute"] > 0 and f["t_memory"] > 0
        assert f["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < f["useful_ratio"] < 1.5, r["arch"]
        assert 0 <= f["roofline_fraction"] <= 1.0


def test_tables_regenerate():
    _load("dryrun_baseline.json")
    from benchmarks import make_experiments_tables as m
    base = m.load("dryrun_baseline.json")
    opt = m.load("dryrun_optimized.json")
    md = m.table(base, opt, "16x16")
    assert md.count("\n") > 30
    assert "train_4k" in md
