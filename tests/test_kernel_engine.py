"""The fused Pallas iteration engine across the whole projection family.

What PR 5 claims, tested:

  * ``use_kernel=True`` on apc / consensus / cimmino matches the unfused
    path to <= 1e-6 relative on BOTH backends (the in-process mesh is
    (1, 1) — the full shard_map + Pallas path executes; the true 2x2
    multi-device parity runs as a slow subprocess test, mirrored by the
    CI kernel smoke).
  * ``solve_many`` routes batches through the true multi-RHS kernels and
    matches the unfused batched path.
  * ``LinsysServer(use_kernel=True)`` serves at zero steady-state
    retraces on both backends.
  * The ``FactorStore`` augments an entry with the pinv factors exactly
    ONCE — including through the mesh-side ``lookup``/``insert`` split
    (the PR-5 bugfix) — with the augmentation visible in ``store.stats``
    as hits, never as extra misses.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers import FactorStore, LinsysServer

PROJ = ["apc", "consensus", "cimmino"]
ITERS = 120

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=96, m=4, cond=10.0, seed=3)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


def _close(a, b, rtol=1e-6, atol=1e-12):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Kernel path == unfused path, local and mesh, single and batched RHS
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", PROJ)
def test_kernel_matches_unfused_local(sys_, name):
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r0 = s.solve(sys_, iters=ITERS, **prm)
    rk = s.solve(sys_, iters=ITERS, use_kernel=True, **prm)
    _close(rk.residuals, r0.residuals)
    _close(rk.x, r0.x, rtol=1e-8, atol=1e-10)
    assert rk.iters_to_tol == r0.iters_to_tol


@pytest.mark.parametrize("name", PROJ)
def test_kernel_matches_unfused_mesh(sys_, mesh, name):
    """use_kernel=True composes with backend='mesh': each worker shard
    runs the kernel on its local block, psum contract unchanged."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r0 = s.solve(sys_, iters=ITERS, **prm)
    rk = s.solve(sys_, iters=ITERS, use_kernel=True, backend="mesh",
                 mesh=mesh, **prm)
    _close(rk.residuals, r0.residuals)
    _close(rk.x, r0.x, rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("name", PROJ)
@pytest.mark.parametrize("backend", ["local", "mesh"])
def test_solve_many_kernel_matches_unfused(sys_, mesh, name, backend):
    """The multi-RHS kernel path (one A/B read serves the whole batch)
    returns the same batched histories as the unfused driver."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    B = np.random.default_rng(4).standard_normal((6, sys_.N))
    kw = dict(backend=backend, mesh=mesh) if backend == "mesh" else {}
    r0 = s.solve_many(sys_, B, iters=ITERS, **prm)
    rk = s.solve_many(sys_, B, iters=ITERS, use_kernel=True, **kw, **prm)
    assert rk.x.shape == (6, sys_.n)
    _close(rk.residuals, r0.residuals)
    np.testing.assert_array_equal(np.asarray(rk.iters_to_tol),
                                  np.asarray(r0.iters_to_tol))


def test_kernel_state_warm_starts_unfused(sys_):
    """Kernel and unfused runs share the state layout: a kernel half-run
    resumes through the unfused driver exactly (and vice versa)."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    full = s.solve(sys_, iters=100, **prm)
    half = s.solve(sys_, iters=50, use_kernel=True, **prm)
    rest = s.solve(sys_, iters=50, warm_state=half.state, **prm)
    _close(rest.x, full.x, rtol=1e-8, atol=1e-10)
    half_u = s.solve(sys_, iters=50, **prm)
    rest_k = s.solve(sys_, iters=50, use_kernel=True,
                     warm_state=half_u.state, **prm)
    _close(rest_k.x, full.x, rtol=1e-8, atol=1e-10)


def test_redundancy_still_rejects_kernel(sys_):
    with pytest.raises(ValueError, match="use_kernel"):
        solvers.get("apc").solve(sys_, iters=5, redundancy=2,
                                 use_kernel=True)


# ---------------------------------------------------------------------------
# FactorStore: augment-once through every acquisition path (PR-5 bugfix)
# ---------------------------------------------------------------------------


def test_store_augments_once_local(sys_):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    store = FactorStore()
    f1 = store.factors(s, sys_, use_kernel=True, **prm)
    assert f1.B is not None
    assert store.stats.misses == 1 and store.stats.hits == 0
    f2 = store.factors(s, sys_, use_kernel=True, **prm)
    # the SAME augmented object comes back — kernel_factors detected the
    # augmentation instead of recomputing the pinv
    assert f2 is f1
    assert store.stats.misses == 1 and store.stats.hits == 1


def test_store_augments_once_mesh_lookup_insert(sys_, mesh):
    """The mesh backend's lookup/insert split must augment-once too: a
    kernel mesh solve that MISSES inserts an already-augmented entry, a
    kernel mesh solve that HITS gets the augmentation written back —
    never extra misses, never a second pinv computation."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)

    # mesh-side miss: on-mesh kernel prepare inserts augmented factors
    store = FactorStore()
    s.solve(sys_, iters=10, use_kernel=True, backend="mesh", mesh=mesh,
            store=store, **prm)
    assert store.stats.misses == 1 and store.stats.hits == 0, store.stats
    key = store.key(s, sys_, **prm)
    assert store._mem[key].B is not None
    # local kernel hit reuses it unchanged (no extra miss, same object)
    cached = store._mem[key]
    s.solve(sys_, iters=10, use_kernel=True, store=store, **prm)
    assert store.stats.misses == 1 and store.stats.hits == 1, store.stats
    assert store._mem[key] is cached

    # unfused entry first, then a kernel MESH hit: augmented in place
    store2 = FactorStore()
    s.solve(sys_, iters=10, store=store2, **prm)            # plain miss
    assert store2._mem[store2.key(s, sys_, **prm)].B is None
    s.solve(sys_, iters=10, use_kernel=True, backend="mesh", mesh=mesh,
            store=store2, **prm)                            # kernel hit
    assert store2.stats.misses == 1 and store2.stats.hits == 1, store2.stats
    aug = store2._mem[store2.key(s, sys_, **prm)]
    assert aug.B is not None
    # and a second kernel mesh solve reuses the augmented entry as-is
    s.solve(sys_, iters=10, use_kernel=True, backend="mesh", mesh=mesh,
            store=store2, **prm)
    assert store2.stats.misses == 1 and store2.stats.hits == 2, store2.stats
    assert store2._mem[store2.key(s, sys_, **prm)] is aug


# ---------------------------------------------------------------------------
# Serving: the batched kernel path at zero steady-state retraces
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["local", "mesh"])
def test_server_kernel_zero_retrace(sys_, mesh, backend):
    kw = {"mesh": mesh} if backend == "mesh" else {}
    store = FactorStore()
    srv = LinsysServer(store, solver="apc", iters=300, batch=3,
                       backend=backend, use_kernel=True, **kw)
    fp = srv.register(sys_)
    rng = np.random.default_rng(0)
    sizes = []
    for _ in range(4):
        for _ in range(3):
            srv.submit(fp, rng.standard_normal(sys_.N))
        out = srv.step()
        assert all(r.residual < 1e-6 for r in out)
        sizes.append(srv.jit_cache_size())
    tail = sizes[1:]
    assert (-1 in tail) or len(set(tail)) == 1, sizes
    assert store.stats.misses == 1 and store.stats.hits >= 3


def test_server_kernel_matches_unfused(sys_):
    rng = np.random.default_rng(1)
    rhs = [rng.standard_normal(sys_.N) for _ in range(4)]
    xs = {}
    for use_kernel in (False, True):
        srv = LinsysServer(FactorStore(), solver="cimmino", iters=400,
                           batch=4, use_kernel=use_kernel)
        fp = srv.register(sys_)
        for r in rhs:
            srv.submit(fp, r)
        xs[use_kernel] = np.stack([r.x for r in srv.drain()])
    _close(xs[True], xs[False], rtol=1e-8, atol=1e-10)


def test_server_rejects_kernel_for_gradient_family():
    with pytest.raises(ValueError, match="use_kernel"):
        LinsysServer(FactorStore(), solver="dgd", use_kernel=True)


# ---------------------------------------------------------------------------
# True multi-device parity (slow subprocess, mirrored by the CI smoke)
# ---------------------------------------------------------------------------


_SUBPROCESS_KERNEL_PARITY = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh

assert len(jax.devices()) == 4, jax.devices()
sys_ = linsys.conditioned_gaussian(n=96, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ("data", "model"))
B = np.random.default_rng(4).standard_normal((5, sys_.N))
for name in ("apc", "consensus", "cimmino"):
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r0 = s.solve(sys_, iters=120, **prm)
    rk = s.solve(sys_, iters=120, use_kernel=True, backend="mesh",
                 mesh=mesh, **prm)
    np.testing.assert_allclose(np.asarray(rk.residuals),
                               np.asarray(r0.residuals),
                               rtol=1e-6, atol=1e-12)
    m0 = s.solve_many(sys_, B, iters=120, **prm)
    mk = s.solve_many(sys_, B, iters=120, use_kernel=True,
                      backend="mesh", mesh=mesh, **prm)
    np.testing.assert_allclose(np.asarray(mk.residuals),
                               np.asarray(m0.residuals),
                               rtol=1e-6, atol=1e-12)
print("OK")
"""


@pytest.mark.slow
def test_kernel_mesh_parity_2x2_subprocess():
    """use_kernel=True on a REAL 2x2 (data x model) mesh: the n axis is
    column-sharded, each shard's kernel sees (p, n/2) blocks, and the
    psum between gather and scatter restores exact parity."""
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_KERNEL_PARITY],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
