"""SSD (Mamba2) numerics: chunked scan vs sequential recurrence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _inputs(B=2, L=64, H=3, P=8, N=16, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, L, H, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, N)) * 0.3, jnp.float32)
    return x, dt, A, Bm, Cm


def _sequential(x, dt, A, Bm, Cm):
    """Ground truth: token-by-token recurrence via ssd_step."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(L):
        state, y = ssm.ssd_step(state, x[:, t], dt[:, t], A, Bm[:, t],
                                Cm[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_equals_sequential(chunk):
    x, dt, A, Bm, Cm = _inputs()
    y_seq, h_seq = _sequential(x, dt, A, Bm, Cm)
    y_chk, h_chk = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               rtol=2e-4, atol=2e-4)


def test_final_state_continues_decode():
    """Prefill state hand-off: running chunked on the prefix then stepping
    matches the full sequential run."""
    x, dt, A, Bm, Cm = _inputs(L=32)
    y_all, _ = _sequential(x, dt, A, Bm, Cm)
    _, h16 = ssm.ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16],
                             Cm[:, :16], chunk=8)
    state = h16
    for t in range(16, 32):
        state, y = ssm.ssd_step(state.astype(jnp.float32), x[:, t], dt[:, t],
                                A, Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_all[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_causal_conv_step_matches_train():
    rng = np.random.default_rng(1)
    Cch, dw, L = 6, 4, 12
    w = jnp.asarray(rng.standard_normal((dw, Cch)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((Cch,)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((2, L, Cch)), jnp.float32)
    full = ssm._causal_conv_train(w, b, u)
    cache = jnp.zeros((2, dw - 1, Cch), jnp.float32)
    for t in range(L):
        out, cache = ssm._causal_conv_step(w, b, cache, u[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, t]), rtol=1e-5,
                                   atol=1e-5)
