"""Tile-padding exactness and mixed-precision corner cases for PR 9.

The kernel engine pads every operand to (8, 128) tile multiples and, for
sparse systems, compresses the column axis to the per-worker support
width ``w``.  These tests pin the contract at the awkward shapes where
padding bugs hide: odd ``w``, one-row workers (``p=1``), and ``n`` that
is not a multiple of the 128 lane width — through solve, solve_many,
and the mesh backend.  The mixed-precision tests pin the bf16 tile
stream's tolerance envelope, the store-fingerprint split, and the
``_check_precision`` rejection surface.
"""
import warnings

import numpy as np
import pytest

from repro import solvers
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers.store import FactorStore

# f32 relative-residual histories sit at the ~1e-7 floor late in a run,
# so history parity is an absolute comparison (see test_modes.py).
HIST_TOL = dict(rtol=1e-4, atol=2e-6)
X_TOL = dict(rtol=1e-5, atol=1e-6)
# bf16 has ~3 decimal digits: the mixed tile stream floors histories
# near 1e-2 on well-conditioned systems.
MIXED_TOL = dict(rtol=0.5, atol=5e-2)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


# odd support width AND n not a multiple of 128 (p=65, w=65+2*6=77);
# p=1 workers (n=24, m=24); plain even case as control.
CORNER_SYSTEMS = [
    pytest.param(dict(n=130, m=2, bandwidth=6), id="odd-w-n130"),
    pytest.param(dict(n=24, m=24, bandwidth=2), id="p1"),
    pytest.param(dict(n=192, m=4, bandwidth=6), id="even"),
]


def _sys(spec):
    return linsys.banded_system(seed=0, **spec)


@pytest.mark.parametrize("spec", CORNER_SYSTEMS)
@pytest.mark.parametrize("name", ["apc", "cimmino"])
def test_sparse_kernel_exact_at_corner_shapes(spec, name):
    sys_ = _sys(spec)
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        r_k = s.solve(sys_, iters=80, use_kernel=True, **prm)
    r = s.solve(sys_, iters=80, **prm)
    np.testing.assert_allclose(np.asarray(r_k.x), np.asarray(r.x), **X_TOL)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)


@pytest.mark.parametrize("spec", CORNER_SYSTEMS)
def test_sparse_kernel_solve_many_exact_at_corner_shapes(spec):
    sys_ = _sys(spec)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    rng = np.random.default_rng(7)
    B = rng.standard_normal((3, sys_.N))
    r_k = s.solve_many(sys_, B, iters=60, use_kernel=True, **prm)
    r = s.solve_many(sys_, B, iters=60, **prm)
    np.testing.assert_allclose(np.asarray(r_k.x), np.asarray(r.x), **X_TOL)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)


@pytest.mark.parametrize("spec", CORNER_SYSTEMS)
def test_sparse_kernel_mesh_exact_at_corner_shapes(spec, mesh):
    sys_ = _sys(spec)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r_k = s.solve(sys_, iters=60, use_kernel=True, backend="mesh",
                  mesh=mesh, **prm)
    r = s.solve(sys_, iters=60, **prm)
    np.testing.assert_allclose(np.asarray(r_k.x), np.asarray(r.x), **X_TOL)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)


# ---------------------------------------------------------------------------
# fused residual: kernel solves measure ||Ax-b|| inside the step pass;
# histories must match the separate-pass (unfused) measurement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["apc", "cimmino"])
def test_fused_residual_history_matches_unfused(name):
    sys_ = linsys.banded_system(n=192, m=4, bandwidth=6, seed=1)
    s = solvers.get(name)
    assert s.supports_fused_residual
    prm = s.resolve_params(sys_)
    r_k = s.solve(sys_, iters=80, use_kernel=True, **prm)
    r = s.solve(sys_, iters=80, **prm)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)
    if r.errors is not None:
        np.testing.assert_allclose(np.asarray(r_k.errors),
                                   np.asarray(r.errors), **HIST_TOL)


def test_fused_residual_history_matches_unfused_mesh(mesh):
    sys_ = linsys.banded_system(n=192, m=4, bandwidth=6, seed=1)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r_k = s.solve(sys_, iters=80, use_kernel=True, backend="mesh",
                  mesh=mesh, **prm)
    r = s.solve(sys_, iters=80, **prm)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)


def test_fused_residual_history_matches_unfused_many():
    sys_ = linsys.banded_system(n=192, m=4, bandwidth=6, seed=1)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    B = np.random.default_rng(5).standard_normal((4, sys_.N))
    r_k = s.solve_many(sys_, B, iters=60, use_kernel=True, **prm)
    r = s.solve_many(sys_, B, iters=60, **prm)
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals), **HIST_TOL)


# ---------------------------------------------------------------------------
# mixed precision: bf16 tile streams, f32 accumulate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparse"])
@pytest.mark.parametrize("name", ["apc", "cimmino"])
def test_mixed_precision_tracks_f32_within_bf16_envelope(sparse, name):
    sys_ = (linsys.banded_system(n=192, m=4, bandwidth=6, seed=0) if sparse
            else linsys.conditioned_gaussian(n=192, m=4, cond=10.0, seed=0))
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_m = s.solve(sys_, iters=40, use_kernel=True, precision="mixed", **prm)
    r = s.solve(sys_, iters=40, use_kernel=True, **prm)
    res_m = np.asarray(r_m.residuals)
    assert np.all(np.isfinite(res_m))
    np.testing.assert_allclose(res_m, np.asarray(r.residuals), **MIXED_TOL)


def test_mixed_precision_solve_many_and_mesh(mesh):
    sys_ = linsys.banded_system(n=192, m=4, bandwidth=6, seed=0)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    B = np.random.default_rng(2).standard_normal((3, sys_.N))
    r_many = s.solve_many(sys_, B, iters=30, use_kernel=True,
                          precision="mixed", **prm)
    assert np.all(np.isfinite(np.asarray(r_many.residuals)))
    r_mesh = s.solve(sys_, iters=30, use_kernel=True, precision="mixed",
                     backend="mesh", mesh=mesh, **prm)
    r_loc = s.solve(sys_, iters=30, use_kernel=True, precision="mixed", **prm)
    np.testing.assert_allclose(np.asarray(r_mesh.residuals),
                               np.asarray(r_loc.residuals), **MIXED_TOL)


def test_precision_rejections():
    sys_ = linsys.standard_gaussian(n=96, m=4, seed=0)
    s = solvers.get("apc")
    with pytest.raises(ValueError, match="use_kernel"):
        s.solve(sys_, iters=2, precision="mixed")
    with pytest.raises(ValueError, match="unknown precision"):
        s.solve(sys_, iters=2, use_kernel=True, precision="f8")
    # a solver with no kernel engine cannot honour mixed at all
    with pytest.raises(ValueError):
        solvers.get("dgd").solve(sys_, iters=2, use_kernel=True,
                                 precision="mixed")


def test_precision_splits_store_fingerprint():
    sys_ = linsys.standard_gaussian(n=96, m=4, seed=0)
    s = solvers.get("apc")
    st = FactorStore()
    k_def = st.key(s, sys_)
    # explicit default is byte-stable with the implicit one (old digests
    # stay valid), mixed gets its own entry
    assert st.key(s, sys_, precision="default") == k_def
    assert st.key(s, sys_, precision="mixed") != k_def
    s.solve(sys_, iters=3, use_kernel=True, precision="mixed", store=st)
    s.solve(sys_, iters=3, use_kernel=True, precision="mixed", store=st)
    assert st.stats.hits == 1 and st.stats.misses == 1


# ---------------------------------------------------------------------------
# tile autotune plumbing: env pins for the new bp/bk axes
# ---------------------------------------------------------------------------


def test_tile_env_pins(monkeypatch):
    from repro.kernels import ops
    ops.tile_cache_clear()
    monkeypatch.setenv(ops.BN_ENV, "128")
    monkeypatch.setenv(ops.BP_ENV, "8")
    monkeypatch.setenv(ops.BK_ENV, "8")
    bn, bp_, bk = ops.pick_tiles(1024, 32, 16, np.dtype(np.float32),
                                 interpret=True)
    assert (bn, bp_, bk) == (128, 8, 8)
    ops.tile_cache_clear()


def test_tile_env_pin_rejects_nondivisor(monkeypatch):
    from repro.kernels import ops
    ops.tile_cache_clear()
    monkeypatch.setenv(ops.BP_ENV, "24")
    with pytest.raises(ValueError):
        ops.pick_tiles(1024, 32, 16, np.dtype(np.float32), interpret=True)
    ops.tile_cache_clear()


def test_use_fused_sparse_family_requires_w():
    from repro.kernels import ops
    with pytest.raises(ValueError, match="support width w"):
        ops.use_fused("apc_sparse", 8, 256, 16, np.dtype(np.float32))
