"""Optimizer substrate: AdamW vs reference, schedules, gradient
compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, compress, schedule


def _quad_problem(n=16, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((n, n)) / np.sqrt(n))
    x0 = {"w": jnp.asarray(rng.standard_normal(n)),
          "b": {"v": jnp.asarray(rng.standard_normal(n))}}
    target = jnp.asarray(rng.standard_normal(n))

    def loss(p):
        y = A @ p["w"] + p["b"]["v"]
        return jnp.sum((y - target) ** 2)

    return loss, x0


def test_adamw_matches_manual_reference():
    """One AdamW step against a hand-written numpy implementation."""
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.1, clip_norm=None)
    loss, p = _quad_problem()
    g = jax.grad(loss)(p)
    st = adamw.init(p)
    p2, st2 = adamw.update(cfg, g, st, p)

    for key_path in (("w",), ("b", "v")):
        pv = np.asarray(p[key_path[0]] if len(key_path) == 1
                        else p["b"]["v"], np.float64)
        gv = np.asarray(g[key_path[0]] if len(key_path) == 1
                        else g["b"]["v"], np.float64)
        m = 0.1 * gv
        v = 0.01 * gv * gv
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.99)
        ref = pv - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.1 * pv)
        got = np.asarray(p2[key_path[0]] if len(key_path) == 1
                         else p2["b"]["v"])
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert int(st2.step) == 1


def test_adamw_descends():
    cfg = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0)
    loss, p = _quad_problem()
    st = adamw.init(p)
    l0 = float(loss(p))
    for _ in range(60):
        g = jax.grad(loss)(p)
        p, st = adamw.update(cfg, g, st, p)
    assert float(loss(p)) < 0.2 * l0


def test_clip_norm_equals_manual_scaling():
    """update(clip=c) == update(clip=None) on grads pre-scaled to norm c.
    (Adam itself is scale-invariant, so compare against explicit scaling.)"""
    loss, p = _quad_problem()
    g = jax.grad(loss)(p)
    gn = float(adamw.global_norm(g))
    c = gn / 7.0
    cfg_c = adamw.AdamWConfig(lr=1e-2, clip_norm=c, weight_decay=0.0)
    p2, _ = adamw.update(cfg_c, g, adamw.init(p), p)
    g_scaled = jax.tree.map(lambda x: x * (c / gn), g)
    cfg_n = adamw.AdamWConfig(lr=1e-2, clip_norm=None, weight_decay=0.0)
    p3, _ = adamw.update(cfg_n, g_scaled, adamw.init(p), p)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(p3["w"]),
                               rtol=1e-6, atol=1e-7)


def test_schedule_shapes():
    s0 = float(schedule.linear_warmup_cosine(jnp.asarray(0.0), warmup=10,
                                             total=100))
    s10 = float(schedule.linear_warmup_cosine(jnp.asarray(10.0), warmup=10,
                                              total=100))
    s100 = float(schedule.linear_warmup_cosine(jnp.asarray(100.0), warmup=10,
                                               total=100))
    assert s0 == 0.0 and s10 == pytest.approx(1.0) and \
        s100 == pytest.approx(0.1, abs=1e-6)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000) * 3.0, jnp.float32)
    qg = compress.quantize(g)
    back = compress.dequantize(qg, g.shape, jnp.float32)
    err = np.abs(np.asarray(back - g))
    # per-block scale bounds error by scale/2 = max|block|/254
    assert err.max() <= float(jnp.abs(g).max()) / 254 + 1e-6
    assert qg.q.dtype == jnp.int8


def test_error_feedback_unbiased_sum():
    """Over many steps, sum of compressed grads tracks the true sum —
    the error-feedback guarantee."""
    rng = np.random.default_rng(1)
    p = {"w": jnp.zeros(512)}
    err = compress.init_error(p)
    total_true = np.zeros(512)
    total_comp = np.zeros(512)
    for t in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(512), jnp.float32)}
        deq, err = compress.compress_decompress(g, err)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(deq["w"])
    resid = np.abs(total_true - total_comp).max()
    # residual is bounded by ONE step's quantization error, not 50 steps'
    assert resid < 0.05


def test_wire_bytes_accounting():
    p = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    raw, comp = compress.wire_bytes(p)
    assert raw == 4 * 1024
    assert comp < raw / 3.5
