"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward + one train step on CPU, shape and finiteness assertions; decode
path consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model, sharding
from repro.optim import adamw

RULES = sharding.Rules(batch=("data",), fsdp=None, tensor=None, seq_sp=None,
                       kv_seq=None)


def _batch_for(cfg, B, S, seed=1):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patches"] = 0.02 * jax.random.normal(
            k, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            k, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


def _params(cfg, seed=0):
    return sharding.init_tree(model.model_abstract(cfg),
                              jax.random.PRNGKey(seed), jnp.float32)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = _params(cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    logits = model.forward(cfg, params, batch, rules=RULES)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    opt = adamw.init(params)
    acfg = adamw.AdamWConfig(lr=1e-3)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(
            lambda pp: model.loss_fn(cfg, pp, b, rules=RULES))(p)
        p2, o2 = adamw.update(acfg, g, o, p)
        return p2, o2, loss

    p2, o2, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), jax.tree.map(
            lambda a, b: a - b, params, p2), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_consistency(arch):
    """The full-size config is structurally sound (counted, not allocated)."""
    cfg = configs.get(arch)
    n = model.count_params(cfg)
    assert n > 0
    if cfg.moe is not None:
        assert model.count_params(cfg, active_only=True) < n
    # cache tree builds for every decodable arch
    ab = model.cache_abstract(cfg, 2, 64)
    assert jax.tree.leaves(
        ab, is_leaf=lambda x: isinstance(x, sharding.ParamSpec))


PARAM_COUNT_EXPECT = {
    # published totals (approximate, padded-vocab tolerance)
    "tinyllama-1.1b": (1.0e9, 1.2e9),
    "deepseek-7b": (6.5e9, 7.5e9),
    "deepseek-coder-33b": (32e9, 35e9),
    "qwen3-4b": (3.5e9, 4.5e9),
    "deepseek-v2-236b": (220e9, 250e9),
    "qwen3-moe-30b-a3b": (28e9, 32e9),
    "jamba-v0.1-52b": (49e9, 55e9),
    "pixtral-12b": (11.5e9, 13.5e9),   # decoder-only (ViT is stubbed)
    "mamba2-130m": (0.11e9, 0.15e9),
    "whisper-tiny": (0.028e9, 0.060e9),
}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_counts_match_published(arch):
    lo, hi = PARAM_COUNT_EXPECT[arch]
    n = model.count_params(configs.get(arch))
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


DECODE_ARCHS = ["tinyllama-1.1b", "qwen3-4b", "deepseek-v2-236b",
                "jamba-v0.1-52b", "mamba2-130m", "whisper-tiny"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:   # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
    params = _params(cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    full = model.forward(cfg, params, batch, rules=RULES)

    cache = model.init_cache(cfg, B, 32, jnp.float32)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :S - 2]
    ll, cache = model.prefill(cfg, params, pb, cache, rules=RULES)
    np.testing.assert_allclose(np.asarray(ll[:, 0]), np.asarray(full[:, S - 3]),
                               rtol=1e-4, atol=1e-4)
    pos = S - 2
    for t in range(2):
        dl, cache = model.decode_step(
            cfg, params, batch["tokens"][:, pos:pos + 1], cache,
            jnp.asarray(pos, jnp.int32), rules=RULES)
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(full[:, pos]),
                                   rtol=1e-4, atol=2e-4)
        pos += 1


def test_vocab_padding_masked_in_loss():
    cfg = dataclasses.replace(configs.get_smoke("tinyllama-1.1b"),
                              vocab_size=250)   # pads to 256
    assert cfg.padded_vocab == 256
    params = _params(cfg)
    batch = _batch_for(cfg, 2, 16)
    loss = model.loss_fn(cfg, params, batch, rules=RULES)
    assert bool(jnp.isfinite(loss))
