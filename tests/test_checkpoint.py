"""Checkpoint layer: atomic, versioned, validated restore."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(v=0.0):
    return {"a": jnp.arange(6, dtype=jnp.float32) + v,
            "b": {"c": jnp.ones((2, 3)) * v, "step": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree(1.5)
    ckpt.save(d, 10, t)
    out = ckpt.restore(d, _tree())
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))
    np.testing.assert_allclose(np.asarray(out["b"]["c"]),
                               np.asarray(t["b"]["c"]))


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, _tree(float(s)), keep=3)
    assert ckpt.latest_step(d) == 5
    assert ckpt.all_steps(d) == [3, 4, 5]
    out = ckpt.restore(d, _tree(), step=4)
    assert float(out["b"]["c"][0, 0]) == 4.0


def test_uncommitted_ignored(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1.0))
    # simulate a crash mid-save: a step dir without the COMMIT marker
    os.makedirs(os.path.join(d, "step_0000000002"))
    assert ckpt.latest_step(d) == 1


def test_structure_validation(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    with pytest.raises(ValueError):
        ckpt.restore(d, {"only": jnp.zeros(3)})
    bad = _tree()
    bad["a"] = jnp.zeros((7,))
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)


def test_resume_missing_dir():
    with pytest.raises(FileNotFoundError):
        ckpt.restore("/tmp/definitely_missing_ckpt_dir_xyz", _tree())


def test_restore_dtype_drift_raises(tmp_path):
    """A checkpoint written under x64 restored into an f32 program (or any
    other dtype drift) must fail loudly, not silently cast."""
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.arange(4, dtype=jnp.float64)})
    like = {"w": jnp.zeros(4, jnp.float32)}
    with pytest.raises(ValueError, match="dtype drift"):
        ckpt.restore(d, like)
    # the explicit escape hatch casts to the running program's dtype
    out = ckpt.restore(d, like, allow_cast=True)
    assert out["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(4.0))


def test_restore_same_dtype_unaffected(tmp_path):
    d = str(tmp_path)
    t = _tree(2.0)
    ckpt.save(d, 1, t)
    out = ckpt.restore(d, _tree())             # same dtypes: no error
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))
