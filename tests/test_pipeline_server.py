"""AsyncLinsysServer: pipelined serving must preserve every contract of
the sync server — grouping, results, warm gating, zero-retrace — while
adding backpressure (explicit Shed), per-request futures, and the SLO
latency report."""
import numpy as np
import pytest

from repro.analysis import tracecheck
from repro.data import linsys
from repro.solvers.pipeline import AsyncLinsysServer, Shed
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore

PRM = {"gamma": 1.0, "eta": 1.0}     # shared explicit params: one
                                     # executor across same-shape systems


@pytest.fixture(scope="module")
def sys_a():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=0)


@pytest.fixture(scope="module")
def sys_b():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=1)


def _drive(srv, fps, order, rhs):
    """Submit everything, then drain: with the full backlog queued before
    the pipeline starts, the assembly thread's grouping is deterministic
    and identical to the sync step() loop."""
    tickets = [srv.submit(fps[i], b) for i, b in zip(order, rhs)]
    out = srv.drain()
    srv.close()
    return tickets, out


# ---------------------------------------------------------------------------
# parity with the sync server
# ---------------------------------------------------------------------------


def test_async_matches_sync_bit_equal(sys_a, sys_b):
    rng = np.random.default_rng(0)
    order = [0, 0, 1, 0, 1, 1, 0, 1]
    rhs = [rng.standard_normal(48) for _ in order]

    sync = LinsysServer(FactorStore(), solver="apc", iters=40, batch=2,
                        **PRM)
    fps = [sync.register(sys_a), sync.register(sys_b)]
    for i, b in zip(order, rhs):
        sync.submit(fps[i], b)
    ref = {r.rid: r for r in sync.drain()}

    asrv = AsyncLinsysServer(FactorStore(), solver="apc", iters=40,
                             batch=2, pipeline_depth=2, **PRM)
    afps = [asrv.register(sys_a), asrv.register(sys_b)]
    _, out = _drive(asrv, afps, order, rhs)

    assert [r.rid for r in out] == list(range(len(order)))
    for r in out:
        assert np.array_equal(r.x, ref[r.rid].x)
        assert r.residual == ref[r.rid].residual
        assert r.fp == ref[r.rid].fp
    assert asrv.stats.served == len(order)
    assert asrv.stats.shed == 0


def test_ticket_futures_stream_results(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=20, batch=2,
                            **PRM)
    fp = srv.register(sys_a)
    rng = np.random.default_rng(1)
    with srv:
        tickets = [srv.submit(fp, rng.standard_normal(48))
                   for _ in range(4)]
        results = [t.result(timeout=60) for t in tickets]
    for t, r in zip(tickets, results):
        assert r.rid == t.rid and r.fp == fp
        assert np.isfinite(r.residual)
    rep = srv.latency_report()
    assert rep["count"] == 4
    assert rep["p99_ms"] >= rep["p50_ms"] > 0


# ---------------------------------------------------------------------------
# admission control / backpressure
# ---------------------------------------------------------------------------


def test_backpressure_sheds_exactly_beyond_capacity(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=10, batch=2,
                            admit_capacity=4, **PRM)
    fp = srv.register(sys_a)
    rng = np.random.default_rng(2)
    # 10 submits against capacity 4 BEFORE the pipeline starts: exactly
    # the first 4 admit, the other 6 shed with already-resolved futures
    tickets = [srv.submit(fp, rng.standard_normal(48)) for _ in range(10)]
    for t in tickets[4:]:
        assert t.future.done()
        assert isinstance(t.result(), Shed)
    assert srv.stats.admitted == 4 and srv.stats.shed == 6

    out = srv.drain()
    srv.close()
    assert [r.rid for r in out] == list(range(10))      # rid order kept
    assert all(not isinstance(r, Shed) for r in out[:4])
    assert all(isinstance(r, Shed) for r in out[4:])
    assert srv.stats.served == 4
    # latency is recorded for ADMITTED requests only
    assert srv.latency_report()["count"] == 4


def test_capacity_frees_as_requests_complete(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=10, batch=2,
                            admit_capacity=2, **PRM)
    fp = srv.register(sys_a)
    rng = np.random.default_rng(3)
    with srv:
        first = [srv.submit(fp, rng.standard_normal(48)) for _ in range(2)]
        for t in first:
            assert not isinstance(t.result(timeout=60), Shed)
        # the pipeline drained: capacity is available again
        again = srv.submit(fp, rng.standard_normal(48))
        assert not isinstance(again.result(timeout=60), Shed)
    assert srv.stats.shed == 0 and srv.stats.served == 3


def test_async_validation_shares_sync_guards(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=5, batch=2,
                            **PRM)
    fp = srv.register(sys_a)
    with pytest.raises(KeyError, match="deadbeef"):
        srv.submit("deadbeef", np.zeros(48))
    with pytest.raises(ValueError, match="shape"):
        srv.submit(fp, np.zeros(7))
    with pytest.raises(ValueError, match="pipeline_depth"):
        AsyncLinsysServer(FactorStore(), pipeline_depth=0)
    with pytest.raises(ValueError, match="admit_capacity"):
        AsyncLinsysServer(FactorStore(), admit_capacity=0)


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------


def test_empty_drain_and_close_are_noops():
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=5, **PRM)
    assert srv.drain() == []
    srv.close()                                   # never started: no-op
    assert srv._assembler is None                 # no threads were spun up
    assert srv.stats.executor_builds == 0


def test_step_is_not_part_of_the_async_surface(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=5, **PRM)
    with pytest.raises(RuntimeError, match="submit"):
        srv.step()


def test_context_manager_drains_on_exit(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=10, batch=2,
                            **PRM)
    fp = srv.register(sys_a)
    rng = np.random.default_rng(4)
    with srv:
        tickets = [srv.submit(fp, rng.standard_normal(48))
                   for _ in range(3)]
    # __exit__ drained the pipeline: every future resolved
    assert all(t.future.done() for t in tickets)
    assert srv.stats.served == 3


# ---------------------------------------------------------------------------
# zero steady-state retraces
# ---------------------------------------------------------------------------


def test_async_zero_retrace_steady_state(sys_a, sys_b):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=10, batch=2,
                            pipeline_depth=2, **PRM)
    fps = [srv.register(sys_a), srv.register(sys_b)]
    rng = np.random.default_rng(5)
    with srv:
        # warmup: one group per system compiles the shared executor
        for fp in fps:
            ts = [srv.submit(fp, rng.standard_normal(48)) for _ in range(2)]
            for t in ts:
                t.result(timeout=60)
        # steady state: a retrace ANYWHERE in the pipeline (assembly
        # thread or device pool) fails with its attributed call site
        with tracecheck(steady_state=True):
            for i in range(5):
                ts = [srv.submit(fps[i % 2], rng.standard_normal(48))
                      for _ in range(2)]
                for t in ts:
                    t.result(timeout=60)
    assert srv.stats.executor_builds == 1


# ---------------------------------------------------------------------------
# warm starts through the pipeline
# ---------------------------------------------------------------------------


def test_async_warm_chaining_repeated_rhs(sys_a):
    srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=30, batch=1,
                            warm_start=True, **PRM)
    fp = srv.register(sys_a)
    b = np.random.default_rng(6).standard_normal(48)
    with srv:
        first = srv.submit(fp, b).result(timeout=60)
        second = srv.submit(fp, b).result(timeout=60)
    # warm chaining serialized the same-system batches: the repeat resumed
    assert not first.warm and second.warm
    assert second.residual < first.residual
    assert srv.stats.warm_batches == 1


def test_async_warm_mixed_traffic_matches_sync(sys_a):
    """Interleaved repeated/perturbed RHS through BOTH servers: identical
    warm/cold gating and bit-equal solutions step by step."""
    rng = np.random.default_rng(7)
    b0 = rng.standard_normal(48)
    b1 = b0 + 1e-3 * rng.standard_normal(48)
    seq = [b0, b0, b1, b1, b0]            # repeat, perturb, repeat, back

    sync = LinsysServer(FactorStore(), solver="apc", iters=30, batch=1,
                        warm_start=True, **PRM)
    fs = sync.register(sys_a)
    ref = []
    for b in seq:
        sync.submit(fs, b)
        ref.append(sync.drain()[0])

    asrv = AsyncLinsysServer(FactorStore(), solver="apc", iters=30,
                             batch=1, warm_start=True, **PRM)
    fa = asrv.register(sys_a)
    with asrv:
        out = [asrv.submit(fa, b).result(timeout=60) for b in seq]

    # APC gates perturbed RHS cold; repeats chain warm — same pattern,
    # bit-equal states either way
    assert [r.warm for r in out] == [r.warm for r in ref] == \
        [False, True, False, True, False]
    for r, e in zip(out, ref):
        assert np.array_equal(r.x, e.x)
        assert r.residual == e.residual


# ---------------------------------------------------------------------------
# backend / kernel composition
# ---------------------------------------------------------------------------


def test_async_mesh_matches_local(sys_a):
    rng = np.random.default_rng(8)
    rhs = [rng.standard_normal(48) for _ in range(4)]
    out = {}
    for backend in ("local", "mesh"):
        srv = AsyncLinsysServer(FactorStore(), solver="apc", iters=60,
                                batch=2, backend=backend, **PRM)
        fp = srv.register(sys_a)
        _, out[backend] = _drive(srv, [fp] * 4, [0] * 4, rhs)
    for rl, rm in zip(out["local"], out["mesh"]):
        assert np.allclose(rl.x, rm.x, rtol=1e-8, atol=1e-10)
        assert rm.residual == pytest.approx(rl.residual, rel=1e-6)


def test_async_use_kernel_matches_sync(sys_a):
    rng = np.random.default_rng(9)
    rhs = [rng.standard_normal(48) for _ in range(4)]

    sync = LinsysServer(FactorStore(), solver="apc", iters=40, batch=2,
                        use_kernel=True, **PRM)
    fp = sync.register(sys_a)
    for b in rhs:
        sync.submit(fp, b)
    ref = sync.drain()

    asrv = AsyncLinsysServer(FactorStore(), solver="apc", iters=40,
                             batch=2, use_kernel=True, **PRM)
    afp = asrv.register(sys_a)
    _, out = _drive(asrv, [afp] * 4, [0] * 4, rhs)
    for r, e in zip(out, ref):
        assert np.array_equal(r.x, e.x)
        assert r.residual == e.residual
