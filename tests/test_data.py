"""Data pipeline: determinism, host sharding, label shift; linsys spectra."""
import numpy as np
import pytest

from repro.data import linsys, synthetic


def test_batches_deterministic():
    cfg = synthetic.DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    b1 = synthetic.make_batch(cfg, step=7)
    b2 = synthetic.make_batch(cfg, step=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic.make_batch(cfg, step=8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_host_sharding_partitions_global_batch():
    cfg = synthetic.DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    full = synthetic.make_batch(cfg, 3)
    shards = [synthetic.make_batch(cfg, 3, host_id=h, num_hosts=4)
              for h in range(4)]
    got = np.concatenate([np.asarray(s["tokens"]) for s in shards])
    np.testing.assert_array_equal(got, np.asarray(full["tokens"]))


def test_labels_are_next_token():
    cfg = synthetic.DataConfig(vocab_size=100, seq_len=12, global_batch=2)
    b = synthetic.make_batch(cfg, 0)
    assert b["tokens"].shape == (2, 12)
    # labels[t] is the token that followed tokens[t] in the raw stream:
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokens_in_vocab():
    cfg = synthetic.DataConfig(vocab_size=50, seq_len=64, global_batch=4)
    b = synthetic.make_batch(cfg, 2)
    assert int(b["tokens"].max()) < 50
    assert int(b["tokens"].min()) >= 0


@pytest.mark.parametrize("key", sorted(linsys.MM_PROXIES))
def test_matrix_market_proxy_shapes_and_cond(key):
    spec = linsys.MM_PROXIES[key]
    sys_ = linsys.matrix_market_proxy(key)
    assert sys_.n == spec.n
    assert sys_.N >= spec.N
    A, _ = sys_.dense()
    s = np.linalg.svd(np.asarray(A), compute_uv=False)
    # padding duplicates rows, which can only mildly change the spectrum
    assert s[0] / s[-1] == pytest.approx(spec.cond, rel=0.5)


def test_conditioned_gaussian_exact_cond():
    sys_ = linsys.conditioned_gaussian(n=40, m=4, cond=123.0, seed=0)
    A, _ = sys_.dense()
    s = np.linalg.svd(np.asarray(A), compute_uv=False)
    assert s[0] / s[-1] == pytest.approx(123.0, rel=1e-6)


def test_consistent_rhs():
    """b = A x_true exactly (solvable system, paper's setting)."""
    sys_ = linsys.standard_gaussian(n=50, m=2, seed=1)
    A, b = sys_.dense()
    r = np.asarray(A) @ np.asarray(sys_.x_true) - np.asarray(b)
    assert float(np.abs(r).max()) < 1e-10


def test_banded_system_support_and_exact_compression():
    sys_ = linsys.banded_system(n=128, m=4, bandwidth=8, seed=0)
    assert sys_.is_sparse and sys_.mode == "square"
    assert sys_.sparsity > 0.7                    # genuinely sparse blocks
    A = np.asarray(sys_.A_blocks)
    for i in range(sys_.m):                       # support = declared cols
        nz = np.flatnonzero((A[i] != 0).any(axis=0))
        assert set(nz) <= set(np.asarray(sys_.cols[i]).tolist())
    # the compressed operand scatters back to exactly the dense stack
    from repro.core import blockops
    np.testing.assert_array_equal(np.asarray(blockops.densify(sys_.A_op)), A)


def test_block_sparse_system_covers_every_column():
    sys_ = linsys.block_sparse_system(n=96, m=4, density=0.2, seed=0)
    assert sys_.is_sparse
    A = np.asarray(sys_.A_blocks)
    covered = (A != 0).any(axis=(0, 1))
    assert covered.all()                          # structurally square
    b = np.asarray(sys_.b_blocks).reshape(-1)
    x = np.asarray(sys_.x_true)
    np.testing.assert_allclose(A.reshape(sys_.N, sys_.n) @ x, b, atol=1e-9)


@pytest.mark.parametrize("key", sorted(linsys.MM_PROXIES))
def test_sparse_matrix_market_proxy_keeps_cond(key):
    spec = linsys.MM_PROXIES[key]
    sys_ = linsys.sparse_matrix_market_proxy(key)
    assert sys_.is_sparse
    A, _ = sys_.dense()
    s = np.linalg.svd(np.asarray(A), compute_uv=False)
    assert s[0] / s[-1] == pytest.approx(spec.cond, rel=0.5)
