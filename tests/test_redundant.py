"""Redundant straggler-tolerant execution (repro.solvers.redundant).

Contract under test (ISSUE 3 / ROADMAP "Redundant execution"):
``solve(sys, redundancy=r, alive_schedule=...)`` matches the no-failure
run to <= 1e-6 relative for every projection-family solver on BOTH
backends, states stay global-shaped so warm starts and checkpoints
round-trip across redundancy settings and backends, and uncoverable
alive-masks fail loudly.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import solvers
from repro.checkpoint import ckpt
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.runtime import fault
from repro.solvers import redundant

PROJ = ["apc", "consensus", "cimmino"]
ITERS = 150

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


def rotating_straggler(m):
    """Covering schedule: worker t mod m stalls at iteration t."""
    return lambda t: np.array([i != (t % m) for i in range(m)])


def _assert_match(r_red, r_ref):
    np.testing.assert_allclose(np.asarray(r_red.x), np.asarray(r_ref.x),
                               rtol=1e-8, atol=1e-10)
    # rtol 1e-6 is the contract; atol covers the converged noise floor.
    np.testing.assert_allclose(np.asarray(r_red.residuals),
                               np.asarray(r_ref.residuals),
                               rtol=1e-6, atol=1e-12)


@pytest.mark.parametrize("name", PROJ)
def test_redundant_local_matches_no_failure(sys_, name):
    """Exactness: a covered straggler every iteration changes nothing."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_ref = s.solve(sys_, iters=ITERS, **prm)
    r_red = s.solve(sys_, iters=ITERS, redundancy=2,
                    alive_schedule=rotating_straggler(sys_.m), **prm)
    assert r_red.name == name
    assert r_red.residuals.shape == (ITERS,)
    assert r_red.errors is not None
    _assert_match(r_red, r_ref)
    np.testing.assert_allclose(np.asarray(r_red.errors),
                               np.asarray(r_ref.errors),
                               rtol=1e-6, atol=1e-12)
    assert r_red.iters_to_tol == r_ref.iters_to_tol


@pytest.mark.parametrize("name", PROJ)
def test_redundant_mesh_matches_no_failure(sys_, mesh, name):
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_ref = s.solve(sys_, iters=ITERS, **prm)
    r_red = s.solve(sys_, iters=ITERS, redundancy=2, backend="mesh",
                    mesh=mesh, alive_schedule=rotating_straggler(sys_.m),
                    **prm)
    _assert_match(r_red, r_ref)
    assert r_red.errors is not None


@pytest.mark.parametrize("name", PROJ)
def test_redundant_state_is_global_shaped(sys_, name):
    """The SolveResult state has the PLAIN structure/shapes — replication
    is internal — so it is interchangeable with non-redundant states."""
    s = solvers.get(name)
    r_plain = s.solve(sys_, iters=10)
    r_red = s.solve(sys_, iters=10, redundancy=3)
    plain_shapes = jax.tree.map(lambda a: np.shape(a), r_plain.state)
    red_shapes = jax.tree.map(lambda a: np.shape(a), r_red.state)
    assert plain_shapes == red_shapes


def test_warm_start_roundtrips_across_redundancy(sys_):
    """plain -> redundant and redundant -> plain resume exactly."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    sched = rotating_straggler(sys_.m)
    full = s.solve(sys_, iters=100, **prm)

    half = s.solve(sys_, iters=50, **prm)
    res = s.solve(sys_, iters=50, redundancy=2, alive_schedule=sched,
                  warm_state=half.state, **prm)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)
    assert int(res.state.t) == 100

    half_r = s.solve(sys_, iters=50, redundancy=2, alive_schedule=sched,
                     **prm)
    res2 = s.solve(sys_, iters=50, warm_state=half_r.state, **prm)
    np.testing.assert_allclose(np.asarray(res2.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)


def test_warm_start_roundtrips_across_backends(sys_, mesh):
    """redundant mesh <-> plain local warm starts agree with the
    uninterrupted plain run."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    sched = rotating_straggler(sys_.m)
    full = s.solve(sys_, iters=100, **prm)

    half_m = s.solve(sys_, iters=50, redundancy=2, alive_schedule=sched,
                     backend="mesh", mesh=mesh, **prm)
    res_l = s.solve(sys_, iters=50, warm_state=jax.device_get(half_m.state),
                    **prm)
    np.testing.assert_allclose(np.asarray(res_l.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)

    half_l = s.solve(sys_, iters=50, **prm)
    res_m = s.solve(sys_, iters=50, redundancy=2, alive_schedule=sched,
                    backend="mesh", mesh=mesh, warm_state=half_l.state,
                    **prm)
    np.testing.assert_allclose(np.asarray(res_m.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)


def test_checkpoint_roundtrips_across_redundancy(sys_, tmp_path):
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r1 = s.solve(sys_, iters=40, redundancy=2,
                 alive_schedule=rotating_straggler(sys_.m), **prm)
    ckpt.save(str(tmp_path), 40, r1.state)
    restored = ckpt.restore(str(tmp_path), r1.state)
    r2 = s.solve(sys_, iters=40, redundancy=3, warm_state=restored, **prm)
    full = s.solve(sys_, iters=80, **prm)
    np.testing.assert_allclose(np.asarray(r2.x), np.asarray(full.x),
                               rtol=1e-8, atol=1e-10)


def test_heartbeat_monitor_drives_alive_mask(sys_):
    """A HeartbeatMonitor passed as alive_schedule: its drop_set() is the
    mask source, and a dead worker still yields the exact solution."""
    import time
    mon = fault.HeartbeatMonitor(n_workers=sys_.m, timeout=60.0)
    now = time.monotonic()
    for w in range(sys_.m):
        mon.beat(w, now=now, duration=1.0)
    mon.mark_dead(2)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r_ref = s.solve(sys_, iters=ITERS, **prm)
    r_mon = s.solve(sys_, iters=ITERS, redundancy=2, alive_schedule=mon,
                    **prm)
    _assert_match(r_mon, r_ref)
    with pytest.raises(ValueError, match="HeartbeatMonitor"):
        wrong = fault.HeartbeatMonitor(n_workers=sys_.m + 1)
        s.solve(sys_, iters=5, redundancy=2, alive_schedule=wrong)


def test_array_schedules(sys_):
    """Static (m,) and per-iteration (T, m) mask arrays are accepted."""
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r_ref = s.solve(sys_, iters=60, **prm)
    static = np.array([True, False, True, True])   # worker 1 always out
    r1 = s.solve(sys_, iters=60, redundancy=2, alive_schedule=static, **prm)
    _assert_match(r1, r_ref)
    per_t = np.stack([np.roll(static, t) for t in range(60)])
    r2 = s.solve(sys_, iters=60, redundancy=2, alive_schedule=per_t, **prm)
    _assert_match(r2, r_ref)
    with pytest.raises(ValueError, match="shape"):
        s.solve(sys_, iters=60, redundancy=2,
                alive_schedule=np.ones((10, sys_.m), bool))


def test_uncoverable_mask_raises(sys_):
    s = solvers.get("apc")
    # r=2, workers 0 and 1 adjacent and both dead -> block 1 has no holder
    dead_pair = np.array([False, False, True, True])
    with pytest.raises(RuntimeError, match="unrecoverable"):
        s.solve(sys_, iters=10, redundancy=2, alive_schedule=dead_pair)
    # r=1 tolerates nothing: any straggler is fatal
    with pytest.raises(RuntimeError, match="unrecoverable"):
        s.solve(sys_, iters=10, redundancy=1,
                alive_schedule=rotating_straggler(sys_.m))
    # on the mesh backend too (lowering happens before placement)
    with pytest.raises(RuntimeError, match="unrecoverable"):
        s.solve(sys_, iters=10, redundancy=2, backend="mesh",
                alive_schedule=dead_pair)


def test_validation_errors(sys_):
    s = solvers.get("apc")
    with pytest.raises(ValueError, match="redundancy"):
        s.solve(sys_, iters=5, redundancy=sys_.m + 1)
    with pytest.raises(ValueError, match="use_kernel"):
        s.solve(sys_, iters=5, redundancy=2, use_kernel=True)
    with pytest.raises(ValueError, match="redundant"):
        solvers.get("dgd").solve(sys_, iters=5, redundancy=2)
    # solve_many must reject rather than silently drop the kwargs into
    # **params and run the batch without straggler tolerance
    B = np.ones((2, sys_.N))
    with pytest.raises(ValueError, match="solve_many"):
        s.solve_many(sys_, B, iters=5, redundancy=2)
    with pytest.raises(ValueError, match="solve_many"):
        s.solve_many(sys_, B, iters=5,
                     alive_schedule=rotating_straggler(sys_.m))


def test_selection_weights_match_legacy_semantics():
    """Vectorized lowering picks the lowest-index alive holder, each block
    exactly once, dead workers contributing nothing (the coding.py rule)."""
    m, r = 6, 3
    holder = redundant.Assignment(m=m, r=r).holder
    for trial in range(20):
        rng = np.random.default_rng(trial)
        alive = rng.random(m) > 0.3
        if not fault.covering_ok(alive, r):
            continue
        W = redundant.selection_weights(alive, m, r)
        per_block = np.zeros(m)
        np.add.at(per_block, holder.ravel(), W.ravel())
        np.testing.assert_allclose(per_block, 1.0)
        assert W[~alive].sum() == 0.0
        # lowest-index preference: the provider of block j is the first
        # alive worker in {j, j-1, ...} scanned by worker index
        for blk in range(m):
            cands = sorted((int((blk - k) % m), k) for k in range(r)
                           if alive[(blk - k) % m])
            i, k = cands[0]
            assert W[i, k] == 1.0


@pytest.mark.slow
def test_redundant_mesh_parity_2x2_subprocess():
    """Acceptance check: projection family, r=2, rotating straggler, on a
    4-device 2 x 2 (data x model) mesh — matches the no-failure local
    run's residual history."""
    code = """
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh

assert len(jax.devices()) == 4
sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ('data', 'model'))
sched = lambda t: np.array([i != (t % 4) for i in range(4)])
for name in ['apc', 'consensus', 'cimmino']:
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    rl = s.solve(sys_, iters=150, **prm)
    rm = s.solve(sys_, iters=150, redundancy=2, alive_schedule=sched,
                 backend='mesh', mesh=mesh, **prm)
    assert np.allclose(np.asarray(rm.residuals), np.asarray(rl.residuals),
                       rtol=1e-6, atol=1e-12), name
    assert np.allclose(np.asarray(rm.x), np.asarray(rl.x),
                       rtol=1e-8, atol=1e-10), name
print('OK')
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4"),
        capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout[-1000:] + r.stderr[-2000:]
