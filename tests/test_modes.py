"""System modes as first-class citizens through every layer.

The refactor's contract under test: dense-square, least-squares, and
block-sparse systems flow through the SAME solve/solve_many/serve entry
points; a solver that cannot handle a mode says so at dispatch
(``CapabilityError``) instead of silently diverging; least-squares
results match the closed-form lstsq reference; the sparse execution path
is numerically a twin of the densified one; and the streaming mode
(``solve_stream``) warm-starts exactly where ``Solver.warm_rhs_ok``
allows.
"""
import warnings

import numpy as np
import pytest

from repro import solvers
from repro.core.partition import partition
from repro.data import linsys
from repro.launch import mesh as mesh_lib
from repro.solvers import CapabilityError, solve_stream
from repro.solvers.pipeline import AsyncLinsysServer
from repro.solvers.serve import LinsysServer
from repro.solvers.store import FactorStore

SPARSE_OK = ["apc", "consensus", "cimmino", "dgd", "dnag", "dhbm", "madmm"]
LS_OK = ["cimmino", "dgd", "dnag", "dhbm"]
SQUARE_ONLY_ON_LS = ["apc", "consensus", "madmm", "pdhbm"]


@pytest.fixture(scope="module")
def sparse_sys():
    return linsys.banded_system(n=192, m=4, bandwidth=6, seed=0)


@pytest.fixture(scope="module")
def ls_sys():
    # inconsistent by construction: noise pushes b out of range(A)
    return linsys.tall_gaussian(N=240, n=120, m=4, seed=0, noise=0.05)


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.solver_mesh(1, 1)


# ---------------------------------------------------------------------------
# capability dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", SQUARE_ONLY_ON_LS)
def test_square_only_solver_rejects_least_squares(ls_sys, name):
    s = solvers.get(name)
    with pytest.raises(CapabilityError, match="least_squares"):
        s.solve(ls_sys, iters=5)


def test_pdhbm_rejects_sparse(sparse_sys):
    # the preconditioned method eigendecomposes the dense normal matrix
    with pytest.raises(CapabilityError, match="sparse"):
        solvers.get("pdhbm").solve(sparse_sys, iters=5)


def test_capability_error_names_solver_and_declared_set(ls_sys):
    with pytest.raises(CapabilityError, match="'apc'") as ei:
        solvers.get("apc").solve(ls_sys, iters=5)
    assert "supports=" in str(ei.value)          # actionable: what it CAN do


def test_server_register_checks_capability(ls_sys):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5,
                       gamma=1.0, eta=1.0)
    with pytest.raises(CapabilityError, match="register"):
        srv.register(ls_sys)


def test_solve_many_checks_capability(ls_sys):
    B = np.zeros((2, ls_sys.N))
    with pytest.raises(CapabilityError, match="least_squares"):
        solvers.get("madmm").solve_many(ls_sys, B, iters=5)


def test_redundant_execution_is_dense_square_only(sparse_sys, ls_sys):
    with pytest.raises(ValueError, match="dense-square only"):
        solvers.get("apc").solve(sparse_sys, iters=5, redundancy=2)
    with pytest.raises(ValueError, match="dense-square only"):
        solvers.get("cimmino").solve(ls_sys, iters=5, redundancy=2)


# ---------------------------------------------------------------------------
# mode resolution on the system itself
# ---------------------------------------------------------------------------


def test_mode_auto_resolution(rng):
    A = rng.standard_normal((48, 48))
    sq = partition(A, A @ rng.standard_normal(48), 4)
    assert sq.mode == "square"
    At = rng.standard_normal((96, 48))
    tall = partition(At, rng.standard_normal(96), 4)
    assert tall.mode == "least_squares"
    # an explicit tag wins over the shape heuristic
    tagged = partition(At, rng.standard_normal(96), 4, mode="square")
    assert tagged.mode == "square"
    with pytest.raises(ValueError, match="mode"):
        partition(A, A[:, 0], 4, mode="banana")


def test_tall_gaussian_default_is_bit_identical_and_consistent():
    old = linsys.tall_gaussian(N=240, n=120, m=4, seed=0)
    new = linsys.tall_gaussian(N=240, n=120, m=4, seed=0, noise=0.0)
    assert np.array_equal(np.asarray(old.A_blocks), np.asarray(new.A_blocks))
    assert np.array_equal(np.asarray(old.b_blocks), np.asarray(new.b_blocks))
    assert old.mode == new.mode == "square"      # consistent: b = A x_true
    A, b = old.dense()
    assert np.allclose(np.asarray(A) @ np.asarray(old.x_true), b)


def test_tall_gaussian_noise_makes_inconsistent_ls(ls_sys):
    assert ls_sys.mode == "least_squares"
    A, b = map(np.asarray, ls_sys.dense())
    x_ls, residual_ss, *_ = np.linalg.lstsq(A, b, rcond=None)
    assert residual_ss > 0                       # b truly out of range(A)
    # x_true is the lstsq solution, not the planted generator vector
    assert np.allclose(np.asarray(ls_sys.x_true), x_ls)


# ---------------------------------------------------------------------------
# least-squares mode: converge to the lstsq reference, local and mesh
# ---------------------------------------------------------------------------


def _rel_err(x, ref):
    return float(np.linalg.norm(np.asarray(x) - np.asarray(ref))
                 / np.linalg.norm(np.asarray(ref)))


@pytest.mark.parametrize("name", LS_OK)
def test_ls_solution_matches_solver_reference(ls_sys, name):
    s = solvers.get(name)
    prm = s.resolve_params(ls_sys)
    r = s.solve(ls_sys, iters=800, **prm)
    ref = s.ls_reference(ls_sys)
    assert _rel_err(r.x, ref) < 1e-6
    assert r.residuals[-1] < 1e-8                # LS optimality moment -> 0
    assert r.errors is not None                  # tracked even w/o planted x


@pytest.mark.parametrize("name", ["dgd", "dnag", "dhbm"])
def test_gradient_family_ls_matches_plain_lstsq(ls_sys, name):
    # the gradient fixed point is the UNWEIGHTED normal equations: the
    # solver must land on numpy's lstsq, not some reweighted variant
    A, b = map(np.asarray, ls_sys.dense())
    x_ls, *_ = np.linalg.lstsq(A, b, rcond=None)
    s = solvers.get(name)
    r = s.solve(ls_sys, iters=800, **s.resolve_params(ls_sys))
    assert _rel_err(r.x, x_ls) < 1e-6


def test_cimmino_ls_reference_is_gram_weighted(ls_sys):
    # Cimmino's fixed point solves the G^{-1}-weighted LS problem; on an
    # INCONSISTENT system that is a different minimizer than plain lstsq
    A, b = map(np.asarray, ls_sys.dense())
    x_plain, *_ = np.linalg.lstsq(A, b, rcond=None)
    ref = np.asarray(solvers.get("cimmino").ls_reference(ls_sys))
    assert _rel_err(ref, x_plain) > 1e-3


def test_consistent_tall_system_reaches_x_true():
    sys_ = linsys.tall_gaussian(N=240, n=120, m=4, seed=1)  # mode="square"
    for name in ("cimmino", "dgd"):
        s = solvers.get(name)
        r = s.solve(sys_, iters=800, **s.resolve_params(sys_))
        assert _rel_err(r.x, sys_.x_true) < 1e-8


@pytest.mark.parametrize("name", ["cimmino", "dgd"])
def test_ls_mesh_matches_local(ls_sys, mesh, name):
    s = solvers.get(name)
    prm = s.resolve_params(ls_sys)
    r_loc = s.solve(ls_sys, iters=300, **prm)
    r_mesh = s.solve(ls_sys, iters=300, backend="mesh", mesh=mesh, **prm)
    np.testing.assert_allclose(np.asarray(r_mesh.x), np.asarray(r_loc.x),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(r_mesh.residuals),
                               np.asarray(r_loc.residuals),
                               rtol=1e-6, atol=1e-12)


def test_ls_solve_many_batches_the_optimality_residual(ls_sys):
    s = solvers.get("dgd")
    prm = s.resolve_params(ls_sys)
    rng = np.random.default_rng(2)
    B = np.stack([rng.standard_normal(ls_sys.N) for _ in range(3)])
    rm = s.solve_many(ls_sys, B, iters=800, **prm)
    A, _ = map(np.asarray, ls_sys.dense())
    for k in range(3):
        x_k, *_ = np.linalg.lstsq(A, B[k], rcond=None)
        assert _rel_err(rm.x[k], x_k) < 1e-6
        assert rm.residuals[k, -1] < 1e-8


# ---------------------------------------------------------------------------
# sparse mode: the compressed path is a numerical twin of the dense one
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", SPARSE_OK)
def test_sparse_matches_densified(sparse_sys, name):
    s = solvers.get(name)
    prm = s.resolve_params(sparse_sys)
    r_sp = s.solve(sparse_sys, iters=150, **prm)
    r_dn = s.solve(sparse_sys.densified(), iters=150, **prm)
    np.testing.assert_allclose(np.asarray(r_sp.x), np.asarray(r_dn.x),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(r_sp.residuals),
                               np.asarray(r_dn.residuals),
                               rtol=1e-6, atol=1e-12)


@pytest.mark.parametrize("name", ["apc", "dgd"])
def test_sparse_mesh_matches_local(sparse_sys, mesh, name):
    s = solvers.get(name)
    prm = s.resolve_params(sparse_sys)
    r_loc = s.solve(sparse_sys, iters=150, **prm)
    r_mesh = s.solve(sparse_sys, iters=150, backend="mesh", mesh=mesh, **prm)
    np.testing.assert_allclose(np.asarray(r_mesh.x), np.asarray(r_loc.x),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(r_mesh.residuals),
                               np.asarray(r_loc.residuals),
                               rtol=1e-6, atol=1e-12)


@pytest.mark.parametrize("name", ["apc", "cimmino"])
def test_sparse_kernel_request_dispatches_silently(sparse_sys, name):
    # Kernel-capable solvers run the compressed-support Pallas pair on
    # sparse systems: no fallback, no RuntimeWarning, same answer.
    s = solvers.get(name)
    prm = s.resolve_params(sparse_sys)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        r_k = s.solve(sparse_sys, iters=100, use_kernel=True, **prm)
    r = s.solve(sparse_sys, iters=100, **prm)
    np.testing.assert_allclose(np.asarray(r_k.x), np.asarray(r.x),
                               rtol=1e-5, atol=1e-6)
    # relative-residual histories live at the f32 floor (~1e-7) late in
    # the run, so compare absolutely, not relatively
    np.testing.assert_allclose(np.asarray(r_k.residuals),
                               np.asarray(r.residuals),
                               rtol=1e-4, atol=2e-6)


def test_sparse_kernel_without_engine_falls_back_loudly(sparse_sys):
    # A solver with no kernel engine (supports_kernel=False) downgrades
    # a sparse use_kernel request with a RuntimeWarning, then matches
    # the unfused path bit-for-bit.
    s = solvers.get("dgd")
    prm = s.resolve_params(sparse_sys)
    with pytest.warns(RuntimeWarning, match="supports_kernel=False"):
        r_k = s.solve(sparse_sys, iters=100, use_kernel=True, **prm)
    r = s.solve(sparse_sys, iters=100, **prm)
    assert np.array_equal(np.asarray(r_k.x), np.asarray(r.x))
    assert np.array_equal(np.asarray(r_k.residuals),
                          np.asarray(r.residuals))


def test_sparse_solve_many_matches_densified(sparse_sys):
    s = solvers.get("cimmino")
    prm = s.resolve_params(sparse_sys)
    rng = np.random.default_rng(3)
    B = np.stack([rng.standard_normal(sparse_sys.N) for _ in range(2)])
    r_sp = s.solve_many(sparse_sys, B, iters=150, **prm)
    r_dn = s.solve_many(sparse_sys.densified(), B, iters=150, **prm)
    np.testing.assert_allclose(np.asarray(r_sp.x), np.asarray(r_dn.x),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(r_sp.residuals),
                               np.asarray(r_dn.residuals),
                               rtol=1e-6, atol=1e-12)


# ---------------------------------------------------------------------------
# streaming mode: solve_stream + warm-start gating through both servers
# ---------------------------------------------------------------------------


def _perturbed_stream(fp, n, k, seed):
    rng = np.random.default_rng(seed)
    b0 = rng.standard_normal(n)
    return [(fp, b0 + 1e-3 * rng.standard_normal(n)) for _ in range(k)]


@pytest.fixture(scope="module")
def small_sys():
    return linsys.conditioned_gaussian(n=48, m=4, cond=10.0, seed=0)


def test_solve_stream_warm_hits_for_warm_rhs_ok_solver(small_sys):
    srv = LinsysServer(FactorStore(), solver="dhbm", iters=120, batch=1,
                      warm_start=True)
    fp = srv.register(small_sys)
    rep = solve_stream(srv, _perturbed_stream(fp, 48, 8, seed=4))
    assert len(rep.served) == 8
    assert rep.batches == 8
    # only the very first batch has no prior state to resume from
    assert rep.warm_batches == 7
    assert rep.warm_hit_rate == pytest.approx(7 / 8)
    assert [r.warm for r in rep.served] == [False] + [True] * 7


def test_solve_stream_cold_for_state_caching_solver(small_sys):
    # APC iterates stay feasible for the OLD b: perturbed-RHS traffic must
    # serve cold every time, and the report says so
    srv = LinsysServer(FactorStore(), solver="apc", iters=40, batch=1,
                      warm_start=True, gamma=1.0, eta=1.0)
    fp = srv.register(small_sys)
    rep = solve_stream(srv, _perturbed_stream(fp, 48, 6, seed=5))
    assert rep.batches == 6 and rep.warm_batches == 0
    assert rep.warm_hit_rate == 0.0


def test_solve_stream_async_server_parity(small_sys):
    stream_args = (48, 8, 4)
    sync = LinsysServer(FactorStore(), solver="dhbm", iters=120, batch=1,
                        warm_start=True)
    fp_s = sync.register(small_sys)
    rep_s = solve_stream(sync, _perturbed_stream(fp_s, *stream_args))

    asrv = AsyncLinsysServer(FactorStore(), solver="dhbm", iters=120,
                             batch=1, warm_start=True)
    fp_a = asrv.register(small_sys)
    with asrv:
        rep_a = solve_stream(asrv, _perturbed_stream(fp_a, *stream_args))
    assert rep_a.batches == rep_s.batches
    assert rep_a.warm_batches == rep_s.warm_batches
    assert [r.rid for r in rep_a.served] == [r.rid for r in rep_s.served]
    for ra, rs in zip(rep_a.served, rep_s.served):
        assert np.array_equal(np.asarray(ra.x), np.asarray(rs.x))
        assert ra.residual == rs.residual


def test_solve_stream_coalesces_with_larger_drain_cadence(small_sys):
    srv = LinsysServer(FactorStore(), solver="apc", iters=20, batch=4,
                      gamma=1.0, eta=1.0)
    fp = srv.register(small_sys)
    rep = solve_stream(srv, _perturbed_stream(fp, 48, 8, seed=6),
                       drain_every=4)
    assert len(rep.served) == 8
    assert rep.batches == 2                      # 2 full coalesced batches
    assert srv.stats.padded == 0


def test_solve_stream_validates_cadence(small_sys):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5,
                      gamma=1.0, eta=1.0)
    with pytest.raises(ValueError, match="drain_every"):
        solve_stream(srv, [], drain_every=0)


def test_solve_stream_empty_stream(small_sys):
    srv = LinsysServer(FactorStore(), solver="apc", iters=5,
                      gamma=1.0, eta=1.0)
    rep = solve_stream(srv, [])
    assert rep.served == [] and rep.batches == 0
    assert rep.warm_hit_rate == 0.0


def test_serve_least_squares_system(ls_sys):
    # the server's LS executors report the optimality residual — a served
    # LS request converges to the lstsq solution of ITS rhs
    srv = LinsysServer(FactorStore(), solver="dgd", iters=800, batch=1)
    fp = srv.register(ls_sys)
    rng = np.random.default_rng(7)
    b = rng.standard_normal(ls_sys.N)
    srv.submit(fp, b)
    out = srv.drain()[0]
    A, _ = map(np.asarray, ls_sys.dense())
    x_ref, *_ = np.linalg.lstsq(A, b, rcond=None)
    assert _rel_err(out.x, x_ref) < 1e-6
    assert out.residual < 1e-8


def test_serve_sparse_system_matches_densified(sparse_sys):
    rng = np.random.default_rng(8)
    rhs = [rng.standard_normal(sparse_sys.N) for _ in range(3)]
    outs = {}
    for tag, sys_ in (("sp", sparse_sys), ("dn", sparse_sys.densified())):
        srv = LinsysServer(FactorStore(), solver="cimmino", iters=150,
                          batch=1)
        fp = srv.register(sys_)
        for b in rhs:
            srv.submit(fp, b)
        outs[tag] = srv.drain()
    for r_sp, r_dn in zip(outs["sp"], outs["dn"]):
        np.testing.assert_allclose(np.asarray(r_sp.x), np.asarray(r_dn.x),
                                   rtol=1e-8, atol=1e-10)
