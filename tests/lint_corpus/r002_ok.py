"""R002 conforming: jax.random with threaded keys; host timing outside
the traced region; seeded Generator construction."""
import time

import jax
import numpy as np


@jax.jit
def good_step(key, x):
    noise = jax.random.normal(key, x.shape)
    return x + noise


def host_probe(f, x):
    t0 = time.perf_counter()
    jax.block_until_ready(f(x))
    return time.perf_counter() - t0


RNG = np.random.default_rng(0)
