"""R004 violations: registered solvers with incomplete hook surfaces."""
from repro.solvers.registry import register


class _Base:
    def prepare(self, A_blocks, prm):
        raise NotImplementedError  # abstract stub: does NOT count


@register("half_baked")
class HalfBaked(_Base):
    def prepare(self, A_blocks, prm):
        return A_blocks

    def init(self, factors, b_blocks, prm):
        return b_blocks

    def step(self, factors, b_blocks, state, prm):
        return state
    # missing extract()


@register("mesh_partial")
class MeshPartial:
    def prepare(self, A_blocks, prm):
        return A_blocks

    def init(self, factors, b_blocks, prm):
        return b_blocks

    def step(self, factors, b_blocks, state, prm):
        return state

    def extract(self, state, prm):
        return state

    def mesh_step(self, factors, b_blocks, state, prm):
        # any mesh_* hook demands the full mesh set
        return state
