"""R001 violation carrying an inline suppression: must lint clean."""
import jax


def build_step(f):
    return jax.jit(f)  # repro: allow[R001] one-shot tool, jit deliberately scoped to the call
