"""R006 conforming: interpret threaded from default_interpret()."""
from jax.experimental import pallas as pl

from repro.kernels.block_projection import default_interpret


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def fused(x, shape, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return pl.pallas_call(_kernel, out_shape=shape, interpret=interpret)(x)
