"""Lock-discipline conforming version of locks_bad.BadPipeline."""
import threading

import jax


class GoodPipeline:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending = []
        self._count = 0
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, item):
        with self._cv:
            self._pending.append(item)
            self._count += 1
            self._cv.notify_all()

    def wait_idle(self):
        with self._cv:
            while self._pending:
                self._cv.wait()

    def _loop(self):
        while True:
            with self._cv:
                while not self._pending:
                    self._cv.wait()
                item = self._pending.pop()
                self._count -= 1
            out = item.run()
            jax.block_until_ready(out)
