"""R008 violations: capability claims without the mode machinery."""


class LSClaimNoHooks:
    # claims least_squares but the chain has neither ls hook
    supports = frozenset({"square", "least_squares"})

    def step(self, factors, b_blocks, state, prm):
        return state


class _LsBase:
    def ls_moment(self, factors, A, b, x, params, ctx):
        raise NotImplementedError  # interface stub: does NOT count


class LSClaimStubbed(_LsBase):
    # inherits only the abstract stub; ls_reference missing outright
    supports = frozenset({"least_squares"})

    def step(self, factors, b_blocks, state, prm):
        return state


class SparseClaimNoBlockops:
    # claims sparse but this module never imports repro.core.blockops,
    # so a SparseBlocks operand would hit raw einsums and crash
    supports = ("square", "sparse")

    def step(self, factors, b_blocks, state, prm):
        return state
