"""R007 violation: broad except that swallows without resolving."""


def run_request(req):
    try:
        return req.solve()
    except Exception:
        return None  # the caller's future never learns about this
