"""R007 conforming: narrow types, or broad with resolution/re-raise."""


def run_request(req):
    try:
        return req.solve()
    except (ValueError, RuntimeError):
        return None


def run_and_resolve(req):
    try:
        req.future.set_result(req.solve())
    except Exception as e:
        req.future.set_exception(e)


def run_and_reraise(req):
    try:
        return req.solve()
    except Exception:
        req.log("failed")
        raise
