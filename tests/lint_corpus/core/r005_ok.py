"""R005 conforming: the sanctioned lazy-import shim pattern."""


def solve(A, b):
    from repro.solvers import get_solver  # lazy: cycle guard
    return get_solver("apc").solve(A, b)
