"""R005 violations: a core/ module importing upward at module scope."""
from repro.solvers import registry  # noqa: F401

import repro.kernels.ops as kops  # noqa: F401
