"""R006 violations: pallas_call with pinned or missing interpret mode."""
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def fused_pinned(x, shape):
    return pl.pallas_call(_kernel, out_shape=shape, interpret=True)(x)


def fused_missing(x, shape):
    # no interpret= at all silently means compiled-only
    return pl.pallas_call(_kernel, out_shape=shape)(x)
