# Deliberately-violating snippets for tests/test_analysis_lint.py.
# These files are PARSED, never imported; every *_bad.py must trip its
# rule, every *_ok.py must be fully clean under ALL rules.
