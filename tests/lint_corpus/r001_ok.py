"""R001 conforming: jits live at module scope."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    return x * 2


@functools.partial(jax.jit, static_argnames=("k",))
def scaled(x, k=2):
    return x * k


_sin = jax.jit(jnp.sin)
