"""R003 violations: factorization acquired past the FactorStore."""


def factor_directly(get_solver, A_blocks, prm):
    solver = get_solver("apc")
    return solver.prepare(A_blocks, prm)


def mesh_factor_directly(solver, mesh, A_blocks, prm):
    return solver.mesh_prepare(mesh, A_blocks, prm)
