"""R009 conforming: the execution surface travels on ONE plan."""
from repro.solvers import ExecutionPlan


def run(solver, sys_, mesh, store):
    plan = ExecutionPlan(backend="mesh", mesh=mesh, kernel=True,
                         store=store)
    res = solver.solve(sys_, iters=100, plan=plan)
    many = solver.solve_many(sys_, [sys_.b_blocks],
                             plan=plan.replace(kernel=False))
    return res, many
