"""R009 violations: internal code leaning on the deprecated kwarg shim."""


def run(solver, sys_, mesh, store):
    res = solver.solve(sys_, iters=100, backend="mesh", mesh=mesh,
                       use_kernel=True)
    many = solver.solve_many(sys_, [sys_.b_blocks], store=store,
                             precision="mixed")
    return res, many
