"""R007 violation carrying an inline suppression: must lint clean."""


def sweep(suites):
    for s in suites:
        try:
            s.run()
        except Exception:  # repro: allow[R007] diagnostic sweep, no futures in flight
            continue
