"""R004 conforming: lifecycle completed across an inheritance split,
full mesh set on the base."""
from repro.solvers.registry import register


class _Family:
    def prepare(self, A_blocks, prm):
        return A_blocks

    def step(self, factors, b_blocks, state, prm):
        return state

    def extract(self, state, prm):
        return state

    def mesh_factor_specs(self, prm):
        return ()

    def mesh_state_specs(self, prm):
        return ()

    def mesh_prepare(self, mesh, A_blocks, prm):
        return A_blocks

    def mesh_step(self, factors, b_blocks, state, prm):
        return state


@register("family_member")
class FamilyMember(_Family):
    def init(self, factors, b_blocks, prm):
        return b_blocks
