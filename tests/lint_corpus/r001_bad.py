"""R001 violations: jax.jit constructed per call / per loop iteration."""
import jax


def build_step(f):
    # fresh jit wrapper per call: every caller pays a full retrace
    return jax.jit(f)


STEPS = []
for _k in range(4):
    STEPS.append(jax.jit(lambda x, _k=_k: x * _k))
