"""R008 conforming: claims backed by hooks across an inheritance split,
sparse claim backed by the blockops import."""
from repro.core import blockops


class _LsFamily:
    def ls_moment(self, factors, A, b, x, params, ctx):
        return ctx.psum_workers(blockops.brmatvec_sum(A, b))

    def ls_reference(self, sys):
        return sys.x_true


class FullClaims(_LsFamily):
    supports = frozenset({"square", "least_squares", "sparse"})

    def step(self, factors, b_blocks, state, prm):
        return state


class SquareOnly:
    # no LS/sparse claim -> no obligations
    supports = frozenset({"square"})

    def step(self, factors, b_blocks, state, prm):
        return state
