"""R002 violations: host time/RNG baked into traced code."""
import random
import time

import jax
import numpy as np


@jax.jit
def bad_step(x):
    noise = np.random.rand(3)          # unseeded host RNG at trace time
    t0 = time.time()                   # host clock at trace time
    return x + noise + t0 + random.random()


def scan_body(carry, x):
    return carry + time.perf_counter(), x


def run(xs):
    return jax.lax.scan(scan_body, 0.0, xs)
