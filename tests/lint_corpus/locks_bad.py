"""Lock-discipline violations (L001/L002/L003) in a threaded class."""
import threading

import jax


class BadPipeline:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending = []
        self._count = 0
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, item):
        self._pending.append(item)  # L001: shared write, no lock
        self._count += 1            # L001
        with self._cv:
            self._cv.notify_all()

    def wait_idle(self):
        self._cv.wait()             # L002: wait without the lock

    def _loop(self):
        while True:
            with self._lock:
                if self._pending:
                    item = self._pending.pop()
                    self._count -= 1
                    out = item.run()            # L003: blocking under lock
                    jax.block_until_ready(out)  # L003
