"""R003 conforming: factors via the store; self-receivers exempt."""


def factors_via_store(store, system, solver, prm):
    return store.factors(system, solver, prm)


class MySolver:
    def prepare(self, A_blocks, prm):
        return A_blocks

    def mesh_prepare(self, mesh, A_blocks, prm):
        # a solver invoking its own prepare IS the factorization
        return self.prepare(A_blocks, prm)
